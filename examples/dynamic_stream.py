"""Dynamic datasets (paper contribution 2): points arrive in waves during
a single continual optimisation -- no precompute stall, no recompilation.

Each wave's optimisation runs through the resilient chunked driver
(``fit(state=..., resilience=ResiliencePolicy(...))``): health telemetry
is checked after every chunk, the full state is checkpointed between
waves' chunks, and a NaN/explosion chunk would roll back and retry with a
backed-off learning rate instead of killing the session -- the always-on
interactive service the paper pitches.

  PYTHONPATH=src python examples/dynamic_stream.py
"""
import sys
import tempfile
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax                       # noqa: E402
import jax.numpy as jnp          # noqa: E402
import numpy as np               # noqa: E402

from repro.core import funcsne                       # noqa: E402
from repro.core.knn import exact_knn                 # noqa: E402
from repro.core.quality import rnx_auc, rnx_curve    # noqa: E402
from repro.core.resilience import ResiliencePolicy   # noqa: E402
from repro.data.synthetic import blobs               # noqa: E402


def main():
    n_total, wave = 1800, 600
    X, labels = blobs(n=n_total, dim=24, n_centers=6, center_std=6.0, seed=0)
    Xj = jnp.asarray(X)
    cfg = funcsne.FuncSNEConfig(n_points=n_total, dim_hd=24)
    hp = funcsne.default_hparams(n_total, perplexity=12.0)
    active = jnp.arange(n_total) < wave
    st = funcsne.init_state(jax.random.PRNGKey(0), Xj, cfg, active=active,
                            perplexity=hp.perplexity)

    # session-lifetime policy: one checkpoint dir spans all waves, so a
    # killed session resumes (fit(resume_from=...)) with whatever points
    # had streamed in by the last committed chunk
    ckdir = tempfile.mkdtemp(prefix="funcsne-stream-ck-")
    policy = ResiliencePolicy(checkpoint_dir=ckdir, checkpoint_every=2,
                              on_event=lambda e: print(f"  [resilience] {e}"))
    hold = lambda it, n_iter, h: h      # hp held constant within a wave

    for wave_i in range(3):
        t0 = time.time()
        st, _ = funcsne.fit(Xj, cfg=cfg, n_iter=300, chunk_size=50,
                            hparams=hp, schedule=hold, state=st,
                            resilience=policy, validate=wave_i == 0)
        act = int(st.active.sum())
        # sample the first 512 rows (active in every wave); the exact KNN
        # reference must exclude not-yet-arrived points, and the R_NX
        # chance correction must use the active count, not capacity
        k = cfg.k_hd
        true_idx, _ = exact_knn(Xj, k, active=st.active)
        q = float(rnx_auc(rnx_curve(st.hd_idx[:512, :k], true_idx[:512],
                                    act)))
        print(f"wave {wave_i}: {act} active points, 300 iters in "
              f"{time.time() - t0:.1f}s, knn AUC(sample)={q:.3f}")
        if wave_i < 2:
            new = jnp.arange(wave * (wave_i + 1), wave * (wave_i + 2))
            st = funcsne.add_points(st, new, jax.random.PRNGKey(wave_i))
            print(f"  + added {len(new)} points mid-run (no recompile)")
    # and remove a cluster
    st = funcsne.remove_points(st, jnp.nonzero(jnp.asarray(labels == 0))[0])
    st, _ = funcsne.fit(Xj, cfg=cfg, n_iter=100, chunk_size=50, hparams=hp,
                        schedule=hold, state=st, resilience=policy,
                        validate=False)
    print(f"removed cluster 0 -> {int(st.active.sum())} active; "
          f"embedding finite: {bool(jnp.isfinite(st.Y).all())}; "
          f"{len(policy.events)} resilience events; checkpoints in {ckdir}")


if __name__ == "__main__":
    main()

"""The paper's flagship property: change ANY hyperparameter mid-run --
including HD-side ones (perplexity) -- with zero recompilation or restart.

A scripted stand-in for the GUI: we sweep alpha 3.0 -> 0.5 (cluster
fragmentation), crank the repulsion ratio (paper Sec. 4.1), and *change the
perplexity* mid-flight; the sigma refresh absorbs it within a few
iterations because affinities are re-derived from the live KNN sets.

  PYTHONPATH=src python examples/interactive_hparams.py
"""
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax                       # noqa: E402
import jax.numpy as jnp          # noqa: E402
import numpy as np               # noqa: E402

from repro.core import funcsne   # noqa: E402
from repro.core.dbscan import dbscan, relabel_compact  # noqa: E402
from repro.data.synthetic import mnist_like            # noqa: E402


def cluster_count(Y, q=0.02):
    sub = Y[:: max(1, len(Y) // 1024)]
    d = np.sqrt(((sub[:, None] - sub[None, :]) ** 2).sum(-1))
    eps = float(np.quantile(d[d > 0], q))
    _, k = relabel_compact(dbscan(jnp.asarray(Y), eps, 5))
    return k


def main():
    X, _ = mnist_like(n=1500, dim=48, seed=0)
    Xj = jnp.asarray(X)
    n = len(X)
    cfg = funcsne.FuncSNEConfig(n_points=n, dim_hd=48)
    st = funcsne.init_state(jax.random.PRNGKey(0), Xj, cfg)
    step = funcsne.make_step(cfg)
    hp = funcsne.default_hparams(n, perplexity=15.0)

    phases = [
        ("warmup (early exaggeration)", 300,
         hp._replace(exaggeration=jnp.float32(12.0),
                     momentum=jnp.float32(0.5))),
        ("alpha=1.0 (t-SNE tails)", 250, hp),
        ("alpha=0.5 (heavier tails)", 250,
         hp._replace(alpha=jnp.float32(0.5), lr=hp.lr * 0.3)),
        ("alpha=0.5 + 3x repulsion (de-collapse)", 250,
         hp._replace(alpha=jnp.float32(0.5), repulsion=jnp.float32(3.0),
                     lr=hp.lr * 0.3)),
        ("perplexity 15 -> 40 (HD-side change!)", 250,
         hp._replace(perplexity=jnp.float32(40.0), lr=hp.lr * 0.3)),
    ]
    for name, iters, ph in phases:
        t0 = time.time()
        for _ in range(iters):
            st = step(st, Xj, ph)
        jax.block_until_ready(st.Y)
        dt = time.time() - t0
        k = cluster_count(np.asarray(st.Y))
        print(f"{name:45s} {iters} iters in {dt:5.1f}s "
              f"({iters / dt:5.0f} it/s)  clusters={k}")
    print("no recompilation happened after the first phase: every "
          "hyperparameter above is a traced scalar.")


if __name__ == "__main__":
    main()

"""Paper Sec. 4.2 (ImageNet/EVA pipeline shape): embed the hidden states
of an LM backbone with a higher-dimensional FUnc-SNE and evaluate 1-NN
transfer -- model latents -> PCA -> 8-D NE -> 1-NN.

Uses the musicgen-large *smoke* backbone as the latent producer (any
assigned arch works; the frontend is the assignment's modality stub).

  PYTHONPATH=src python examples/embed_latents.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax                       # noqa: E402
import jax.numpy as jnp          # noqa: E402
import numpy as np               # noqa: E402

from repro.configs.base import get_arch, smoke_variant  # noqa: E402
from repro.core import funcsne                           # noqa: E402
from repro.core.quality import one_nn_accuracy           # noqa: E402
from repro.models.transformer import LMModel             # noqa: E402


def main():
    cfg = smoke_variant(get_arch("musicgen-large"))
    model = LMModel(cfg)
    params = model.init_params(jax.random.PRNGKey(0))

    # synthetic "audio": 8 latent classes of frame-embedding sequences
    n_seq, seq = 512, 24
    rng = np.random.default_rng(0)
    protos = rng.normal(size=(8, cfg.d_model)).astype(np.float32) * 2.0
    labels = rng.integers(0, 8, n_seq)
    frames = (protos[labels][:, None, :]
              + rng.normal(size=(n_seq, seq, cfg.d_model))
              .astype(np.float32) * 0.7)

    # backbone latents: mean-pooled final hidden states
    H = []
    for i in range(0, n_seq, 64):
        h = model.hidden_states(params, jnp.asarray(frames[i:i + 64]))
        H.append(np.asarray(h.mean(axis=1), np.float32))
    H = np.concatenate(H)

    # latents -> PCA(16) -> FUnc-SNE(8)
    Hj = jnp.asarray(H)
    W = funcsne.pca_directions(Hj, 16)
    Hp = np.asarray((Hj - Hj.mean(0)) @ W)
    cfg_ne = funcsne.FuncSNEConfig(n_points=n_seq, dim_hd=16, dim_ld=8)
    st, _ = funcsne.fit(Hp, cfg=cfg_ne, n_iter=500,
                        hparams=funcsne.default_hparams(n_seq,
                                                        perplexity=12.0))
    lj = jnp.asarray(labels)
    for name, Z in (("backbone latents", Hj), ("pca16", jnp.asarray(Hp)),
                    ("funcsne8", st.Y)):
        acc = one_nn_accuracy(Z, lj, jax.random.PRNGKey(1), n_trials=5,
                              one_shot=True)
        print(f"one-shot 1-NN accuracy on {name:18s}: {float(acc):.3f}")


if __name__ == "__main__":
    main()

"""Paper Sec. 4.2: extract a cluster hierarchy by sweeping alpha in a
continual optimisation (d_ld=4) and linking DBSCAN clusters across levels.

  PYTHONPATH=src python examples/hierarchy_graph.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core.hierarchy import extract_hierarchy   # noqa: E402
from repro.data.synthetic import hierarchical_cells  # noqa: E402


def main():
    X, major, minor = hierarchical_cells(n=1200, dim=24, n_major=4,
                                         minors_per_major=4, seed=0)
    graph = extract_hierarchy(X, alphas=(3.0, 1.0, 0.5),
                              iters_per_level=300, warmup_iters=300)
    print(graph.summary())
    # ground truth: 4 major types splitting into 16 minor types
    ks = [lv.n_clusters for lv in graph.levels]
    print(f"cluster counts per level (alpha 3.0 -> 0.5): {ks}")
    print(f"(data truth: 4 major -> 16 minor)")
    strong = [e for e in graph.edges if e[4] > 0.5]
    print(f"{len(strong)} strong parent->child edges, e.g.:")
    for e in strong[:8]:
        print(f"  level{e[0]}/cluster{e[1]} -> level{e[2]}/cluster{e[3]} "
              f"(overlap {e[4]:.2f})")


if __name__ == "__main__":
    main()

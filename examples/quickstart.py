"""Quickstart: embed a synthetic single-cell-style dataset with FUnc-SNE.

  PYTHONPATH=src python examples/quickstart.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax.numpy as jnp          # noqa: E402
import numpy as np               # noqa: E402

from repro.core import funcsne                      # noqa: E402
from repro.core.quality import (embedding_quality,  # noqa: E402
                                knn_set_quality, one_nn_accuracy)
from repro.data.synthetic import hierarchical_cells  # noqa: E402
import jax                       # noqa: E402


def main():
    X, major, minor = hierarchical_cells(n=2000, dim=32, seed=0)
    hp = funcsne.default_hparams(len(X), alpha=1.0, perplexity=15.0)
    st, _ = funcsne.fit(X, n_iter=750, hparams=hp)

    Xj = jnp.asarray(X)
    print(f"HD KNN quality (AUC R_NX vs exact): "
          f"{float(knn_set_quality(st.hd_idx, Xj)):.3f}")
    print(f"embedding quality (AUC R_NX):        "
          f"{float(embedding_quality(Xj, st.Y)):.3f}")
    print(f"1-NN major-type accuracy in 2-D:     "
          f"{float(one_nn_accuracy(st.Y, jnp.asarray(major), jax.random.PRNGKey(0))):.3f}")
    np.save("quickstart_embedding.npy", np.asarray(st.Y))
    print("wrote quickstart_embedding.npy")


if __name__ == "__main__":
    main()

"""End-to-end driver: train a ~100M-class LM for a few hundred steps with
checkpoint/restart (thin wrapper over repro.launch.train).

  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

if __name__ == "__main__":
    sys.argv = [sys.argv[0], "--arch", "qwen2-7b", "--reduce",
                "--steps", "300", "--batch", "8", "--seq", "256",
                "--ckpt-dir", "checkpoints/example_train"] + sys.argv[1:]
    from repro.launch.train import main
    main()

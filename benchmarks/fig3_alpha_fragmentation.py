"""Paper Fig. 3/5: heavier LD tails (smaller alpha) fragment the embedding
into finer clusters.  Reports DBSCAN cluster counts per alpha on the
mnist-like manifold mixture, under a continual optimisation (no restart
between alpha levels -- the interactive-sweep regime).
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row
from repro.core import funcsne
from repro.core.dbscan import dbscan, relabel_compact
from repro.data.synthetic import mnist_like


def run(n=1200, warmup=400, per_level=250, alphas=(3.0, 1.0, 0.5)):
    X, _ = mnist_like(n=n, dim=48, n_classes=10, seed=0)
    Xj = jnp.asarray(X)
    cfg = funcsne.FuncSNEConfig(n_points=n, dim_hd=48)
    base = funcsne.default_hparams(n, perplexity=12.0)
    st = funcsne.init_state(jax.random.PRNGKey(0), Xj, cfg)
    step = funcsne.make_step(cfg)
    for it in range(warmup):
        st = step(st, Xj, funcsne.default_schedule(it, warmup, base))
    rows = []
    for alpha in alphas:
        hp = base._replace(alpha=jnp.float32(alpha),
                           lr=base.lr * 0.3)
        for _ in range(per_level):
            st = step(st, Xj, hp)
        Y = np.asarray(st.Y)
        sub = Y[:: max(1, n // 1024)]
        d = np.sqrt(((sub[:, None] - sub[None, :]) ** 2).sum(-1))
        eps = float(np.quantile(d[d > 0], 0.02))
        _, k = relabel_compact(dbscan(jnp.asarray(Y), eps, 5))
        rows.append(row(f"fig3_alpha{alpha}", 0.0, f"clusters={k}"))
    return rows

"""Paper Table 2: 1-NN classification in three representations --
raw HD features, PCA, and a higher-dimensional FUnc-SNE embedding
(d_ld=8 here; the paper uses 32 on ImageNet/EVA features).

one-shot = one labelled example per class; loo = leave-one-out.
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timed
from repro.core import funcsne
from repro.core.quality import one_nn_accuracy
from repro.data.synthetic import mnist_like


def run(n=1500, iters=600):
    X, labels = mnist_like(n=n, dim=64, n_classes=10, seed=0)
    Xj, lj = jnp.asarray(X), jnp.asarray(labels)
    reps = {"raw64": Xj}
    W = funcsne.pca_directions(Xj, 16)
    reps["pca16"] = (Xj - Xj.mean(0)) @ W
    cfg = funcsne.FuncSNEConfig(n_points=n, dim_hd=64, dim_ld=8)
    hp = funcsne.default_hparams(n, perplexity=15.0)
    (st, _), dt = timed(lambda: funcsne.fit(X, cfg=cfg, n_iter=iters,
                                            hparams=hp))
    reps["ne8"] = st.Y
    rows = []
    for name, Z in reps.items():
        one = float(one_nn_accuracy(Z, lj, jax.random.PRNGKey(0),
                                    n_trials=5, one_shot=True))
        loo = float(one_nn_accuracy(Z, lj, jax.random.PRNGKey(0)))
        rows.append(row(f"table2_{name}",
                        dt * 1e6 / iters if name == "ne8" else 0.0,
                        f"one_shot={one:.3f};loo={loo:.3f}"))
    return rows

"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Each module's ``run()``
reproduces the measurement behind the corresponding paper artifact at
CPU-feasible scale; the roofline table (EXPERIMENTS.md) comes from the
dry-run (repro.launch.dryrun), not from here.

``--json PATH`` additionally writes the machine-readable results
(``{name: us_per_call}``) so the perf trajectory is tracked in-repo:
``BENCH_kernels.json`` (kernel microbenches) and ``BENCH_step.json``
(fig8 step timings) are the committed baselines.

  PYTHONPATH=src python -m benchmarks.run [--only fig6,fig8] [--fast]
                                          [--json PATH]
"""
import argparse
import json
import sys
import time
import traceback
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

MODULES = ["fig4_feedback_loop", "fig6_rnx_quality", "fig7_knn_vs_nnd",
           "fig8_scaling", "table2_one_shot", "fig3_alpha_fragmentation",
           "bench_kernels"]

FAST_KW = {
    "fig4_feedback_loop": dict(n=600, iters=120, probe_every=60),
    "fig6_rnx_quality": dict(n=600, iters=250),
    "fig7_knn_vs_nnd": dict(n=800, iters=200),
    "fig8_scaling": dict(sizes=(512, 1024, 2048), iters=60,
                         cand_ns=(2048, 16384), cand_iters=6),
    "table2_one_shot": dict(n=800, iters=300),
    "fig3_alpha_fragmentation": dict(n=700, warmup=250, per_level=150),
    "bench_kernels": dict(ns=(1024, 4096), repeats=5),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated module prefixes")
    ap.add_argument("--fast", action="store_true",
                    help="reduced sizes (CI)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write {name: us_per_call} JSON to PATH")
    args = ap.parse_args()

    selected = MODULES
    if args.only:
        keys = args.only.split(",")
        selected = [m for m in MODULES if any(m.startswith(k) for k in keys)]

    results = {}
    print("name,us_per_call,derived")
    for mod_name in selected:
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{mod_name}",
                             fromlist=["run"])
            kwargs = FAST_KW.get(mod_name, {}) if args.fast else {}
            for r in mod.run(**kwargs):
                print(r, flush=True)
                try:
                    name, us = str(r).split(",")[:2]
                    results[name] = float(us)
                except ValueError:
                    pass
            print(f"# {mod_name} done in {time.time() - t0:.1f}s",
                  flush=True)
        except Exception:
            print(f"# {mod_name} FAILED:", flush=True)
            traceback.print_exc()

    if args.json:
        out = Path(args.json)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(json.dumps(results, indent=2, sort_keys=True) + "\n")
        print(f"# wrote {len(results)} results to {args.json}", flush=True)


if __name__ == "__main__":
    main()

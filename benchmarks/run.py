"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Each module's ``run()``
reproduces the measurement behind the corresponding paper artifact at
CPU-feasible scale; the roofline table (EXPERIMENTS.md) comes from the
dry-run (repro.launch.dryrun), not from here.

  PYTHONPATH=src python -m benchmarks.run [--only fig6,fig8] [--fast]
"""
import argparse
import sys
import time
import traceback
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

MODULES = ["fig4_feedback_loop", "fig6_rnx_quality", "fig7_knn_vs_nnd",
           "fig8_scaling", "table2_one_shot", "fig3_alpha_fragmentation"]

FAST_KW = {
    "fig4_feedback_loop": dict(n=600, iters=120, probe_every=60),
    "fig6_rnx_quality": dict(n=600, iters=250),
    "fig7_knn_vs_nnd": dict(n=800, iters=200),
    "fig8_scaling": dict(sizes=(512, 1024, 2048), iters=60),
    "table2_one_shot": dict(n=800, iters=300),
    "fig3_alpha_fragmentation": dict(n=700, warmup=250, per_level=150),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated module prefixes")
    ap.add_argument("--fast", action="store_true",
                    help="reduced sizes (CI)")
    args = ap.parse_args()

    selected = MODULES
    if args.only:
        keys = args.only.split(",")
        selected = [m for m in MODULES if any(m.startswith(k) for k in keys)]

    print("name,us_per_call,derived")
    for mod_name in selected:
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{mod_name}",
                             fromlist=["run"])
            kwargs = FAST_KW.get(mod_name, {}) if args.fast else {}
            for r in mod.run(**kwargs):
                print(r, flush=True)
            print(f"# {mod_name} done in {time.time() - t0:.1f}s",
                  flush=True)
        except Exception:
            print(f"# {mod_name} FAILED:", flush=True)
            traceback.print_exc()


if __name__ == "__main__":
    main()

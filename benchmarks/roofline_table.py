"""Format the EXPERIMENTS.md roofline table from the dry-run JSONs.

  PYTHONPATH=src python -m benchmarks.roofline_table [--mesh single]
"""
import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k",
               "embed_1m"]
ARCH_ORDER = ["chameleon-34b", "olmoe-1b-7b", "deepseek-v2-236b",
              "zamba2-2.7b", "mamba2-130m", "yi-34b", "qwen2.5-14b",
              "gemma2-2b", "qwen2-7b", "musicgen-large", "funcsne-1m"]


def fmt_s(x):
    if x == 0:
        return "0"
    for unit, scale in (("s", 1.0), ("ms", 1e-3), ("us", 1e-6),
                        ("ns", 1e-9)):
        if x >= scale:
            return f"{x / scale:.2f}{unit}"
    return f"{x:.1e}s"


def load(mesh):
    rows = []
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            f = RESULTS / f"{arch}__{shape}__{mesh}.json"
            if f.exists():
                rows.append(json.loads(f.read_text()))
    return rows


def table(mesh="single", md=True):
    rows = load(mesh)
    out = []
    hdr = ("| arch | shape | compute | memory | collective | bottleneck | "
           "6ND/HLO | HBM/chip | fits? |")
    out.append(hdr)
    out.append("|" + "---|" * 9)
    for r in rows:
        if r.get("status") == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | - | - | - | "
                       f"skipped | - | - | - |")
            continue
        if r.get("status") != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | - | - | - | "
                       f"ERROR | - | - | - |")
            continue
        t = r["roofline"]
        mem = r.get("memory") or {}
        hbm = (mem.get("argument_size_in_bytes", 0)
               + mem.get("temp_size_in_bytes", 0)) / 2 ** 30
        fits = "yes" if hbm and hbm < 16 else ("~" if hbm else "?")
        ratio = r.get("model_flops_ratio", 0.0)
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(t['compute_s'])} | "
            f"{fmt_s(t['memory_s'])} | {fmt_s(t['collective_s'])} | "
            f"{t['bottleneck']} | {ratio:.2f} | {hbm:.1f}GiB | {fits} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi"])
    args = ap.parse_args()
    print(table(args.mesh))


if __name__ == "__main__":
    main()

"""Paper Fig. 8: wall time vs dataset size at fixed dim (32).

Verifies the O(N) per-iteration claim: time/iter should grow ~linearly in
N.  ``fig8_linearity`` is the measured slope ratio over the ideal linear
ratio -- 1.0 means exactly linear, >1 superlinear -- computed from the
n-sweep endpoints.  Also compares the always-refine-HD variant (paper's
dashed line) against the default probabilistic refresh.

The chunked-driver rows time the scan-chunked step (§Perf H15) at the
sweep's largest size: ``fig8_chunked_T1`` dispatches every iteration (the
per-dispatch baseline the host loop used to pay), ``fig8_chunked_T50``
runs 50 iterations per dispatch; the ratio row is the amortisation win.
The two are timed *paired* (interleaved, best-of-trials) so shared-host
load hits both equally.

Run directly (``python -m benchmarks.fig8_scaling --smoke --json f.json``)
this module is its own harness: unlike ``benchmarks.run`` it does NOT
swallow exceptions, so CI uses ``--smoke`` as a driver-level regression
gate that actually fails the workflow.
"""
import argparse
import json
from pathlib import Path

import jax
import jax.numpy as jnp

from benchmarks.common import row, timed
from repro.core import funcsne
from repro.data.synthetic import blobs


def _copy(st):
    return jax.tree.map(lambda a: jnp.array(a, copy=True), st)


def _chunked_rows(n, Xj, iters, chunk_sizes, trials=5):
    """Per-iteration us for each chunk size, paired/interleaved."""
    cfg = funcsne.FuncSNEConfig(n_points=n, dim_hd=Xj.shape[1])
    hp = funcsne.default_hparams(n)
    st0 = funcsne.init_state(jax.random.PRNGKey(0), Xj, cfg)

    runners = {}
    for T in chunk_sizes:
        chunk = funcsne.make_chunked_step(cfg, T)
        n_chunks = max(1, iters // T)

        def run(chunk=chunk, n_chunks=n_chunks, T=T):
            st = _copy(st0)               # the program donates its input
            for _ in range(n_chunks):
                st, _, _ = chunk(st, Xj, hp)
            jax.block_until_ready(st.Y)
            return n_chunks * T

        run()                             # compile outside the clock
        runners[T] = run

    best = {T: float("inf") for T in chunk_sizes}
    for t in range(trials):
        order = chunk_sizes if t % 2 == 0 else tuple(reversed(chunk_sizes))
        for T in order:
            steps, dt = timed(runners[T])
            best[T] = min(best[T], dt * 1e6 / steps)
    rows = [row(f"fig8_chunked_T{T}_n{n}", best[T],
                f"{max(1, iters // T)}x{T}-step dispatches")
            for T in chunk_sizes]
    if len(chunk_sizes) >= 2:
        t1, tb = chunk_sizes[0], chunk_sizes[-1]
        ratio = best[t1] / max(best[tb], 1e-9)
        rows.append(row(f"fig8_chunked_amortisation_n{n}", ratio,
                        f"T{t1}_us/T{tb}_us={ratio:.3f} (ratio, not us)"))
    return rows


def _health_rows(n, Xj, iters, T, trials=5):
    """Full-chunk A/B of the in-scan health telemetry (resilience layer):
    ``health_metrics=True`` (finite-fraction / max-|Y| / first-bad-step
    scalars folded into the chunk scan) vs ``False`` (the pre-resilience
    ChunkMetrics).  Paired/interleaved best-of like the chunked rows; the
    acceptance bar is <= 5% overhead on the full step."""
    cfg = funcsne.FuncSNEConfig(n_points=n, dim_hd=Xj.shape[1])
    hp = funcsne.default_hparams(n)
    st0 = funcsne.init_state(jax.random.PRNGKey(0), Xj, cfg)
    n_chunks = max(1, iters // T)

    runners = {}
    for health in (False, True):
        chunk = funcsne.make_chunked_step(cfg, T, health_metrics=health)

        def run(chunk=chunk):
            st = _copy(st0)               # the program donates its input
            for _ in range(n_chunks):
                st, _, _ = chunk(st, Xj, hp)
            jax.block_until_ready(st.Y)
            return n_chunks * T

        run()                             # compile outside the clock
        runners[health] = run

    best = {h: float("inf") for h in runners}
    for t in range(trials):
        order = (False, True) if t % 2 == 0 else (True, False)
        for h in order:
            steps, dt = timed(runners[h])
            best[h] = min(best[h], dt * 1e6 / steps)
    ratio = best[True] / max(best[False], 1e-9)
    return [
        row(f"fig8_health_off_n{n}", best[False],
            f"T{T} chunks, no health telemetry"),
        row(f"fig8_health_on_n{n}", best[True],
            f"T{T} chunks, in-scan health telemetry"),
        row(f"fig8_health_overhead_n{n}", ratio,
            f"on_us/off_us={ratio:.3f} (ratio, not us; bar <=1.05)"),
    ]


def _audit_rows(n, Xj, iters, T, trials=5):
    """Full-chunk A/B of the chunk-boundary state auditor (resilience
    layer): the driver loop with ``audit_state`` + its host read after
    EVERY chunk (``audit_every=1``, the worst case) vs the plain loop.
    The audit is one fused pass over the index tables with no gathers,
    so the acceptance bar is <=1% per chunk at production chunk sizes.
    Paired/interleaved best-of like the chunked rows."""
    cfg = funcsne.FuncSNEConfig(n_points=n, dim_hd=Xj.shape[1])
    hp = funcsne.default_hparams(n)
    st0 = funcsne.init_state(jax.random.PRNGKey(0), Xj, cfg)
    n_chunks = max(1, iters // T)
    chunk = funcsne.make_chunked_step(cfg, T)

    def run_plain():
        st = _copy(st0)                   # the program donates its input
        for _ in range(n_chunks):
            st, _, _ = chunk(st, Xj, hp)
        jax.block_until_ready(st.Y)
        return n_chunks * T

    def run_audited():
        st = _copy(st0)
        for _ in range(n_chunks):
            st, _, _ = chunk(st, Xj, hp)
            jax.device_get(funcsne.audit_state(st, cfg, Xj))
        jax.block_until_ready(st.Y)
        return n_chunks * T

    runners = {False: run_plain, True: run_audited}
    for r in runners.values():
        r()                               # compile outside the clock
    best = {h: float("inf") for h in runners}
    for t in range(trials):
        order = (False, True) if t % 2 == 0 else (True, False)
        for h in order:
            steps, dt = timed(runners[h])
            best[h] = min(best[h], dt * 1e6 / steps)
    ratio = best[True] / max(best[False], 1e-9)
    return [
        row(f"fig8_audit_off_n{n}", best[False],
            f"T{T} chunks, no boundary audit"),
        row(f"fig8_audit_on_n{n}", best[True],
            f"T{T} chunks, audit_every=1 boundary audit"),
        row(f"fig8_audit_overhead_n{n}", ratio,
            f"on_us/off_us={ratio:.3f} (ratio, not us; bar <=1.01)"),
    ]


def _cand_rows(n, iters, trials=3):
    """Full-step A/B of the candidate-generation phase (§Perf H17):
    ``cand_fused=False`` (legacy threefry sampler + (n, s, K2) two-hop
    broadcasts) vs ``cand_fused=True`` (counter-hash sampler; in-kernel
    generation on the pallas path, flat jnp gathers on this host).
    Paired/interleaved best-of like the chunked rows."""
    X, _ = blobs(n=n, dim=32, n_centers=8, center_std=6.0, seed=0)
    Xj = jnp.asarray(X)
    hp = funcsne.default_hparams(n)
    runners = {}
    for fused in (False, True):
        cfg = funcsne.FuncSNEConfig(n_points=n, dim_hd=32,
                                    cand_fused=fused)
        st0 = funcsne.init_state(jax.random.PRNGKey(0), Xj, cfg)
        step = funcsne.make_step(cfg)
        jax.block_until_ready(step(_copy(st0), Xj, hp).Y)    # compile

        def run_one(step=step, st0=st0):
            s = _copy(st0)
            for _ in range(iters):
                s = step(s, Xj, hp)
            jax.block_until_ready(s.Y)
            return iters

        runners[fused] = run_one

    best = {f: float("inf") for f in runners}
    for t in range(trials):
        order = (False, True) if t % 2 == 0 else (True, False)
        for f in order:
            steps, dt = timed(runners[f])
            best[f] = min(best[f], dt * 1e6 / steps)
    ratio = best[False] / max(best[True], 1e-9)
    return [
        row(f"fig8_cand_xla_n{n}", best[False],
            "threefry sampler, full step"),
        row(f"fig8_cand_fused_n{n}", best[True],
            "counter-fused sampler, full step"),
        row(f"fig8_cand_ratio_n{n}", ratio,
            f"xla_us/fused_us={ratio:.3f} (ratio, not us)"),
    ]


def run(sizes=(512, 1024, 2048, 4096), iters=120, chunk_sizes=(1, 50),
        cand_ns=(2048, 16384), cand_iters=6):
    rows = []
    per_iter = {}
    for n in sizes:
        X, _ = blobs(n=n, dim=32, n_centers=8, center_std=6.0, seed=0)
        Xj = jnp.asarray(X)
        for always, tag in ((False, "default"), (True, "always_refine")):
            cfg = funcsne.FuncSNEConfig(
                n_points=n, dim_hd=32,
                min_refresh_prob=1.0 if always else 0.05)
            hp = funcsne.default_hparams(n)
            st = funcsne.init_state(jax.random.PRNGKey(0), Xj, cfg)
            step = funcsne.make_step(cfg)
            st = step(st, Xj, hp)           # compile
            jax.block_until_ready(st.Y)

            def loop(st=st):
                s = st
                for _ in range(iters):
                    s = step(s, Xj, hp)
                jax.block_until_ready(s.Y)
                return s

            _, dt = timed(loop)
            us = dt * 1e6 / iters
            per_iter[(tag, n)] = us
            rows.append(row(f"fig8_n{n}_{tag}", us, f"n={n}"))
    slope = (per_iter[("default", sizes[-1])]
             / max(per_iter[("default", sizes[0])], 1e-9))
    ideal = sizes[-1] / sizes[0]
    rows.append(row("fig8_linearity", slope / ideal,
                    f"t({sizes[-1]})/t({sizes[0]})={slope:.2f};"
                    f"ideal={ideal:.1f};score=slope/ideal (1.0=linear)"))

    # chunked driver at the largest size: per-dispatch vs 50-per-dispatch
    n = sizes[-1]
    X, _ = blobs(n=n, dim=32, n_centers=8, center_std=6.0, seed=0)
    rows += _chunked_rows(n, jnp.asarray(X), iters, tuple(chunk_sizes))

    # health-telemetry A/B (resilience layer): the on-device probes must
    # stay in the noise next to the force phase
    rows += _health_rows(n, jnp.asarray(X), iters, chunk_sizes[-1])

    # chunk-boundary auditor A/B (trusted recovery): worst-case
    # audit_every=1 must stay <=1% next to a full chunk dispatch
    rows += _audit_rows(n, jnp.asarray(X), iters, chunk_sizes[-1])

    # candidate-phase A/B (§Perf H17): more calls at the small size so
    # sub-ms deltas aren't swamped by dispatch noise
    for n in cand_ns:
        rows += _cand_rows(n, max(cand_iters,
                                  cand_iters * max(cand_ns) // n))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sweep: CI driver-level regression gate")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write {name: us_per_call} JSON to PATH")
    args = ap.parse_args()
    kwargs = dict(sizes=(256, 512), iters=16, chunk_sizes=(1, 8),
                  cand_ns=(256,), cand_iters=4) if args.smoke else {}
    results = {}
    print("name,us_per_call,derived")
    for r in run(**kwargs):
        print(r, flush=True)
        name, us = str(r).split(",")[:2]
        results[name] = float(us)
    if args.json:
        out = Path(args.json)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(json.dumps(results, indent=2, sort_keys=True) + "\n")
        print(f"# wrote {len(results)} results to {args.json}", flush=True)


if __name__ == "__main__":
    main()

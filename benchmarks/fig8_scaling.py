"""Paper Fig. 8: wall time vs dataset size at fixed dim (32).

Verifies the O(N) per-iteration claim: time/iter should grow ~linearly in
N (slope ratio reported).  Also compares the always-refine-HD variant
(paper's dashed line) against the default probabilistic refresh.
"""
import jax
import jax.numpy as jnp

from benchmarks.common import row, timed
from repro.core import funcsne
from repro.data.synthetic import blobs


def run(sizes=(512, 1024, 2048, 4096), iters=120):
    rows = []
    per_iter = {}
    for n in sizes:
        X, _ = blobs(n=n, dim=32, n_centers=8, center_std=6.0, seed=0)
        Xj = jnp.asarray(X)
        for always, tag in ((False, "default"), (True, "always_refine")):
            cfg = funcsne.FuncSNEConfig(
                n_points=n, dim_hd=32,
                min_refresh_prob=1.0 if always else 0.05)
            hp = funcsne.default_hparams(n)
            st = funcsne.init_state(jax.random.PRNGKey(0), Xj, cfg)
            step = funcsne.make_step(cfg)
            st = step(st, Xj, hp)           # compile
            jax.block_until_ready(st.Y)

            def loop(st=st):
                s = st
                for _ in range(iters):
                    s = step(s, Xj, hp)
                jax.block_until_ready(s.Y)
                return s

            _, dt = timed(loop)
            us = dt * 1e6 / iters
            per_iter[(tag, n)] = us
            rows.append(row(f"fig8_n{n}_{tag}", us, f"n={n}"))
    slope = (per_iter[("default", sizes[-1])]
             / max(per_iter[("default", sizes[0])], 1e-9))
    ideal = sizes[-1] / sizes[0]
    rows.append(row("fig8_linearity", 0.0,
                    f"t({sizes[-1]})/t({sizes[0]})={slope:.2f};"
                    f"ideal={ideal:.1f}"))
    return rows

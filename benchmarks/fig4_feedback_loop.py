"""Paper Fig. 4: the embedding<->KNN positive feedback loop.

HD KNN-set quality (AUC of R_NX vs exact sets) over iterations, with the
embedding frozen (no feedback) vs co-optimised, at d_ld in {2, 8}.
The paper's claim: live embeddings accelerate HD neighbour discovery, more
so at higher d_ld.
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timed
from repro.core import funcsne
from repro.core.quality import knn_set_quality
from repro.data.synthetic import hierarchical_cells


def run(n=1200, iters=240, probe_every=60):
    X, _, _ = hierarchical_cells(n=n, dim=32, seed=0)
    Xj = jnp.asarray(X)
    rows = []
    for d_ld in (2, 8):
        cfg = funcsne.FuncSNEConfig(n_points=n, dim_hd=32, dim_ld=d_ld,
                                    c_hd_rand=1, c_hd_non=2)
        hp = funcsne.default_hparams(n, perplexity=10.0)
        for frozen in (False, True):
            st = funcsne.init_state(jax.random.PRNGKey(0), Xj, cfg)
            step = funcsne.make_step(cfg)
            y0, curve = jnp.array(st.Y, copy=True), []   # step donates
            t0 = __import__("time").time()
            for it in range(iters):
                st = step(st, Xj, hp)
                if frozen:
                    st = st._replace(Y=jnp.array(y0, copy=True),
                                     vel=jnp.zeros_like(st.vel))
                if (it + 1) % probe_every == 0:
                    curve.append(float(knn_set_quality(st.hd_idx, Xj)))
            dt = (__import__("time").time() - t0) / iters
            label = f"fig4_dld{d_ld}_{'frozen' if frozen else 'live'}"
            rows.append(row(label, dt * 1e6,
                            "auc@probes:" + "|".join(f"{c:.3f}"
                                                     for c in curve)))
    return rows

"""Shared benchmark utilities."""
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))


def timed(fn, *args, repeats: int = 1, **kwargs):
    """Returns (result, seconds_per_call)."""
    t0 = time.time()
    out = None
    for _ in range(repeats):
        out = fn(*args, **kwargs)
    return out, (time.time() - t0) / repeats


def row(name: str, us_per_call: float, derived: str):
    return f"{name},{us_per_call:.1f},{derived}"

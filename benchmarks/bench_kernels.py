"""Microbenchmark: pre-gather vs gather-fused vs scatter/merge-fused paths.

Four comparisons, at N in {2k, 16k} with C/K at FuncSNEConfig defaults:

  * ``pairwise_sqdist``: explicit ``X[cand]`` + pre-gather kernel vs the
    index-taking ``pairwise_sqdist_gather``.
  * ``ne_forces``: three per-mode launches on explicit ``Y[idx]`` buffers
    (HD attraction / LD repulsion / negatives) vs ONE segmented
    ``ne_forces_gather`` launch over the concatenated neighbour axis.
  * force *epilogue*: the edge-emitting launch + three XLA ``.at[].add``
    symmetrisation scatters vs the scatter-fused launch whose (N, d)
    per-segment partials make the displacement field three AXPYs.
  * neighbour *selection* epilogue: the XLA pipeline
    (``dedup_candidates``'s (N, C, K)/(N, C, C) broadcast masks +
    candidate-distance round-trip + ``merge_knn``'s top_k over (N, K+C))
    vs the merge-fused selection (the kernel's stable-rank dedup+merge as
    flat compare/select arithmetic -- no sort, no broadcast tensors).

Wall-clock here times the *XLA lowering* of both paths end-to-end (the
Pallas kernels target TPU; interpret mode is an interpreter, so its
wall-clock is meaningless).  The derived column carries the roofline
entry: modeled per-call HBM bytes on TPU, where the pre-gather path pays
write+read of the gathered operand that the gather-fused kernel never
materialises -- the actual TPU win the rewiring is after.

Run directly (``python -m benchmarks.bench_kernels --smoke --json f.json``)
this module is its own harness: unlike ``benchmarks.run`` it does NOT
swallow exceptions, so CI uses ``--smoke`` (tiny shapes) as a
kernel-launch regression gate that actually fails the workflow.
"""
import argparse
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timed
from repro.core.funcsne import FuncSNEConfig
from repro.core.knn import (counter_candidates, dedup_candidates, key_salt,
                            merge_knn, sample_direct, sample_hops,
                            sample_uniform)
from repro.kernels.knn_merge.ref import knn_merge_ref, knn_merge_rank_ref
from repro.kernels.ne_forces.ref import (ne_forces_gather_ref, ne_forces_ref,
                                         ne_forces_scatter_ref)
from repro.kernels.pairwise_sqdist.ref import (pairwise_sqdist_gather_ref,
                                               pairwise_sqdist_ref)

_DEFAULTS = FuncSNEConfig(n_points=2, dim_hd=2)   # source of C/K defaults


def _mb(x: float) -> str:
    return f"{x / 2 ** 20:.1f}MB"


def _bench_pair(fn_a, fn_b, *args, repeats, trials=7):
    """(us_a, us_b): paired, interleaved best-of-``trials`` timings.

    A and B run back-to-back within every trial so load phases of a
    shared host hit both paths equally; the per-path minimum over trials
    is the noise-robust statistic.  Unpaired timing on this class of host
    shows +-15% drift, which swamps a parity comparison.
    """
    fa, fb = jax.jit(fn_a), jax.jit(fn_b)
    jax.block_until_ready(fa(*args))               # compile
    jax.block_until_ready(fb(*args))
    best_a = best_b = float("inf")
    for t in range(trials):
        # alternate order: cache/allocator state after A's big buffers is
        # not the same as after B's, and whoever runs second inherits it
        pair = ((fa, fb) if t % 2 == 0 else (fb, fa))
        dts = {}
        for f in pair:
            _, dts[f] = timed(lambda: jax.block_until_ready(f(*args)),
                              repeats=repeats)
        best_a, best_b = min(best_a, dts[fa]), min(best_b, dts[fb])
    return best_a * 1e6, best_b * 1e6


def run(ns=(2048, 16384), m=192, repeats=10):
    """``repeats`` is the per-trial call count at the largest size; smaller
    sizes get proportionally more calls so sub-ms launches aren't swamped
    by dispatch noise on a shared host."""
    rng = np.random.default_rng(0)
    rows = []
    C = _DEFAULTS.c_hd
    k_hd, k_ld, k_neg = (_DEFAULTS.k_hd, _DEFAULTS.k_ld,
                         _DEFAULTS.n_negatives)
    d = _DEFAULTS.dim_ld
    segments = (("attraction", k_hd), ("repulsion", k_ld),
                ("repulsion", k_neg))
    K = k_hd + k_ld + k_neg

    for n in ns:
        n_reps = max(repeats, repeats * max(ns) // n)
        X = jnp.asarray(rng.normal(size=(n, m)).astype(np.float32))
        Y = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
        qid = jnp.arange(n, dtype=jnp.int32)
        cand = jnp.asarray(rng.integers(0, n, (n, C)).astype(np.int32))
        nbr = jnp.asarray(rng.integers(0, n, (n, K)).astype(np.int32))
        coef = jnp.asarray(rng.random((n, K)).astype(np.float32))

        # ---- pairwise_sqdist: pre-gather vs gather-fused
        def sq_pre(X, qid, cand):
            return pairwise_sqdist_ref(X[qid], X[jnp.clip(cand, 0, n - 1)])

        def sq_gat(X, qid, cand):
            return pairwise_sqdist_gather_ref(X, qid, cand)

        us_pre, us_gat = _bench_pair(sq_pre, sq_gat, X, qid, cand,
                                     repeats=n_reps)
        # TPU HBM model: pre-gather writes then re-reads the (N, C, M)
        # buffer; gather-fused reads each needed row exactly once
        b_rows = 4.0 * n * (C + 1) * m
        b_pre = 2.0 * 4.0 * n * C * m + b_rows
        rows.append(row(f"kbench_sqdist_pregather_n{n}", us_pre,
                        f"modeled_tpu_hbm={_mb(b_pre)}"))
        rows.append(row(f"kbench_sqdist_gather_n{n}", us_gat,
                        f"modeled_tpu_hbm={_mb(b_rows)}"))
        ratio = us_pre / max(us_gat, 1e-9)
        rows.append(row(f"kbench_sqdist_xla_ratio_n{n}", ratio,
                        f"pregather_us/gather_us={ratio:.3f} (ratio, not us)"))

        # ---- ne_forces: three pre-gather launches vs one fused launch
        # (both return the per-segment outputs the call site consumes)
        def nf_pre(Y, qid, nbr, coef):
            y = Y[qid]
            outs = []
            k0 = 0
            for mode, size in segments:
                sl = slice(k0, k0 + size)
                outs += list(ne_forces_ref(
                    y, Y[jnp.clip(nbr[:, sl], 0, n - 1)], coef[:, sl],
                    1.0, mode=mode))
                k0 += size
            return outs

        def nf_gat(Y, qid, nbr, coef):
            # emit_edges mirrors _forces_update: negatives' edges unused
            return ne_forces_gather_ref(Y, qid, nbr, coef, 1.0,
                                        segments=segments,
                                        emit_edges=(True, True, False))

        us_pre, us_gat = _bench_pair(nf_pre, nf_gat, Y, qid, nbr, coef,
                                     repeats=n_reps)
        # pre: write+read of the gathered (N, K, d) buffers plus a written
        # y_l read back by each of the three launches; fused: one direct
        # row-gather read.  (Edge/agg output writes are identical on both
        # sides and omitted.)
        b_rows = 4.0 * n * (K + 1) * d
        b_pre = 4.0 * (2.0 * n * K * d + 4.0 * n * d)
        rows.append(row(f"kbench_forces_pregather3_n{n}", us_pre,
                        f"modeled_tpu_hbm={_mb(b_pre)};launches=3"))
        rows.append(row(f"kbench_forces_fused1_n{n}", us_gat,
                        f"modeled_tpu_hbm={_mb(b_rows)};launches=1"))
        ratio = us_pre / max(us_gat, 1e-9)
        rows.append(row(f"kbench_forces_xla_ratio_n{n}", ratio,
                        f"pregather_us/fused_us={ratio:.3f} (ratio, not us)"))

        # ---- force epilogue: edge-emitting + .at[].add symmetrisation
        # scatters vs the scatter-fused (N, d)-partial launch.  Both
        # produce the final displacement buffer a step consumes; the
        # scale factors mirror _forces_update's attr_s / rep_s /
        # rep_s * scale_neg structure.
        back = (True, True, False)

        def ep_edges(Y, qid, nbr, coef):
            aggs, edges, wsums = ne_forces_gather_ref(
                Y, qid, nbr, coef, 1.0, segments=segments,
                emit_edges=(True, True, False))
            buf = jnp.zeros((n, d), jnp.float32)
            buf = buf.at[qid].add(1.5 * aggs[0] + 0.7 * (aggs[1]
                                                         + 3.0 * aggs[2]))
            k0 = 0
            for s, (_, size) in enumerate(segments):
                if back[s]:
                    tgt = nbr[:, k0:k0 + size].reshape(-1)
                    scale = 1.5 if s == 0 else 0.7
                    buf = buf.at[tgt].add(-(scale
                                            * edges[s]).reshape(-1, d))
                k0 += size
            return buf, wsums[1], wsums[2]

        def ep_scatter(Y, qid, nbr, coef):
            scats, wsums = ne_forces_scatter_ref(
                Y, qid, nbr, coef, 1.0, segments=segments,
                scatter_back=back)
            buf = 1.5 * scats[0] + 0.7 * scats[1] + (0.7 * 3.0) * scats[2]
            return buf, wsums[1], wsums[2]

        us_edge, us_scat = _bench_pair(ep_edges, ep_scatter, Y, qid, nbr,
                                       coef, repeats=n_reps)
        # TPU HBM model for the symmetrisation epilogue alone: the edge
        # path writes then scatter-reads two (N, K_s, d) edge buffers;
        # the scatter-fused path writes G <= 8 per-segment (N, d) grid
        # partials (the kernel caps the grid to bound exactly this term)
        # and reads them back once in the XLA sum.
        g_blocks = min(8, -(-n // 128))
        b_edge = 2.0 * 4.0 * n * (k_hd + k_ld) * d
        b_scat = 2.0 * 4.0 * g_blocks * n * d * len(segments)
        rows.append(row(f"kbench_epilogue_edges_n{n}", us_edge,
                        f"modeled_tpu_hbm={_mb(b_edge)};scatters=3"))
        rows.append(row(f"kbench_epilogue_scatter_n{n}", us_scat,
                        f"modeled_tpu_hbm={_mb(b_scat)};scatters=0"))
        ratio = us_edge / max(us_scat, 1e-9)
        rows.append(row(f"kbench_epilogue_xla_ratio_n{n}", ratio,
                        f"edges_us/scatter_us={ratio:.3f} (ratio, not us)"))

        # ---- neighbour selection epilogue: XLA dedup+top_k vs merge-fused.
        # Both sides score candidates identically (the gather ref); the A/B
        # isolates the *selection*: broadcast dedup masks + lax.top_k vs
        # the kernel's stable-rank compare/select (knn_merge_rank_ref is
        # that algorithm as flat XLA).  Sorted current lists mirror the
        # state invariant.
        k_sel = k_hd
        cur0 = rng.integers(0, n, (n, k_sel)).astype(np.int32)
        d0 = np.asarray(pairwise_sqdist_gather_ref(X, qid,
                                                   jnp.asarray(cur0)))
        order = np.argsort(d0, axis=1, kind="stable")
        cur_idx = jnp.asarray(np.take_along_axis(cur0, order, axis=1))
        cur_d = jnp.asarray(np.take_along_axis(d0, order, axis=1))

        def sel_topk(X, qid, cur_idx, cur_d, cand):
            valid = dedup_candidates(qid, cur_idx, cand)
            cand_d = pairwise_sqdist_gather_ref(X, qid, cand)
            return merge_knn(cur_idx, cur_d, cand, cand_d, valid)

        def sel_rank(X, qid, cur_idx, cur_d, cand):
            return knn_merge_rank_ref(X, qid, cur_idx, cur_d, cand)

        us_topk, us_rank = _bench_pair(sel_topk, sel_rank, X, qid, cur_idx,
                                       cur_d, cand, repeats=n_reps)
        # TPU HBM model for the selection epilogue alone (scoring traffic
        # is identical on both sides): the XLA path materialises the
        # (N, C, K) + (N, C, C) pred dedup broadcasts, round-trips the
        # (N, C) candidate distances, and top_k re-reads + rewrites the
        # (N, K+C) concatenation; merge-fused writes only the (N, K)
        # idx/d lists + the (N,) improved flags from VMEM.
        b_topk = (n * C * k_sel + n * C * C
                  + 2.0 * 4.0 * n * C + 2.0 * 8.0 * n * (k_sel + C))
        b_rank = 8.0 * n * k_sel + 4.0 * n
        rows.append(row(f"kbench_select_topk_n{n}", us_topk,
                        f"modeled_tpu_hbm={_mb(b_topk)};sorts=1"))
        rows.append(row(f"kbench_select_merge_n{n}", us_rank,
                        f"modeled_tpu_hbm={_mb(b_rank)};sorts=0"))
        ratio = us_topk / max(us_rank, 1e-9)
        rows.append(row(f"kbench_select_xla_ratio_n{n}", ratio,
                        f"topk_us/merge_us={ratio:.3f} (ratio, not us)"))

        # ---- candidate generation: legacy threefry sampler vs the
        # counter-hash sampler (§Perf H17).  The A side is _hd_refine's
        # legacy stack (fold/split + sample_hops' (n, s, K2) two-hop
        # gather broadcasts); the B side is the jnp reference of the
        # in-kernel generator (identical draws to the kernel, flat
        # gathers, zero threefry).
        hd_tab = jnp.asarray(rng.integers(0, n, (n, k_hd))
                             .astype(np.int32))
        ld_tab = jnp.asarray(rng.integers(0, n, (n, k_ld))
                             .astype(np.int32))
        key = jax.random.PRNGKey(0)
        sources = (("two_hop", 0, 0, _DEFAULTS.c_hd_non),
                   ("one_hop", 1, _DEFAULTS.c_hd_ld),
                   ("two_hop", 1, 1, _DEFAULTS.c_hd_ld_non),
                   ("uniform", _DEFAULTS.c_hd_rand))

        def cand_xla(key, hd_tab, ld_tab):
            r = jax.random.split(jax.random.fold_in(key, 7), 4)
            return jnp.concatenate([
                sample_hops(r[0], hd_tab, hd_tab, qid,
                            _DEFAULTS.c_hd_non),
                sample_direct(r[1], ld_tab, _DEFAULTS.c_hd_ld),
                sample_hops(r[2], ld_tab, ld_tab, qid,
                            _DEFAULTS.c_hd_ld_non),
                sample_uniform(r[3], n, n, _DEFAULTS.c_hd_rand)], axis=1)

        def cand_fused(key, hd_tab, ld_tab):
            return counter_candidates(key_salt(key), qid, sources,
                                      (hd_tab, ld_tab), (hd_tab, ld_tab),
                                      n_total=n)

        us_xla, us_fus = _bench_pair(cand_xla, cand_fused, key, hd_tab,
                                     ld_tab, repeats=n_reps)
        # TPU HBM model for the generation phase alone: the legacy path
        # materialises the two (n, s, K2) two-hop broadcasts and
        # round-trips the (n, C) candidate tensor the kernel re-reads;
        # in-kernel generation fetches one chained int32 element per
        # two-hop slot and writes nothing.
        s2 = _DEFAULTS.c_hd_non * k_hd + _DEFAULTS.c_hd_ld_non * k_ld
        b_xla = 4.0 * n * s2 + 2.0 * 4.0 * n * C
        b_fus = 4.0 * n * (_DEFAULTS.c_hd_non + _DEFAULTS.c_hd_ld_non)
        rows.append(row(f"kbench_cand_xla_n{n}", us_xla,
                        f"modeled_tpu_hbm={_mb(b_xla)};threefry=1"))
        rows.append(row(f"kbench_cand_fused_n{n}", us_fus,
                        f"modeled_tpu_hbm={_mb(b_fus)};threefry=0"))
        ratio = us_xla / max(us_fus, 1e-9)
        rows.append(row(f"kbench_cand_xla_ratio_n{n}", ratio,
                        f"xla_us/fused_us={ratio:.3f} (ratio, not us)"))
    return rows


def smoke_kernel_launches():
    """Actually launch every Pallas kernel (interpret mode, tiny shapes)
    and check it against its ref -- the ``run()`` timings above exercise
    only the XLA refs, so this is what makes ``--smoke`` a *kernel-launch*
    regression gate rather than a ref-only one.  Raises on any lowering
    or parity breakage."""
    from repro.kernels.ne_forces.kernel import (ne_forces_gather_pallas,
                                                ne_forces_scatter_pallas)
    from repro.kernels.pairwise_sqdist.kernel import \
        pairwise_sqdist_gather_pallas

    rng = np.random.default_rng(0)
    n, b, m, d = 40, 33, 16, 2
    segments = (("attraction", 4), ("repulsion", 3), ("repulsion", 2))
    k = 9
    X = jnp.asarray(rng.normal(size=(n, m)).astype(np.float32))
    Y = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    qid = jnp.asarray(rng.integers(0, n, b).astype(np.int32))
    cand = jnp.asarray(rng.integers(-1, n + 2, (b, 5)).astype(np.int32))
    nbr = jnp.asarray(rng.integers(-1, n + 2, (b, k)).astype(np.int32))
    coef = jnp.asarray(rng.random((b, k)).astype(np.float32))

    def close(a, ref, what):
        a, ref = np.asarray(a), np.asarray(ref)
        if not np.allclose(a, ref, rtol=2e-5, atol=2e-5):
            raise AssertionError(f"smoke parity failed: {what}")

    _, dt = timed(lambda: jax.block_until_ready(
        pairwise_sqdist_gather_pallas(X, qid, cand, block_b=16, block_m=8,
                                      interpret=True)))
    close(pairwise_sqdist_gather_pallas(X, qid, cand, block_b=16,
                                        block_m=8, interpret=True),
          pairwise_sqdist_gather_ref(X, qid, cand), "pairwise_sqdist_gather")
    yield row("ksmoke_launch_sqdist_gather", dt * 1e6, "interpret-mode")

    _, dt = timed(lambda: jax.block_until_ready(
        ne_forces_gather_pallas(Y, qid, nbr, coef, 1.3, segments=segments,
                                block_b=16, interpret=True)))
    got = ne_forces_gather_pallas(Y, qid, nbr, coef, 1.3, segments=segments,
                                  block_b=16, interpret=True)
    want = ne_forces_gather_ref(Y, qid, nbr, coef, 1.3, segments=segments)
    for g, w in zip(got[0] + got[2], want[0] + want[2]):
        close(g, w, "ne_forces_gather")
    yield row("ksmoke_launch_forces_gather", dt * 1e6, "interpret-mode")

    back = (True, True, False)
    _, dt = timed(lambda: jax.block_until_ready(
        ne_forces_scatter_pallas(Y, qid, nbr, coef, 1.3, segments=segments,
                                 scatter_back=back, block_b=16,
                                 interpret=True)))
    got = ne_forces_scatter_pallas(Y, qid, nbr, coef, 1.3,
                                   segments=segments, scatter_back=back,
                                   block_b=16, interpret=True)
    want = ne_forces_scatter_ref(Y, qid, nbr, coef, 1.3, segments=segments,
                                 scatter_back=back)
    for g, w in zip(got[0] + got[1], want[0] + want[1]):
        close(g, w, "ne_forces_scatter")
    yield row("ksmoke_launch_forces_scatter", dt * 1e6, "interpret-mode")

    # merge-fused selection: quarter-integer coordinates make distances
    # exact, so the parity check is discrete (indices + flags), not
    # tolerance-based
    from repro.kernels.knn_merge.kernel import knn_merge_pallas

    Xq = jnp.asarray((rng.integers(-8, 9, (n, m)) / 4.0).astype(np.float32))
    k_sel = 6
    cur0 = rng.integers(0, n, (b, k_sel)).astype(np.int32)
    d0 = np.asarray(pairwise_sqdist_gather_ref(Xq, qid, jnp.asarray(cur0)))
    order = np.argsort(d0, axis=1, kind="stable")
    cur_idx = jnp.asarray(np.take_along_axis(cur0, order, axis=1))
    cur_d = jnp.asarray(np.take_along_axis(d0, order, axis=1))
    active = jnp.ones((b, 5), bool)
    cur_valid = jnp.ones((b, k_sel), bool)

    def eq(a, ref, what):
        for g, w in zip(a, ref):
            if not np.array_equal(np.asarray(g), np.asarray(w)):
                raise AssertionError(f"smoke parity failed: {what}")

    _, dt = timed(lambda: jax.block_until_ready(
        knn_merge_pallas(Xq, qid, cur_idx, cur_d, cand, active,
                         rescore=False, block_b=16, block_m=8,
                         interpret=True)))
    eq(knn_merge_pallas(Xq, qid, cur_idx, cur_d, cand, active,
                        rescore=False, block_b=16, block_m=8,
                        interpret=True),
       knn_merge_ref(Xq, qid, cur_idx, cur_d, cand, cand_active=active),
       "knn_merge")
    yield row("ksmoke_launch_knn_merge", dt * 1e6, "interpret-mode")

    _, dt = timed(lambda: jax.block_until_ready(
        knn_merge_pallas(Xq, qid, cur_idx, cur_valid, cand, active,
                         rescore=True, block_b=16, block_m=8,
                         interpret=True)))
    eq(knn_merge_pallas(Xq, qid, cur_idx, cur_valid, cand, active,
                        rescore=True, block_b=16, block_m=8,
                        interpret=True),
       knn_merge_ref(Xq, qid, cur_idx, None, cand, cand_active=active,
                     cur_valid=cur_valid),
       "knn_merge_rescore")
    yield row("ksmoke_launch_knn_merge_rescore", dt * 1e6, "interpret-mode")

    # candidate-fused generation (§Perf H17): the kernel derives the
    # candidates it scores (counter hash + chained two-hop element DMAs
    # through the second-table channel); parity vs the jnp reference
    # sampler is discrete-exact on the quantised coordinates
    from repro.kernels.knn_merge.kernel import knn_merge_cand_pallas
    from repro.kernels.knn_merge.ref import knn_merge_cand_ref

    oth = jnp.asarray(rng.integers(0, n, (b, 4)).astype(np.int32))
    sec = jnp.asarray(rng.integers(0, n, (n, 5)).astype(np.int32))
    act_rows = jnp.asarray(rng.random(n) >= 0.1)
    salt = jnp.int32(5)
    sources = (("two_hop", 0, 0, 2), ("one_hop", 1, 1), ("uniform", 2))

    def launch_cand(rescore):
        cw = cur_valid if rescore else cur_d
        return knn_merge_cand_pallas(
            Xq, qid, cur_idx, cw, salt, (cur_idx, oth), (sec,), None,
            act_rows, sources=sources, rescore=rescore, block_b=16,
            block_m=8, interpret=True)

    for rescore, tag in ((False, "cand_fused"), (True,
                                                 "cand_fused_rescore")):
        _, dt = timed(lambda: jax.block_until_ready(launch_cand(rescore)))
        eq(launch_cand(rescore),
           knn_merge_cand_ref(Xq, qid, cur_idx,
                              None if rescore else cur_d, salt=salt,
                              sources=sources,
                              first_tables=(cur_idx, oth),
                              second_tables=(sec,), active=act_rows,
                              cur_valid=cur_valid if rescore else None),
           tag)
        yield row(f"ksmoke_launch_{tag}", dt * 1e6, "interpret-mode")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes + interpret-mode Pallas launches: "
                         "CI kernel-launch regression gate")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write {name: us_per_call} JSON to PATH")
    args = ap.parse_args()
    kwargs = dict(ns=(256,), m=32, repeats=2) if args.smoke else {}
    results = {}
    print("name,us_per_call,derived")
    rows = run(**kwargs)
    if args.smoke:
        rows += list(smoke_kernel_launches())
    for r in rows:
        print(r, flush=True)
        name, us = str(r).split(",")[:2]
        results[name] = float(us)
    if args.json:
        out = Path(args.json)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(json.dumps(results, indent=2, sort_keys=True) + "\n")
        print(f"# wrote {len(results)} results to {args.json}", flush=True)


if __name__ == "__main__":
    main()

"""Microbenchmark: pre-gather vs gather-fused kernel data paths.

Two comparisons, at N in {2k, 16k} with C/K at FuncSNEConfig defaults:

  * ``pairwise_sqdist``: explicit ``X[cand]`` + pre-gather kernel vs the
    index-taking ``pairwise_sqdist_gather``.
  * ``ne_forces``: three per-mode launches on explicit ``Y[idx]`` buffers
    (HD attraction / LD repulsion / negatives) vs ONE segmented
    ``ne_forces_gather`` launch over the concatenated neighbour axis.

Wall-clock here times the *XLA lowering* of both paths end-to-end (the
Pallas kernels target TPU; interpret mode is an interpreter, so its
wall-clock is meaningless).  The derived column carries the roofline
entry: modeled per-call HBM bytes on TPU, where the pre-gather path pays
write+read of the gathered operand that the gather-fused kernel never
materialises -- the actual TPU win the rewiring is after.
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timed
from repro.core.funcsne import FuncSNEConfig
from repro.kernels.ne_forces.ref import ne_forces_gather_ref, ne_forces_ref
from repro.kernels.pairwise_sqdist.ref import (pairwise_sqdist_gather_ref,
                                               pairwise_sqdist_ref)

_DEFAULTS = FuncSNEConfig(n_points=2, dim_hd=2)   # source of C/K defaults


def _mb(x: float) -> str:
    return f"{x / 2 ** 20:.1f}MB"


def _bench_pair(fn_a, fn_b, *args, repeats, trials=7):
    """(us_a, us_b): paired, interleaved best-of-``trials`` timings.

    A and B run back-to-back within every trial so load phases of a
    shared host hit both paths equally; the per-path minimum over trials
    is the noise-robust statistic.  Unpaired timing on this class of host
    shows +-15% drift, which swamps a parity comparison.
    """
    fa, fb = jax.jit(fn_a), jax.jit(fn_b)
    jax.block_until_ready(fa(*args))               # compile
    jax.block_until_ready(fb(*args))
    best_a = best_b = float("inf")
    for t in range(trials):
        # alternate order: cache/allocator state after A's big buffers is
        # not the same as after B's, and whoever runs second inherits it
        pair = ((fa, fb) if t % 2 == 0 else (fb, fa))
        dts = {}
        for f in pair:
            _, dts[f] = timed(lambda: jax.block_until_ready(f(*args)),
                              repeats=repeats)
        best_a, best_b = min(best_a, dts[fa]), min(best_b, dts[fb])
    return best_a * 1e6, best_b * 1e6


def run(ns=(2048, 16384), m=192, repeats=10):
    """``repeats`` is the per-trial call count at the largest size; smaller
    sizes get proportionally more calls so sub-ms launches aren't swamped
    by dispatch noise on a shared host."""
    rng = np.random.default_rng(0)
    rows = []
    C = _DEFAULTS.c_hd
    k_hd, k_ld, k_neg = (_DEFAULTS.k_hd, _DEFAULTS.k_ld,
                         _DEFAULTS.n_negatives)
    d = _DEFAULTS.dim_ld
    segments = (("attraction", k_hd), ("repulsion", k_ld),
                ("repulsion", k_neg))
    K = k_hd + k_ld + k_neg

    for n in ns:
        n_reps = max(repeats, repeats * max(ns) // n)
        X = jnp.asarray(rng.normal(size=(n, m)).astype(np.float32))
        Y = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
        qid = jnp.arange(n, dtype=jnp.int32)
        cand = jnp.asarray(rng.integers(0, n, (n, C)).astype(np.int32))
        nbr = jnp.asarray(rng.integers(0, n, (n, K)).astype(np.int32))
        coef = jnp.asarray(rng.random((n, K)).astype(np.float32))

        # ---- pairwise_sqdist: pre-gather vs gather-fused
        def sq_pre(X, qid, cand):
            return pairwise_sqdist_ref(X[qid], X[jnp.clip(cand, 0, n - 1)])

        def sq_gat(X, qid, cand):
            return pairwise_sqdist_gather_ref(X, qid, cand)

        us_pre, us_gat = _bench_pair(sq_pre, sq_gat, X, qid, cand,
                                     repeats=n_reps)
        # TPU HBM model: pre-gather writes then re-reads the (N, C, M)
        # buffer; gather-fused reads each needed row exactly once
        b_rows = 4.0 * n * (C + 1) * m
        b_pre = 2.0 * 4.0 * n * C * m + b_rows
        rows.append(row(f"kbench_sqdist_pregather_n{n}", us_pre,
                        f"modeled_tpu_hbm={_mb(b_pre)}"))
        rows.append(row(f"kbench_sqdist_gather_n{n}", us_gat,
                        f"modeled_tpu_hbm={_mb(b_rows)}"))
        ratio = us_pre / max(us_gat, 1e-9)
        rows.append(row(f"kbench_sqdist_xla_ratio_n{n}", ratio,
                        f"pregather_us/gather_us={ratio:.3f} (ratio, not us)"))

        # ---- ne_forces: three pre-gather launches vs one fused launch
        # (both return the per-segment outputs the call site consumes)
        def nf_pre(Y, qid, nbr, coef):
            y = Y[qid]
            outs = []
            k0 = 0
            for mode, size in segments:
                sl = slice(k0, k0 + size)
                outs += list(ne_forces_ref(
                    y, Y[jnp.clip(nbr[:, sl], 0, n - 1)], coef[:, sl],
                    1.0, mode=mode))
                k0 += size
            return outs

        def nf_gat(Y, qid, nbr, coef):
            # emit_edges mirrors _forces_update: negatives' edges unused
            return ne_forces_gather_ref(Y, qid, nbr, coef, 1.0,
                                        segments=segments,
                                        emit_edges=(True, True, False))

        us_pre, us_gat = _bench_pair(nf_pre, nf_gat, Y, qid, nbr, coef,
                                     repeats=n_reps)
        # pre: write+read of the gathered (N, K, d) buffers plus a written
        # y_l read back by each of the three launches; fused: one direct
        # row-gather read.  (Edge/agg output writes are identical on both
        # sides and omitted.)
        b_rows = 4.0 * n * (K + 1) * d
        b_pre = 4.0 * (2.0 * n * K * d + 4.0 * n * d)
        rows.append(row(f"kbench_forces_pregather3_n{n}", us_pre,
                        f"modeled_tpu_hbm={_mb(b_pre)};launches=3"))
        rows.append(row(f"kbench_forces_fused1_n{n}", us_gat,
                        f"modeled_tpu_hbm={_mb(b_rows)};launches=1"))
        ratio = us_pre / max(us_gat, 1e-9)
        rows.append(row(f"kbench_forces_xla_ratio_n{n}", ratio,
                        f"pregather_us/fused_us={ratio:.3f} (ratio, not us)"))
    return rows

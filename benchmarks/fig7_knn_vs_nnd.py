"""Paper Fig. 7: the joint iterative KNN vs nearest-neighbour descent on
'Overlapping' and 'Disjointed' blob regimes.  The paper's claim: NND's
greedy local join traps in local minima on isolated clusters; FUnc-SNE's
random probes + cross-space candidates escape them.
"""
import jax
import jax.numpy as jnp

from benchmarks.common import row, timed
from repro.core import funcsne
from repro.core.nnd import NNDConfig, nnd
from repro.core.quality import knn_set_quality
from repro.data.synthetic import blobs, disjoint_blobs


def run(n=1500, iters=400):
    rows = []
    data = {
        "overlapping": blobs(n=n, dim=32, n_centers=5, center_std=1.0,
                             blob_std=1.0, seed=0)[0],
        "disjointed": disjoint_blobs(n=n, dim=32, n_centers=n // 30,
                                     seed=0)[0],
    }
    for name, X in data.items():
        Xj = jnp.asarray(X)
        m = X.shape[0]
        (idx, d, hist), dt = timed(lambda: nnd(X, NNDConfig(k=16),
                                               max_iter=30))
        rows.append(row(f"fig7_{name}_nnd", dt * 1e6 / max(len(hist), 1),
                        f"auc={float(knn_set_quality(idx, Xj)):.3f};"
                        f"iters={len(hist)}"))
        cfg = funcsne.FuncSNEConfig(n_points=m, dim_hd=32, k_hd=16)
        hp = funcsne.default_hparams(m, perplexity=10.0)

        def run_funcsne():
            st = funcsne.init_state(jax.random.PRNGKey(0), Xj, cfg)
            step = funcsne.make_step(cfg)
            for _ in range(iters):
                st = step(st, Xj, hp)
            return st

        st, dt2 = timed(run_funcsne)
        rows.append(row(f"fig7_{name}_funcsne", dt2 * 1e6 / iters,
                        f"auc={float(knn_set_quality(st.hd_idx, Xj)):.3f};"
                        f"iters={iters}"))
    return rows

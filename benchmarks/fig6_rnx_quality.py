"""Paper Fig. 6: multi-scale R_NX(K) curves, FUnc-SNE vs the
negative-sampling-only (UMAP-regime) baseline vs exact variable-tail t-SNE
(quality oracle standing in for FIt-SNE at this N), on 3 datasets:
transcriptomics stand-in ('cells'), Gaussian blobs, COIL-style rings.
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timed
from repro.core import baselines, funcsne
from repro.core.quality import embedding_rnx_curve, rnx_auc
from repro.data.synthetic import blobs, coil_rings, hierarchical_cells


def _datasets(n):
    yield "cells", hierarchical_cells(n=n, dim=24, seed=0)[0]
    yield "blobs", blobs(n=n, dim=32, n_centers=5, center_std=6.0, seed=0)[0]
    yield "coil", coil_rings(n_objects=max(6, n // 72), n_per_object=72,
                             dim=24, seed=0)[0]


def run(n=1100, iters=500):
    rows = []
    for name, X in _datasets(n):
        Xj = jnp.asarray(X)
        m = X.shape[0]
        hp = funcsne.default_hparams(m, perplexity=10.0)
        st, dt_ours = timed(lambda: funcsne.fit(X, n_iter=iters,
                                                hparams=hp)[0])
        Yn, dt_ns = timed(lambda: baselines.negative_sampling_embed(
            X, n_iter=iters, hparams=hp))
        Yt, dt_ex = timed(lambda: baselines.exact_tsne(X, n_iter=min(iters,
                                                                     350),
                                                       perplexity=10.0))
        for meth, Y, dt in (("funcsne", st.Y, dt_ours), ("ns_only", Yn,
                                                         dt_ns),
                            ("exact", Yt, dt_ex)):
            c = np.asarray(embedding_rnx_curve(Xj, jnp.asarray(Y),
                                               kmax=m // 2))
            ks = [9, 49, m // 4 - 1, m // 2 - 1]
            derived = (f"auc={float(rnx_auc(jnp.asarray(c))):.3f};"
                       + ";".join(f"K{k+1}={c[k]:.3f}" for k in ks))
            rows.append(row(f"fig6_{name}_{meth}", dt * 1e6 / iters,
                            derived))
    return rows

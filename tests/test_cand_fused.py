"""Candidate-fused sampling (§Perf H17): counter RNG, kernel parity, HLO.

Contract layers:

  * RNG statistics -- chi-square uniformity of the counter hash (it is a
    deterministic function, so the test is exactly reproducible) and a
    distribution match of the fused two-hop sampler against the legacy
    ``jax.random`` ``sample_hops`` (same tables, aggregated marginals).
  * kernel vs oracle -- the candidate-generating Pallas kernel
    (interpret mode) must reproduce the pure-jnp counter sampler
    (``knn_lib.counter_candidates``) feeding the legacy selection
    pipeline EXACTLY on discrete outputs: quantised coordinates make
    every distance representable, so generation, chained two-hop DMAs,
    per-candidate active DMAs, dedup and merge are all pinned bitwise.
  * step level -- a 50-step trajectory with in-kernel generation is
    bit-equal to the same 50 steps where the jnp reference sampler
    generates the candidates and feeds them to the operand-taking merge
    kernel (the acceptance anchor); on the 'xla' backend the
    ``merge_fused`` flag stays bit-neutral within ``cand_fused=True``.
  * HLO -- with ``cand_fused=True`` the compiled step contains NO
    threefry ops and NO (n, s, K2) two-hop gather broadcast; the legacy
    flag is the positive control for both detectors.
  * satellites -- cached reverse-edge table (legacy fill protocol
    bit-parity at ``rev_refresh=1``, cache-corruption invariance,
    cadence negative control, the ``nnd`` driver's parity) and the
    ``fit(auto_rescale=)`` ChunkMetrics consumer.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import funcsne
from repro.core import knn as knn_lib
from repro.core.knn import SENTINEL
from repro.data.synthetic import blobs
from repro.kernels.knn_merge import ops as knn_merge_ops
from repro.kernels.knn_merge.kernel import knn_merge_cand_pallas
from repro.kernels.knn_merge.ops import knn_merge
from repro.kernels.knn_merge.ref import knn_merge_cand_ref


# --------------------------------------------------------------------------
# Counter-RNG statistics


def test_counter_randint_chi_square_uniform():
    """40k draws into 64 bins: chi-square must sit below the p=0.001
    critical value (103.4 at df=63).  Deterministic -- no flaky seeds."""
    n_rows, n_draws, bins = 200, 200, 64
    rows = jnp.arange(n_rows, dtype=jnp.int32)[:, None]
    draws = jnp.arange(n_draws, dtype=jnp.int32)[None, :]
    for salt in (0, 1, 12345, -77):
        v = np.asarray(knn_lib.counter_randint(jnp.int32(salt), rows,
                                               draws, bins)).ravel()
        counts = np.bincount(v, minlength=bins)
        expect = v.size / bins
        chi2 = float(((counts - expect) ** 2 / expect).sum())
        assert chi2 < 103.4, (salt, chi2)


def test_counter_uniform01_range_and_mean():
    h = knn_lib.hash3(jnp.int32(7), jnp.arange(50000, dtype=jnp.int32), 0)
    u = np.asarray(knn_lib.counter_uniform01(h))
    assert (u >= 0.0).all() and (u < 1.0).all()
    assert abs(u.mean() - 0.5) < 0.01
    assert abs(u.var() - 1.0 / 12.0) < 0.01


def test_counter_stream_shard_invariance():
    """Draws are keyed on global row ids: sampling rows [0, n) in one
    block equals sampling any row slice separately (the property that
    lets the distributed path drop the per-shard fold_in)."""
    sources = (("uniform", 3),)
    salt = jnp.int32(42)
    rows = jnp.arange(64, dtype=jnp.int32)
    full = knn_lib.counter_candidates(salt, rows, sources, n_total=101)
    lo = knn_lib.counter_candidates(salt, rows[:32], sources, n_total=101)
    hi = knn_lib.counter_candidates(salt, rows[32:], sources, n_total=101)
    np.testing.assert_array_equal(np.asarray(full),
                                  np.vstack([np.asarray(lo),
                                             np.asarray(hi)]))


def test_two_hop_marginal_matches_legacy_sampler():
    """The fused two-hop source must draw from the same distribution as
    ``sample_hops`` (uniform a, SENTINEL fallback, uniform b): aggregate
    marginals over many trials agree within a small TV distance."""
    rng = np.random.default_rng(0)
    n, k1, k2, s, trials = 50, 6, 5, 4, 300
    first = rng.integers(0, n, (n, k1)).astype(np.int32)
    first[rng.random((n, k1)) < 0.2] = SENTINEL
    second = jnp.asarray(rng.integers(0, n, (n, k2)).astype(np.int32))
    first = jnp.asarray(first)
    rows = jnp.arange(n, dtype=jnp.int32)

    legacy = []
    for t in range(trials):
        key = jax.random.fold_in(jax.random.PRNGKey(9), t)
        legacy.append(np.asarray(
            knn_lib.sample_hops(key, first, second, rows, s)))
    fused = []
    for t in range(trials):
        fused.append(np.asarray(knn_lib.counter_candidates(
            jnp.int32(t), rows, (("two_hop", 0, 0, s),), (first,),
            (second,))))
    h_leg = np.bincount(np.concatenate(legacy).ravel(), minlength=n)
    h_fus = np.bincount(np.concatenate(fused).ravel(), minlength=n)
    tv = 0.5 * np.abs(h_leg / h_leg.sum() - h_fus / h_fus.sum()).sum()
    assert tv < 0.03, tv


# --------------------------------------------------------------------------
# Kernel vs jnp reference sampler: discrete-exact parity


def _problem(n, m, b, k, seed, *, k_oth=5, k2a=6, k2b=4):
    rng = np.random.default_rng(seed)
    x = jnp.asarray((rng.integers(-8, 9, (n, m)) / 4.0).astype(np.float32))
    qid = jnp.asarray(rng.integers(0, n, b).astype(np.int32))
    cur_idx = rng.integers(0, n, (b, k)).astype(np.int32)
    sent = np.sort(rng.random((b, k)) < 0.2, axis=1)
    cur_idx[sent] = SENTINEL
    d0 = np.array(jnp.sum((x[jnp.clip(jnp.asarray(cur_idx), 0, n - 1)]
                           - x[qid][:, None, :]) ** 2, axis=-1))
    d0[sent] = np.inf
    order = np.argsort(d0, axis=1, kind="stable")
    cur_idx = jnp.asarray(np.take_along_axis(cur_idx, order, axis=1))
    cur_d = jnp.asarray(np.take_along_axis(d0, order, axis=1))
    oth = rng.integers(0, n, (b, k_oth)).astype(np.int32)
    oth[rng.random((b, k_oth)) < 0.15] = SENTINEL
    sec_a = rng.integers(0, n, (n, k2a)).astype(np.int32)
    sec_a[rng.random((n, k2a)) < 0.1] = SENTINEL
    sec_b = rng.integers(0, n, (n, k2b)).astype(np.int32)
    active = jnp.asarray(rng.random(n) >= 0.15)
    extra = jnp.asarray(rng.integers(-2, n + 3, (b, 2)).astype(np.int32))
    cur_valid = jnp.asarray((np.asarray(cur_idx) != SENTINEL)
                            & (rng.random((b, k)) < 0.9))
    return (x, qid, cur_idx, cur_d, jnp.asarray(oth), jnp.asarray(sec_a),
            jnp.asarray(sec_b), active, extra, cur_valid)


def _assert_cand_parity(n, m, b, k, seed, *, rescore, use_active,
                        use_extra, **pallas_kw):
    (x, qid, cur_idx, cur_d, oth, sec_a, sec_b, active, extra,
     cur_valid) = _problem(n, m, b, k, seed)
    sources = (("two_hop", 0, 0, 3), ("one_hop", 1, 2),
               ("two_hop", 1, 1, 2), ("uniform", 2)) \
        + ((("extra", 2),) if use_extra else ())
    salt = jnp.int32(seed * 7 + 3)
    kw = dict(salt=salt, sources=sources, first_tables=(cur_idx, oth),
              second_tables=(sec_a, sec_b),
              extra=extra if use_extra else None,
              active=active if use_active else None)
    cd, cv = (None, cur_valid) if rescore else (cur_d, None)
    want = knn_merge_cand_ref(x, qid, cur_idx, cd, cur_valid=cv, **kw)
    want_rank = knn_merge_cand_ref(x, qid, cur_idx, cd, cur_valid=cv,
                                   rank=True, **kw)
    got = knn_merge_cand_pallas(
        x, qid, cur_idx, cv if rescore else cur_d, salt, (cur_idx, oth),
        (sec_a, sec_b), extra if use_extra else None,
        active if use_active else None, sources=sources, rescore=rescore,
        interpret=True, **pallas_kw)
    for g, w, name in zip(want_rank, want, ("idx", "d", "improved")):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w),
                                      err_msg=f"rank:{name}")
    for g, w, name in zip(got, want, ("idx", "d", "improved")):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w),
                                      err_msg=f"kernel:{name}")


@pytest.mark.parametrize("n,m,b,k,bb,bm", [
    (50, 19, 37, 6, 16, 8),     # everything ragged; 3 ragged M chunks
    (64, 128, 64, 8, 32, 128),  # exact tiling, unpadded B
    (40, 300, 33, 4, 8, 128),   # padded B + clamped+masked final M chunk
    (30, 2, 30, 8, 16, 512),    # tiny M (the LD-space case)
])
@pytest.mark.parametrize("rescore", [False, True])
def test_cand_kernel_vs_ref_sweep(n, m, b, k, bb, bm, rescore):
    """In-kernel generation (hash draws, chained two-hop element DMAs,
    active DMAs) == jnp counter sampler + legacy selection, exactly."""
    _assert_cand_parity(n, m, b, k, seed=n + m + k, rescore=rescore,
                        use_active=True, use_extra=True, block_b=bb,
                        block_m=bm)


@pytest.mark.parametrize("use_active,use_extra", [
    (False, False), (True, False), (False, True),
])
def test_cand_kernel_optional_channels(use_active, use_extra):
    """The active-DMA channel and the extra (cached reverse-edge) slab
    are independently optional."""
    _assert_cand_parity(45, 33, 29, 5, seed=11, rescore=False,
                        use_active=use_active, use_extra=use_extra,
                        block_b=16, block_m=16)


@pytest.mark.parametrize("sub_b,persistent_q", [
    (8, False), (8, True), (16, None), (None, True),
])
def test_cand_kernel_pipeline_variants(sub_b, persistent_q):
    """Double-buffering and the persistent-q slab stay pure scheduling
    for the candidate-generating kernel too."""
    _assert_cand_parity(45, 300, 37, 5, seed=17, rescore=False,
                        use_active=True, use_extra=True, block_b=16,
                        block_m=64, sub_b=sub_b, persistent_q=persistent_q)


def test_cand_ops_dispatch():
    """ops.knn_merge in candidate-fused mode: 'xla' is the jnp-sampler
    oracle, 'interpret' runs the generating kernel, both agree; explicit
    ``cand_active`` is rejected (activity is derived in-op)."""
    (x, qid, cur_idx, cur_d, oth, sec_a, sec_b, active, extra,
     cur_valid) = _problem(40, 7, 23, 5, seed=5)
    sources = (("two_hop", 0, 0, 2), ("uniform", 2), ("extra", 2))
    kw = dict(sources=sources, salt=jnp.int32(3),
              first_tables=(cur_idx,), second_tables=(sec_a,),
              active=active)
    want = knn_merge(x, qid, cur_idx, cur_d, extra, backend="xla", **kw)
    got = knn_merge(x, qid, cur_idx, cur_d, extra, backend="interpret",
                    **kw)
    for g, w, name in zip(got, want, ("idx", "d", "improved")):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w),
                                      err_msg=name)
    with pytest.raises(AssertionError):
        knn_merge(x, qid, cur_idx, cur_d, extra, backend="xla",
                  cand_active=jnp.ones((23, 2), bool), **kw)


# --------------------------------------------------------------------------
# Step level


def _run_steps(cfg, st, Xj, hp, n_steps):
    step = jax.jit(lambda s, x, h: funcsne.funcsne_step(cfg, s, x, h))
    for _ in range(n_steps):
        st = step(st, Xj, hp)
    return st


def _assert_states_equal(a, b, skip=()):
    for name in funcsne.FuncSNEState._fields:
        if name in skip:
            continue
        np.testing.assert_array_equal(np.asarray(getattr(a, name)),
                                      np.asarray(getattr(b, name)),
                                      err_msg=name)


@pytest.mark.slow
def test_cand_fused_step_bit_equal_to_jnp_sampler_feed(monkeypatch):
    """Acceptance: a 50-step trajectory with candidates generated
    *inside* the kernel is bit-equal to the jnp reference sampler
    generating them and feeding the operand-taking merge kernel (same
    interpret backend, so scoring arithmetic is identical and the only
    varying piece is the generation)."""
    X, _ = blobs(n=64, dim=8, n_centers=3, center_std=5.0, seed=1)
    Xj = jnp.asarray(X)
    cfg = funcsne.FuncSNEConfig(n_points=64, dim_hd=8, k_hd=6, k_ld=4,
                                c_hd_non=2, c_hd_ld=1, c_hd_ld_non=1,
                                c_hd_rand=1, c_ld_non=2, c_ld_hd=1,
                                c_ld_rand=1, n_negatives=4,
                                backend="interpret")
    st0 = funcsne.init_state(jax.random.PRNGKey(0), Xj, cfg)
    hp = funcsne.default_hparams(64)

    st_kernel = _run_steps(cfg, st0, Xj, hp, 50)

    real = knn_merge_ops.knn_merge

    def feed(x, qid, cur_idx, cur_d, cand=None, *, cand_active=None,
             cur_valid=None, backend="auto", sources=None, salt=None,
             first_tables=(), second_tables=(), active=None):
        if sources is None:
            return real(x, qid, cur_idx, cur_d, cand,
                        cand_active=cand_active, cur_valid=cur_valid,
                        backend=backend)
        gen = knn_lib.counter_candidates(
            salt, qid, tuple(s for s in sources if s[-1] > 0),
            first_tables, second_tables, n_total=x.shape[0], extra=cand)
        act = None
        if active is not None:
            act = active[jnp.clip(gen, 0, active.shape[0] - 1)]
        return real(x, qid, cur_idx, cur_d, gen, cand_active=act,
                    cur_valid=cur_valid, backend=backend)

    monkeypatch.setattr(funcsne, "knn_merge", feed)
    st_feed = _run_steps(cfg, st0, Xj, hp, 50)
    _assert_states_equal(st_kernel, st_feed)


def test_cand_fused_merge_flag_bit_neutral_on_xla():
    """Within cand_fused=True the merge_fused anchor survives: on the
    'xla' backend both settings run the jnp sampler + legacy selection,
    so 50 steps are bit-identical."""
    X, _ = blobs(n=257, dim=13, n_centers=4, center_std=5.0, seed=0)
    Xj = jnp.asarray(X)
    cfg_m = funcsne.FuncSNEConfig(n_points=257, dim_hd=13, backend="xla",
                                  c_hd_rev=2, merge_fused=True)
    cfg_l = dataclasses.replace(cfg_m, merge_fused=False)
    st0 = funcsne.init_state(jax.random.PRNGKey(0), Xj, cfg_m)
    hp = funcsne.default_hparams(257)
    st_m = _run_steps(cfg_m, st0, Xj, hp, 50)
    st_l = _run_steps(cfg_l, st0, Xj, hp, 50)
    _assert_states_equal(st_m, st_l)


# --------------------------------------------------------------------------
# HLO: threefry and the two-hop broadcast are structurally gone


def _step_hlo_text(cfg, n):
    X = jnp.asarray(np.random.default_rng(0)
                    .normal(size=(n, cfg.dim_hd)).astype(np.float32))
    st_ = funcsne.init_state(jax.random.PRNGKey(0), X, cfg)
    hp = funcsne.default_hparams(n)
    step = jax.jit(lambda s, x, h: funcsne.funcsne_step(cfg, s, x, h))
    return step.lower(st_, X, hp).compile().as_text()


def _twohop_broadcast_shapes(text, cfg, n):
    from repro.launch.hlo_analysis import module_array_shapes
    tails = {(cfg.c_hd_non, cfg.k_hd), (cfg.c_hd_ld_non, cfg.k_ld),
             (cfg.c_ld_non, cfg.k_ld)}
    return [dims for dtype, dims in module_array_shapes(text)
            if dtype == "s32" and len(dims) == 3
            and tuple(dims[1:]) in tails and dims[0] >= n]


@pytest.mark.parametrize("backend", ["interpret", "xla"])
def test_cand_fused_step_hlo_no_threefry_no_twohop_broadcast(backend):
    """Acceptance: with cfg.cand_fused=True the compiled step contains
    no threefry/random-bits ops anywhere (gate, candidates, negatives
    all run on the counter RNG) and no (n, s, K2) two-hop gather
    broadcast (in-kernel chains / flat gathers).  The legacy flag is the
    positive control for both detectors."""
    n = 257
    kw = dict(n_points=n, dim_hd=7, backend=backend)
    cfg_f = funcsne.FuncSNEConfig(cand_fused=True, **kw)
    text_f = _step_hlo_text(cfg_f, n)
    low = text_f.lower()
    assert low.count("threefry") == 0, "threefry back in the fused step"
    assert "rng-bit-generator" not in low
    assert _twohop_broadcast_shapes(text_f, cfg_f, n) == [], \
        "(n, s, K2) two-hop broadcast back in the fused step"

    cfg_l = funcsne.FuncSNEConfig(cand_fused=False, **kw)
    text_l = _step_hlo_text(cfg_l, n)
    assert text_l.lower().count("threefry") > 0, \
        "detector is blind: legacy path shows no threefry"
    assert _twohop_broadcast_shapes(text_l, cfg_l, n), \
        "detector is blind: legacy path shows no two-hop broadcast"


def test_cand_fused_chunked_hlo_no_threefry():
    """The scan-chunked driver compounds the win (T random phases per
    dispatch): the whole chunk module must be threefry-free too."""
    n = 96
    cfg = funcsne.FuncSNEConfig(n_points=n, dim_hd=5, backend="interpret")
    X = jnp.asarray(np.random.default_rng(1)
                    .normal(size=(n, 5)).astype(np.float32))
    st_ = funcsne.init_state(jax.random.PRNGKey(0), X, cfg)
    hp = funcsne.default_hparams(n)
    chunk = funcsne.make_chunked_step(cfg, 4)
    text = chunk.lower(st_, X, hp).compile().as_text()
    assert text.lower().count("threefry") == 0


# --------------------------------------------------------------------------
# Satellite: cached reverse-edge table


def test_rev_cache_matches_legacy_fill_protocol():
    """rev_refresh=1 on the legacy sampler reproduces the pre-cache
    semantics bit-for-bit: after a step whose refinement ran, the cached
    table equals a fresh ``reverse_neighbors`` built with exactly the
    r[4] key the inline rebuild used."""
    n = 128
    X, _ = blobs(n=n, dim=9, n_centers=3, seed=3)
    Xj = jnp.asarray(X)
    cfg = funcsne.FuncSNEConfig(n_points=n, dim_hd=9, c_hd_rev=3,
                                rev_refresh=1, cand_fused=False,
                                backend="xla", min_refresh_prob=1.0)
    st0 = funcsne.init_state(jax.random.PRNGKey(0), Xj, cfg)
    hp = funcsne.default_hparams(n)
    hd_before = jnp.array(st0.hd_idx, copy=True)
    rng0 = st0.rng
    st1 = _run_steps(cfg, st0, Xj, hp, 1)
    r_hd = jax.random.split(jax.random.fold_in(rng0, 0), 4)[1]
    fill_key = jax.random.split(r_hd, 5)[4]
    want = knn_lib.reverse_neighbors(hd_before, n, 3, fill_rng=fill_key)
    np.testing.assert_array_equal(np.asarray(st1.rev_idx),
                                  np.asarray(want))


@pytest.mark.parametrize("cand_fused", [False, True])
def test_rev_cache_never_read_at_refresh_1(cand_fused):
    """At rev_refresh=1 the cache is rebuilt before every use, so
    corrupting it between steps must not change the trajectory -- the
    bit-parity argument that refresh=1 IS the legacy per-refinement
    rebuild."""
    n = 96
    X, _ = blobs(n=n, dim=8, n_centers=3, seed=4)
    Xj = jnp.asarray(X)
    cfg = funcsne.FuncSNEConfig(n_points=n, dim_hd=8, c_hd_rev=2,
                                rev_refresh=1, cand_fused=cand_fused,
                                backend="xla")
    st_a = funcsne.init_state(jax.random.PRNGKey(1), Xj, cfg)
    st_b = jax.tree.map(lambda x: jnp.array(x, copy=True), st_a)
    hp = funcsne.default_hparams(n)
    step = jax.jit(lambda s, x, h: funcsne.funcsne_step(cfg, s, x, h))
    garbage = jnp.full((n, 2), 17, jnp.int32)
    for _ in range(10):
        st_a = step(st_a, Xj, hp)
        st_b = step(st_b._replace(rev_idx=garbage), Xj, hp)
    _assert_states_equal(st_a, st_b, skip=("rev_idx",))


def test_rev_cache_cadence_is_since_last_refresh():
    """Refinement runs behind a stochastic gate, so the cadence counts
    steps since the last *actual* refresh: a refinement at step
    rev_step + k refreshes iff k >= rev_refresh, regardless of absolute
    step alignment (an absolute step % k schedule would lose every
    refresh whose step the gate happened to skip)."""
    n = 64
    X, _ = blobs(n=n, dim=6, n_centers=2, seed=8)
    Xj = jnp.asarray(X)
    cfg = funcsne.FuncSNEConfig(n_points=n, dim_hd=6, c_hd_rev=2,
                                rev_refresh=3, backend="xla")
    st = funcsne.init_state(jax.random.PRNGKey(0), Xj, cfg)
    salt = jnp.int32(11)
    marker = jnp.full((n, 2), 23, jnp.int32)

    # 2 steps after the last refresh: the gate fired but the cache is
    # young -- no rebuild, the (marked) table is served as-is
    st_young = st._replace(step=jnp.int32(2), rev_step=jnp.int32(0),
                           rev_idx=marker)
    out = funcsne._hd_refine(cfg, st_young, Xj, salt, funcsne.AxisCtx())
    assert int(out.rev_step) == 0
    np.testing.assert_array_equal(np.asarray(out.rev_idx),
                                  np.asarray(marker))

    # 5 steps after (the step-3 refresh fell on a gate-skipped step):
    # the refresh is NOT lost -- it fires now and restamps rev_step
    st_stale = st._replace(step=jnp.int32(5), rev_step=jnp.int32(0),
                           rev_idx=marker)
    out = funcsne._hd_refine(cfg, st_stale, Xj, salt, funcsne.AxisCtx())
    assert int(out.rev_step) == 5
    assert not np.array_equal(np.asarray(out.rev_idx), np.asarray(marker))


def test_cand_kernel_accepts_zero_width_sources():
    """The grammar allows c == 0 entries; the kernel entry point must
    drop them instead of tripping over the static slot plan."""
    (x, qid, cur_idx, cur_d, oth, sec_a, _, _, _, _) = _problem(
        40, 7, 23, 5, seed=13)
    sources = (("two_hop", 0, 0, 0), ("uniform", 2), ("extra", 0))
    salt = jnp.int32(1)
    want = knn_merge_cand_ref(x, qid, cur_idx, cur_d, salt=salt,
                              sources=sources, first_tables=(cur_idx,),
                              second_tables=(sec_a,))
    got = knn_merge_cand_pallas(x, qid, cur_idx, cur_d, salt, (cur_idx,),
                                (sec_a,), None, None, sources=sources,
                                rescore=False, interpret=True)
    for g, w, name in zip(got, want, ("idx", "d", "improved")):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w),
                                      err_msg=name)


def test_rev_cache_cadence_changes_candidates():
    """Negative control: rev_refresh=5 really serves stale tables (the
    trajectory departs from the rebuild-every-step one)."""
    n = 96
    X, _ = blobs(n=n, dim=8, n_centers=3, seed=5)
    Xj = jnp.asarray(X)
    kw = dict(n_points=n, dim_hd=8, c_hd_rev=4, min_refresh_prob=1.0,
              backend="xla")
    cfg1 = funcsne.FuncSNEConfig(rev_refresh=1, **kw)
    cfg5 = funcsne.FuncSNEConfig(rev_refresh=5, **kw)
    st0 = funcsne.init_state(jax.random.PRNGKey(2), Xj, cfg1)
    hp = funcsne.default_hparams(n)
    st1 = _run_steps(cfg1, st0, Xj, hp, 12)
    st5 = _run_steps(cfg5, st0, Xj, hp, 12)
    assert not np.array_equal(np.asarray(st1.hd_idx),
                              np.asarray(st5.hd_idx))


def test_nnd_rev_cache_refresh1_bit_equals_legacy():
    """The nnd driver's cached reverse table at rev_refresh=1 is
    bit-identical to the legacy in-step rebuild (rev=None), and a
    coarser cadence is a real behaviour change."""
    from repro.core.nnd import NNDConfig, nnd, nnd_init, nnd_step
    X, _ = blobs(n=150, dim=12, n_centers=4, seed=9)
    Xj = jnp.asarray(X)
    cfg = NNDConfig(k=8, c_fwd=4, c_rev=2, backend="xla", rev_refresh=1)
    rng = jax.random.PRNGKey(0)

    idx_c, d_c, hist_c = nnd(Xj, cfg, rng=rng, max_iter=6, tol=-1.0)

    idx, d = nnd_init(rng, Xj, cfg)
    step = jax.jit(lambda r, i, dd, rv: nnd_step(r, Xj, i, dd, cfg,
                                                 rev=rv))
    hist = []
    for it in range(6):
        idx, d, frac = step(jax.random.fold_in(rng, it), idx, d, None)
        hist.append(float(frac))
    np.testing.assert_array_equal(np.asarray(idx_c), np.asarray(idx))
    np.testing.assert_array_equal(np.asarray(d_c), np.asarray(d))
    assert hist_c == hist

    cfg3 = dataclasses.replace(cfg, rev_refresh=3)
    idx_3, _, _ = nnd(Xj, cfg3, rng=rng, max_iter=6, tol=-1.0)
    assert not np.array_equal(np.asarray(idx_3), np.asarray(idx))


def test_nnd_cand_fused_backends_agree():
    """NND's cand_fused mode: the jnp sampler ('xla') and the generating
    kernel ('interpret') produce identical refinements."""
    from repro.core.nnd import NNDConfig, nnd_init, nnd_step
    rng = np.random.default_rng(2)
    X = jnp.asarray((rng.integers(-8, 9, (80, 10)) / 4.0)
                    .astype(np.float32))
    key = jax.random.PRNGKey(4)
    outs = {}
    for backend in ("xla", "interpret"):
        cfg = NNDConfig(k=6, c_fwd=3, c_rev=2, backend=backend,
                        cand_fused=True)
        idx, d = nnd_init(key, X, cfg)
        for it in range(3):
            idx, d, _ = nnd_step(jax.random.fold_in(key, it), X, idx, d,
                                 cfg)
        outs[backend] = (np.asarray(idx), np.asarray(d))
    np.testing.assert_array_equal(outs["xla"][0], outs["interpret"][0])
    np.testing.assert_array_equal(outs["xla"][1], outs["interpret"][1])


# --------------------------------------------------------------------------
# Satellite: auto-rescale (ChunkMetrics consumer)


def test_fit_auto_rescale_triggers_and_matches_manual_loop():
    """auto_rescale with an always-firing threshold must equal a manual
    chunk loop that applies rescale_embedding after every chunk."""
    X, _ = blobs(n=120, dim=6, n_centers=3, seed=6)
    cfg = funcsne.FuncSNEConfig(n_points=120, dim_hd=6)
    hp = funcsne.default_hparams(120)
    st_f, _ = funcsne.fit(X, cfg=cfg, n_iter=30, hparams=hp,
                          schedule=lambda it, n, h: h, chunk_size=10,
                          auto_rescale=1e9)
    chunk = funcsne.make_chunked_step(cfg, 10)
    st = funcsne.init_state(jax.random.PRNGKey(0), jnp.asarray(X), cfg,
                            perplexity=hp.perplexity)
    for i in range(3):
        st, _, _ = chunk(st, jnp.asarray(X), hp)
        if i < 2:    # fit skips the rescale after the final chunk
            st = funcsne.rescale_embedding(st)
    _assert_states_equal(st_f, st)


def test_fit_auto_rescale_off_by_default_and_no_trigger():
    """auto_rescale=None (default) and a never-firing threshold are both
    bit-identical to the plain run."""
    X, _ = blobs(n=120, dim=6, n_centers=3, seed=6)
    cfg = funcsne.FuncSNEConfig(n_points=120, dim_hd=6)
    hp = funcsne.default_hparams(120)
    kw = dict(cfg=cfg, n_iter=20, hparams=hp,
              schedule=lambda it, n, h: h, chunk_size=10)
    st_plain, _ = funcsne.fit(X, **kw)
    st_zero, _ = funcsne.fit(X, auto_rescale=0.0, **kw)
    _assert_states_equal(st_plain, st_zero)


def test_fit_auto_rescale_host_loop_fallback():
    """A host-only schedule routes through _fit_host_loop; the same
    always-firing threshold rescales after every step (except the
    last), matching a manual per-step loop."""
    X, _ = blobs(n=80, dim=5, n_centers=2, seed=7)
    cfg = funcsne.FuncSNEConfig(n_points=80, dim_hd=5)
    hp = funcsne.default_hparams(80)

    def host_schedule(it, n_iter, h):     # Python control flow on it
        return h if int(it) < n_iter else h

    st_f, _ = funcsne.fit(X, cfg=cfg, n_iter=4, hparams=hp,
                          schedule=host_schedule, auto_rescale=1e9)
    st = funcsne.init_state(jax.random.PRNGKey(0), jnp.asarray(X), cfg,
                            perplexity=hp.perplexity)
    step = funcsne.make_step(cfg)
    for it in range(4):
        st = step(st, jnp.asarray(X), hp)
        if it < 3:
            st = funcsne.rescale_embedding(st)
    _assert_states_equal(st_f, st)

"""Merge-fused neighbour refinement: parity, invariants, HLO shape.

Contract layers:

  * kernel vs oracle -- the Pallas kernel (interpret mode) and the
    stable-rank XLA implementation must reproduce
    ``knn_lib.dedup_candidates`` + ``knn_lib.merge_knn`` EXACTLY on
    discrete outputs (indices, improved flags), not just to tolerance:
    test coordinates are quantised to quarter-integers so every squared
    distance is exactly representable and accumulation order cannot flip
    a merge decision.  Sweeps cover SENTINEL slots (current list and
    candidates), inactive rows, duplicate candidates, out-of-range ids,
    distance ties, ragged blocks and multi-M-chunk grids, in both modes
    (HD: stored distances ride in; LD ``rescore``: current rows re-scored
    in-kernel).
  * property suite -- hypothesis (when installed) walks randomized
    shapes/seeds over the same discrete-parity assertion plus the list
    invariants (sorted ascending, self-free, duplicate-free among finite,
    monotone improvement).
  * step level -- flipping ``cfg.merge_fused`` on the XLA backend is
    bit-neutral over 50 steps (the ref IS the legacy pipeline), and the
    interpret backend drives a full step through the kernel.
  * HLO -- the merge-fused step's compiled module contains NO top-k /
    sort (the ``merge_knn`` selection this PR removes) and NO full-size
    (n, C, K) / (n, C, C) dedup broadcast operand; the legacy flag is the
    positive control for both detectors.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import funcsne
from repro.core import knn as knn_lib
from repro.core.knn import SENTINEL
from repro.kernels.knn_merge.kernel import knn_merge_pallas
from repro.kernels.knn_merge.ops import knn_merge
from repro.kernels.knn_merge.ref import knn_merge_ref, knn_merge_rank_ref


# --------------------------------------------------------------------------
# Quantised problem construction (exact distances -> discrete parity)


def _problem(n, m, b, k, c, seed, *, sentinel_frac=0.2, inactive_frac=0.15):
    rng = np.random.default_rng(seed)
    x = jnp.asarray((rng.integers(-8, 9, (n, m)) / 4.0).astype(np.float32))
    qid = jnp.asarray(rng.integers(0, n, b).astype(np.int32))
    cur_idx = rng.integers(0, n, (b, k)).astype(np.int32)
    # invalid tail slots, as merge_knn leaves them (sorted -> inf at end)
    sent = np.sort(rng.random((b, k)) < sentinel_frac, axis=1)
    cur_idx[sent] = SENTINEL
    # out-of-range + SENTINEL + duplicate candidates
    cand = rng.integers(-2, n + 3, (b, c)).astype(np.int32)
    cand[rng.random((b, c)) < 0.1] = SENTINEL
    cand_active = jnp.asarray(rng.random((b, c)) >= inactive_frac)
    # HD-mode stored distances: the real (exact) distances, sorted, with
    # the invariant inf pattern
    d0 = np.array(jnp.sum(
        (x[jnp.clip(jnp.asarray(cur_idx), 0, n - 1)]
         - x[qid][:, None, :]) ** 2, axis=-1))
    d0[sent] = np.inf
    order = np.argsort(d0, axis=1, kind="stable")
    cur_idx_s = jnp.asarray(np.take_along_axis(cur_idx, order, axis=1))
    cur_d = jnp.asarray(np.take_along_axis(d0, order, axis=1))
    cur_valid = jnp.asarray((np.asarray(cur_idx_s) != SENTINEL)
                            & (rng.random((b, k)) < 0.9))
    return x, qid, cur_idx_s, cur_d, jnp.asarray(cand), cand_active, \
        cur_valid


def _assert_all_equal(got, want, what):
    for g, w, name in zip(got, want, ("idx", "d", "improved")):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w),
                                      err_msg=f"{what}:{name}")


def _assert_discrete_parity(n, m, b, k, c, seed, rescore, **pallas_kw):
    x, qid, cur_idx, cur_d, cand, cand_active, cur_valid = _problem(
        n, m, b, k, c, seed)
    if rescore:
        args = (x, qid, cur_idx, None, cand)
        kw = dict(cand_active=cand_active, cur_valid=cur_valid)
        cur_w = cur_valid
    else:
        args = (x, qid, cur_idx, cur_d, cand)
        kw = dict(cand_active=cand_active)
        cur_w = cur_d
    want = knn_merge_ref(*args, **kw)
    _assert_all_equal(knn_merge_rank_ref(*args, **kw), want, "rank_ref")
    got = knn_merge_pallas(x, qid, cur_idx, cur_w, cand, cand_active,
                           rescore=rescore, interpret=True, **pallas_kw)
    _assert_all_equal(got, want, "kernel")
    return want


# --------------------------------------------------------------------------
# Seeded deterministic sweeps (always run, hypothesis or not)


@pytest.mark.parametrize("n,m,b,k,c,bb,bm", [
    (50, 19, 37, 6, 5, 16, 8),     # everything ragged; 3 ragged M chunks
    (64, 128, 64, 8, 7, 32, 128),  # exact tiling, unpadded B
    (40, 300, 33, 4, 3, 8, 128),   # padded B + clamped+masked final M chunk
    (30, 2, 30, 8, 8, 16, 512),    # tiny M (the LD-space case)
])
@pytest.mark.parametrize("rescore", [False, True])
def test_knn_merge_kernel_vs_oracle_sweep(n, m, b, k, c, bb, bm, rescore):
    """Kernel (interpret) and rank ref == dedup_candidates+merge_knn,
    discrete-exact, across ragged/multi-chunk tilings and both modes."""
    _assert_discrete_parity(n, m, b, k, c, seed=n * 10 + m + c,
                            rescore=rescore, block_b=bb, block_m=bm)


@pytest.mark.parametrize("sub_b,persistent_q", [
    (8, False), (8, True), (16, None), (None, True),
])
def test_knn_merge_pipeline_variants(sub_b, persistent_q):
    """Double-buffered sub-blocks and the persistent-q slab are pure
    scheduling for the merge kernel too: every point must stay
    discrete-exact vs the oracle on a multi-M-chunk grid."""
    _assert_discrete_parity(45, 300, 37, 5, 4, seed=17, rescore=False,
                            block_b=16, block_m=64, sub_b=sub_b,
                            persistent_q=persistent_q)


def test_knn_merge_tie_breaking_matches_topk():
    """All-equal coordinates force maximal distance ties: the stable-rank
    merge must resolve them exactly like lax.top_k (current list first,
    then earlier candidates)."""
    n, b, k, c = 12, 9, 4, 6
    x = jnp.zeros((n, 3), jnp.float32)          # every distance == 0.0
    rng = np.random.default_rng(3)
    qid = jnp.asarray(rng.integers(0, n, b).astype(np.int32))
    cur_idx = jnp.asarray(rng.integers(0, n, (b, k)).astype(np.int32))
    cur_d = jnp.zeros((b, k), jnp.float32)
    cand = jnp.asarray(rng.integers(0, n, (b, c)).astype(np.int32))
    active = jnp.ones((b, c), bool)
    want = knn_merge_ref(x, qid, cur_idx, cur_d, cand, cand_active=active)
    got = knn_merge_pallas(x, qid, cur_idx, cur_d, cand, active,
                           rescore=False, interpret=True)
    _assert_all_equal(got, want, "ties")


def test_knn_merge_ops_dispatch():
    """ops.knn_merge: 'xla' is the oracle; 'interpret' runs the kernel;
    both modes agree with the direct ref call."""
    x, qid, cur_idx, cur_d, cand, cand_active, cur_valid = _problem(
        40, 7, 23, 5, 4, seed=5)
    want = knn_merge_ref(x, qid, cur_idx, cur_d, cand,
                         cand_active=cand_active)
    for backend in ("xla", "interpret"):
        got = knn_merge(x, qid, cur_idx, cur_d, cand,
                        cand_active=cand_active, backend=backend)
        _assert_all_equal(got, want, backend)
    with pytest.raises(ValueError):
        knn_merge(x, qid, cur_idx, cur_d, cand, backend="nope")


# --------------------------------------------------------------------------
# Property-based parity + invariants (hypothesis; skipped if missing)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(12, 60), m=st.integers(1, 40), b=st.integers(1, 48),
       k=st.integers(2, 10), c=st.integers(1, 10), rescore=st.booleans(),
       seed=st.integers(0, 10 ** 6))
def test_property_merge_fused_discrete_parity(n, m, b, k, c, rescore, seed):
    """Randomized shapes/seeds: kernel == rank ref == oracle exactly
    (dedup semantics incl. SENTINEL + inactive rows, improved flag), and
    the merged lists keep the merge_knn invariants."""
    new_idx, new_d, _ = _assert_discrete_parity(n, m, b, k, c, seed,
                                                rescore)
    new_idx, new_d = np.asarray(new_idx), np.asarray(new_d)
    assert (np.diff(new_d, axis=1) >= 0).all()           # sorted ascending
    for i in range(b):                                   # no finite dupes
        fin = new_idx[i][np.isfinite(new_d[i])]
        assert len(set(fin.tolist())) == len(fin)


# --------------------------------------------------------------------------
# Step level: flag parity and interpret-backend execution


@pytest.mark.slow
def test_merge_fused_step_bit_equivalent_on_xla():
    """cfg.merge_fused is bit-neutral on the XLA backend: the ref IS the
    legacy dedup/top_k pipeline, so 50 steps from the same seed must
    produce identical state (the gather_fused precedent)."""
    from repro.data.synthetic import blobs
    X, _ = blobs(n=257, dim=13, n_centers=4, center_std=5.0, seed=0)
    Xj = jnp.asarray(X)
    cfg_m = funcsne.FuncSNEConfig(n_points=257, dim_hd=13, backend="xla",
                                  merge_fused=True)
    cfg_l = dataclasses.replace(cfg_m, merge_fused=False)
    st0 = funcsne.init_state(jax.random.PRNGKey(0), Xj, cfg_m)
    hp = funcsne.default_hparams(257)

    def run(cfg, st):
        step = jax.jit(lambda s, x, h: funcsne.funcsne_step(cfg, s, x, h))
        for _ in range(50):
            st = step(st, Xj, hp)
        return st

    st_m = run(cfg_m, st0)
    st_l = run(cfg_l, st0)
    for name in funcsne.FuncSNEState._fields:
        np.testing.assert_array_equal(np.asarray(getattr(st_m, name)),
                                      np.asarray(getattr(st_l, name)),
                                      err_msg=name)


@pytest.mark.slow
def test_merge_fused_step_interpret_trajectory():
    """A few steps with the merge kernel (interpret) vs the XLA selection
    epilogue, same interpret distance kernels: fp32-tolerance parity of
    the embedding (the kernels reassociate distance sums, so bit equality
    is not the contract here)."""
    from repro.data.synthetic import blobs
    X, _ = blobs(n=96, dim=10, n_centers=3, center_std=5.0, seed=1)
    Xj = jnp.asarray(X)
    kw = dict(n_points=96, dim_hd=10, k_hd=8, k_ld=6, n_negatives=5,
              backend="interpret")
    cfg_m = funcsne.FuncSNEConfig(merge_fused=True, **kw)
    cfg_l = funcsne.FuncSNEConfig(merge_fused=False, **kw)
    st_m = funcsne.init_state(jax.random.PRNGKey(3), Xj, cfg_m)
    st_l = funcsne.init_state(jax.random.PRNGKey(3), Xj, cfg_l)
    hp = funcsne.default_hparams(96)
    for _ in range(3):
        st_m = funcsne.funcsne_step(cfg_m, st_m, Xj, hp)
        st_l = funcsne.funcsne_step(cfg_l, st_l, Xj, hp)
    np.testing.assert_allclose(np.asarray(st_m.Y), np.asarray(st_l.Y),
                               rtol=1e-4, atol=1e-5)


def test_nnd_merge_fused_bit_equivalent():
    """nnd.py's port onto knn_merge is bit-neutral on the XLA backend."""
    from repro.core.nnd import NNDConfig, nnd_init, nnd_step
    from repro.data.synthetic import blobs
    X, _ = blobs(n=150, dim=12, n_centers=4, seed=9)
    Xj = jnp.asarray(X)
    cfg_m = NNDConfig(k=8, c_fwd=4, c_rev=2, backend="xla",
                      merge_fused=True)
    cfg_l = dataclasses.replace(cfg_m, merge_fused=False)
    rng = jax.random.PRNGKey(0)

    def run(cfg):
        idx, d = nnd_init(rng, Xj, cfg)
        fracs = []
        for it in range(5):
            idx, d, frac = nnd_step(jax.random.fold_in(rng, it), Xj, idx,
                                    d, cfg)
            fracs.append(float(frac))
        return np.asarray(idx), np.asarray(d), fracs

    idx_m, d_m, f_m = run(cfg_m)
    idx_l, d_l, f_l = run(cfg_l)
    np.testing.assert_array_equal(idx_m, idx_l)
    np.testing.assert_array_equal(d_m, d_l)
    assert f_m == f_l


# --------------------------------------------------------------------------
# HLO: the selection epilogue is structurally gone


def _step_hlo_text(cfg, n):
    X = jnp.asarray(np.random.default_rng(0)
                    .normal(size=(n, cfg.dim_hd)).astype(np.float32))
    st_ = funcsne.init_state(jax.random.PRNGKey(0), X, cfg)
    hp = funcsne.default_hparams(n)
    step = jax.jit(lambda s, x, h: funcsne.funcsne_step(cfg, s, x, h))
    return step.lower(st_, X, hp).compile().as_text()


def _topk_or_sort_lines(text):
    return [l for l in text.splitlines()
            if "TopK" in l or " sort(" in l or "= sort" in l]


def _dedup_broadcast_shapes(text, cfg, n):
    from repro.launch.hlo_analysis import module_array_shapes
    tails = {(cfg.c_hd, cfg.k_hd), (cfg.c_ld, cfg.k_ld),
             (cfg.c_hd, cfg.c_hd), (cfg.c_ld, cfg.c_ld)}
    return [dims for dtype, dims in module_array_shapes(text)
            if dtype == "pred" and len(dims) == 3
            and dims[1:] in tails and dims[0] >= n]


def test_merge_fused_step_hlo_has_no_topk_and_no_dedup_broadcast():
    """Acceptance: with cfg.merge_fused=True (interpret backend = the
    Pallas data path lowered on CPU) the compiled step contains no top-k
    / sort anywhere and no full-size (n, C, K) or (n, C, C) dedup
    broadcast tensor.  The legacy flag is the positive control for both
    detectors."""
    n = 257
    kw = dict(n_points=n, dim_hd=7, backend="interpret")
    cfg_m = funcsne.FuncSNEConfig(merge_fused=True, **kw)
    text_m = _step_hlo_text(cfg_m, n)
    assert _topk_or_sort_lines(text_m) == [], \
        "top_k/sort back in the merge-fused step"
    assert _dedup_broadcast_shapes(text_m, cfg_m, n) == [], \
        "full-size dedup broadcast back in the merge-fused step"

    cfg_l = funcsne.FuncSNEConfig(merge_fused=False, **kw)
    text_l = _step_hlo_text(cfg_l, n)
    assert _topk_or_sort_lines(text_l), \
        "detector is blind: legacy path shows no top_k/sort"
    assert _dedup_broadcast_shapes(text_l, cfg_l, n), \
        "detector is blind: legacy path shows no dedup broadcast"


def test_merge_fused_chunked_step_hlo_clean():
    """The scan-chunked driver compounds the win (the epilogue would run
    T times per dispatch): the whole chunk module must be top_k/sort-free
    too."""
    n = 96
    cfg = funcsne.FuncSNEConfig(n_points=n, dim_hd=5, backend="interpret")
    X = jnp.asarray(np.random.default_rng(1)
                    .normal(size=(n, 5)).astype(np.float32))
    st_ = funcsne.init_state(jax.random.PRNGKey(0), X, cfg)
    hp = funcsne.default_hparams(n)
    chunk = funcsne.make_chunked_step(cfg, 4)
    text = chunk.lower(st_, X, hp).compile().as_text()
    assert _topk_or_sort_lines(text) == []

"""Scatter-fused force epilogue: parity, physics invariants, HLO shape.

Three layers pin the epilogue down:

  * parity -- from the *same* state, the scatter-fused displacement field
    must match the legacy edge-emitting + ``.at[].add`` path to fp32
    reassociation tolerance (randomized shapes, SENTINEL slots, inactive
    rows), and the Pallas scatter kernel must match the segment-sum ref;
  * physics -- with no negative sampling every directed edge acts on both
    endpoints, so the symmetrised field must conserve momentum (sum ~ 0).
    An equally-wrong reference would still pass parity; this catches
    sign/indexing bugs in the epilogue absolutely;
  * HLO -- the scatter-fused step's compiled module must not contain a
    full-size (n, K, d) per-edge force tensor (the buffers this PR
    removes), asserted via the hlo_analysis shape inventory.

Property tests run under hypothesis when installed (tests/_hypothesis_compat
skips them otherwise); seeded parametrized sweeps cover the same ground
unconditionally.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import funcsne
from repro.core.knn import SENTINEL
from repro.kernels.ne_forces.kernel import ne_forces_scatter_pallas
from repro.kernels.ne_forces.ref import (ne_forces_gather_ref,
                                         ne_forces_scatter_ref)


# --------------------------------------------------------------------------
# Randomized state construction (SENTINEL slots, inactive rows)


def _random_forces_state(n, k_hd, k_ld, n_neg, d, seed, *,
                         sentinel_frac=0.15, inactive_frac=0.2):
    rng = np.random.default_rng(seed)
    cfg = funcsne.FuncSNEConfig(n_points=n, dim_hd=4, dim_ld=d, k_hd=k_hd,
                                k_ld=k_ld, n_negatives=n_neg, backend="xla",
                                gather_fused=True, scatter_fused=True)
    hd_idx = rng.integers(0, n, (n, k_hd)).astype(np.int32)
    hd_d = np.sort(rng.random((n, k_hd)).astype(np.float32) * 5.0, axis=1)
    # invalid slots in all the ways _forces_update must mask: SENTINEL
    # index, inf distance, and both
    hd_idx[rng.random((n, k_hd)) < sentinel_frac] = SENTINEL
    hd_d[rng.random((n, k_hd)) < sentinel_frac] = np.inf
    ld_idx = rng.integers(0, n, (n, k_ld)).astype(np.int32)
    ld_idx[rng.random((n, k_ld)) < sentinel_frac] = SENTINEL
    active = rng.random(n) >= inactive_frac
    active[0] = True                      # keep n_act >= 1 row meaningful
    st_ = funcsne.FuncSNEState(
        Y=jnp.asarray(rng.normal(size=(n, d)).astype(np.float32)),
        vel=jnp.asarray(rng.normal(size=(n, d)).astype(np.float32)) * 0.1,
        gains=jnp.asarray(0.5 + rng.random((n, d)).astype(np.float32)),
        hd_idx=jnp.asarray(hd_idx), hd_d=jnp.asarray(hd_d),
        ld_idx=jnp.asarray(ld_idx),
        ld_d=jnp.zeros((n, k_ld), jnp.float32),
        beta=jnp.asarray(0.2 + rng.random(n).astype(np.float32) * 3.0),
        new_flag=jnp.zeros((n,), bool), active=jnp.asarray(active),
        ema_new_frac=jnp.float32(0.5), zhat=jnp.float32(1.7),
        step=jnp.int32(3), rng=jax.random.PRNGKey(seed))
    return cfg, st_


def _assert_forces_update_parity(n, k_hd, k_ld, n_neg, d, alpha, seed):
    cfg_s, st_ = _random_forces_state(n, k_hd, k_ld, n_neg, d, seed)
    cfg_l = dataclasses.replace(cfg_s, scatter_fused=False)
    hp = funcsne.default_hparams(n)._replace(alpha=jnp.float32(alpha))
    key = jax.random.PRNGKey(seed + 1)
    a = funcsne._forces_update(cfg_s, st_, hp, key, funcsne.AxisCtx())
    b = funcsne._forces_update(cfg_l, st_, hp, key, funcsne.AxisCtx())
    # scale-aware fp32 reassociation tolerance on the displacement field
    scale = float(jnp.max(jnp.abs(b.vel))) + 1e-6
    np.testing.assert_allclose(np.asarray(a.vel), np.asarray(b.vel),
                               rtol=5e-5, atol=5e-5 * scale)
    np.testing.assert_allclose(np.asarray(a.Y), np.asarray(b.Y),
                               rtol=5e-5,
                               atol=5e-5 * float(jnp.max(jnp.abs(b.Y))))
    np.testing.assert_allclose(float(a.zhat), float(b.zhat), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(a.gains), np.asarray(b.gains),
                               atol=1e-6)


def _assert_kernel_vs_ref(n, b, d, segments, scatter_back, alpha, seed,
                          block_b):
    rng = np.random.default_rng(seed)
    k = sum(s for _, s in segments)
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    qid = jnp.asarray(rng.integers(0, n, b).astype(np.int32))
    # out-of-range ids: the kernel must clip exactly like the ref
    nbr = jnp.asarray(rng.integers(-2, n + 3, (b, k)).astype(np.int32))
    coef = jnp.asarray(rng.random((b, k)).astype(np.float32))
    scats_p, wsums_p = ne_forces_scatter_pallas(
        x, qid, nbr, coef, alpha, segments=segments,
        scatter_back=scatter_back, block_b=block_b, interpret=True)
    scats_r, wsums_r = ne_forces_scatter_ref(
        x, qid, nbr, coef, alpha, segments=segments,
        scatter_back=scatter_back)
    for s in range(len(segments)):
        np.testing.assert_allclose(np.asarray(scats_p[s]),
                                   np.asarray(scats_r[s]),
                                   rtol=2e-5, atol=2e-5,
                                   err_msg=f"scat[{s}]")
        np.testing.assert_allclose(np.asarray(wsums_p[s]),
                                   np.asarray(wsums_r[s]),
                                   rtol=2e-5, atol=2e-5,
                                   err_msg=f"wsum[{s}]")


# --------------------------------------------------------------------------
# Property-based parity (hypothesis; skipped when it is not installed)


@settings(max_examples=12, deadline=None)
@given(n=st.integers(12, 48), k_hd=st.integers(2, 8),
       k_ld=st.integers(2, 6), n_neg=st.integers(0, 5),
       d=st.integers(2, 4), alpha=st.floats(0.4, 3.0),
       seed=st.integers(0, 10 ** 6))
def test_property_forces_update_parity(n, k_hd, k_ld, n_neg, d, alpha, seed):
    """scatter-fused _forces_update == legacy displacement field, under
    randomized shapes with SENTINEL slots and inactive rows."""
    _assert_forces_update_parity(n, k_hd, k_ld, n_neg, d, alpha, seed)


@settings(max_examples=12, deadline=None)
@given(n=st.integers(8, 60), b=st.integers(1, 50),
       s1=st.integers(1, 6), s2=st.integers(1, 5), d=st.integers(2, 5),
       back2=st.booleans(), alpha=st.floats(0.4, 3.0),
       block_b=st.sampled_from([8, 16, 32]), seed=st.integers(0, 10 ** 6))
def test_property_scatter_kernel_vs_segment_sum_ref(n, b, s1, s2, d, back2,
                                                    alpha, block_b, seed):
    """Pallas scatter kernel (interpret) == jax.ops.segment_sum reference."""
    segments = (("attraction", s1), ("repulsion", s2))
    _assert_kernel_vs_ref(n, b, d, segments, (True, back2), alpha, seed,
                          block_b)


# --------------------------------------------------------------------------
# Seeded deterministic sweeps (always run, hypothesis or not)


@pytest.mark.parametrize("n,k_hd,k_ld,n_neg,d,alpha,seed", [
    (30, 4, 3, 4, 2, 1.0, 0),
    (48, 8, 6, 0, 2, 0.5, 1),     # no negatives: pure symmetrised field
    (17, 2, 2, 2, 3, 2.5, 2),     # ragged small shapes
    (64, 6, 4, 8, 4, 1.3, 3),     # d > 2
])
def test_forces_update_parity_sweep(n, k_hd, k_ld, n_neg, d, alpha, seed):
    _assert_forces_update_parity(n, k_hd, k_ld, n_neg, d, alpha, seed)


@pytest.mark.parametrize("segments,scatter_back", [
    ((("attraction", 5),), (True,)),
    ((("repulsion", 4),), (True,)),
    ((("attraction", 4), ("repulsion", 3), ("repulsion", 2)),
     (True, True, False)),
])
@pytest.mark.parametrize("n,b,d,block_b", [(50, 37, 2, 16),   # padded B
                                           (64, 64, 4, 32),   # exact tiling
                                           (23, 11, 3, 8)])
def test_scatter_kernel_vs_ref_sweep(segments, scatter_back, n, b, d,
                                     block_b):
    _assert_kernel_vs_ref(n, b, d, segments, scatter_back, 1.3,
                          n * 10 + b, block_b)


# --------------------------------------------------------------------------
# N-chunked binning: the resident slab is (chunk_n, d), not (N, d)


@pytest.mark.parametrize("chunk_n", [8, 16, 48, 50])   # ragged + exact + N
@pytest.mark.parametrize("n,b,d,block_b", [(50, 37, 2, 16), (64, 23, 3, 32)])
def test_scatter_kernel_chunked_bins_vs_ref(chunk_n, n, b, d, block_b):
    """Any chunk_n (ragged final chunk included) must reproduce the
    single-chunk answer: the chunk guard bins every edge exactly once and
    the staged rows survive the block's chunk sweep."""
    segments = (("attraction", 4), ("repulsion", 3), ("repulsion", 2))
    rng = np.random.default_rng(n + chunk_n)
    k = 9
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    qid = jnp.asarray(rng.integers(0, n, b).astype(np.int32))
    nbr = jnp.asarray(rng.integers(-2, n + 3, (b, k)).astype(np.int32))
    coef = jnp.asarray(rng.random((b, k)).astype(np.float32))
    got = ne_forces_scatter_pallas(x, qid, nbr, coef, 1.3,
                                   segments=segments,
                                   scatter_back=(True, True, False),
                                   block_b=block_b, chunk_n=chunk_n,
                                   interpret=True)
    want = ne_forces_scatter_ref(x, qid, nbr, coef, 1.3, segments=segments,
                                 scatter_back=(True, True, False))
    for s in range(len(segments)):
        np.testing.assert_allclose(np.asarray(got[0][s]),
                                   np.asarray(want[0][s]),
                                   rtol=2e-5, atol=2e-5,
                                   err_msg=f"scat[{s}]@chunk_n={chunk_n}")
        np.testing.assert_allclose(np.asarray(got[1][s]),
                                   np.asarray(want[1][s]),
                                   rtol=2e-5, atol=2e-5,
                                   err_msg=f"wsum[{s}]@chunk_n={chunk_n}")


def test_scatter_chunk_plan_lifts_large_n_vmem_cap():
    """Acceptance: n=16384 at d=2 with the step's 3 segments no longer
    falls back to the XLA segment-sum ref -- the plan chunks the bins so
    the resident slabs fit the ~10MB VMEM budget."""
    from repro.kernels.ne_forces import ops

    chunk_n = ops.scatter_chunk_plan(16384, 2, 3)
    assert chunk_n is not None, "fused epilogue fell back at n=16384/d=2"
    n_chunks = -(-16384 // chunk_n)
    assert n_chunks > 1, "plan claims a whole-(N,d) slab fits; it cannot"
    lane_padded = 128                       # d=2 pads to one 128-lane tile
    assert 3 * chunk_n * lane_padded * 4 <= ops._SCATTER_VMEM_BUDGET
    assert chunk_n % 8 == 0                 # sublane-tile aligned
    # small problems stay single-chunk; absurd ones still decline
    assert ops.scatter_chunk_plan(2048, 2, 3) == 2048
    assert ops.scatter_chunk_plan(10 ** 7, 2, 3) is None


def test_scatter_ops_dispatch_uses_chunked_kernel_past_old_cap(monkeypatch):
    """End-to-end through ops.ne_forces_gather: when the budget forces
    multiple chunks (budget shrunk so a small n crosses it), the interpret
    dispatch must still produce the ref answer via the chunked kernel
    rather than falling back to XLA."""
    from repro.kernels.ne_forces import ops

    rng = np.random.default_rng(2)
    n, b, d, k = 96, 41, 2, 7
    segments = (("attraction", 4), ("repulsion", 3))
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    qid = jnp.asarray(rng.integers(0, n, b).astype(np.int32))
    nbr = jnp.asarray(rng.integers(-1, n + 2, (b, k)).astype(np.int32))
    coef = jnp.asarray(rng.random((b, k)).astype(np.float32))

    monkeypatch.setattr(ops, "_SCATTER_VMEM_BUDGET", 2 * 128 * 4 * 32)
    assert ops.scatter_chunk_plan(n, d, len(segments)) == 32   # 3 chunks
    got = ops.ne_forces_gather(x, qid, nbr, coef, 1.1, segments=segments,
                               scatter_fused=True,
                               scatter_back=(True, True),
                               backend="interpret")
    want = ne_forces_scatter_ref(x, qid, nbr, coef, 1.1, segments=segments,
                                 scatter_back=(True, True))
    for s in range(len(segments)):
        np.testing.assert_allclose(np.asarray(got[0][s]),
                                   np.asarray(want[0][s]),
                                   rtol=2e-5, atol=2e-5)


def test_scatter_ref_matches_manual_edge_scatters():
    """segment-sum ref == edge-emitting ref + explicit .at[].add scatters
    (the exact construction _forces_update used before this PR)."""
    rng = np.random.default_rng(5)
    n, b, d = 40, 33, 2
    segments = (("attraction", 6), ("repulsion", 4))
    back = (True, False)
    k = 10
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    qid = jnp.asarray(rng.integers(0, n, b).astype(np.int32))
    nbr = jnp.asarray(rng.integers(-1, n + 2, (b, k)).astype(np.int32))
    coef = jnp.asarray(rng.random((b, k)).astype(np.float32))
    scats, wsums = ne_forces_scatter_ref(x, qid, nbr, coef, 0.9,
                                         segments=segments,
                                         scatter_back=back)
    aggs, edges, wsums_e = ne_forces_gather_ref(x, qid, nbr, coef, 0.9,
                                                segments=segments)
    k0 = 0
    for s, (_, size) in enumerate(segments):
        want = jnp.zeros((n, d)).at[jnp.clip(qid, 0, n - 1)].add(aggs[s])
        if back[s]:
            tgt = jnp.clip(nbr[:, k0:k0 + size], 0, n - 1).reshape(-1)
            want = want.at[tgt].add(-edges[s].reshape(-1, d))
        np.testing.assert_allclose(np.asarray(scats[s]), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(wsums[s]),
                                   np.asarray(wsums_e[s]), rtol=1e-6)
        k0 += size


# --------------------------------------------------------------------------
# Physics invariant: momentum conservation without negative sampling


@pytest.mark.parametrize("backend", ["xla", "interpret"])
def test_symmetrised_field_conserves_momentum(backend):
    """Every scatter_back segment pairs +edge (query) with -edge
    (neighbour), so each per-segment field must sum to ~0 -- a sign or
    indexing bug in the epilogue breaks this even if kernel and ref agree.
    """
    rng = np.random.default_rng(7)
    n, b, d = 45, 45, 2
    segments = (("attraction", 5), ("repulsion", 4))
    k = 9
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    qid = jnp.arange(b, dtype=jnp.int32)
    nbr = jnp.asarray(rng.integers(0, n, (b, k)).astype(np.int32))
    coef = jnp.asarray(rng.random((b, k)).astype(np.float32))
    from repro.kernels.ne_forces.ops import ne_forces_gather
    scats, _ = ne_forces_gather(x, qid, nbr, coef, 1.0, segments=segments,
                                scatter_fused=True,
                                scatter_back=(True, True), backend=backend)
    for s, scat in enumerate(scats):
        total = np.asarray(jnp.sum(scat, axis=0))
        np.testing.assert_allclose(total, 0.0, atol=1e-4,
                                   err_msg=f"segment {s}")


@pytest.mark.parametrize("scatter_fused", [True, False])
def test_forces_update_conserves_momentum_without_negatives(scatter_fused):
    """n_negatives=0 + all rows active: the full symmetrised displacement
    field must sum to ~0 (momentum conservation)."""
    n, d = 52, 2
    cfg, st_ = _random_forces_state(n, 6, 4, 0, d, seed=11,
                                    sentinel_frac=0.1, inactive_frac=0.0)
    cfg = dataclasses.replace(cfg, scatter_fused=scatter_fused)
    # zero velocity + unit gains so Y2 - Y == lr * dY exactly
    st_ = st_._replace(vel=jnp.zeros((n, d), jnp.float32),
                       gains=jnp.ones((n, d), jnp.float32))
    hp = funcsne.default_hparams(n)
    out = funcsne._forces_update(cfg, st_, hp, jax.random.PRNGKey(0),
                                 funcsne.AxisCtx())
    dY = np.asarray(out.Y - st_.Y)
    # conservation to fp32 accumulation tolerance, relative to the total
    # unsigned momentum actually exchanged
    budget = np.abs(dY).sum() + 1e-6
    assert np.abs(dY.sum(axis=0)).max() < 1e-5 * budget, (
        dY.sum(axis=0), budget)


def test_negative_sampling_breaks_momentum_conservation():
    """Sanity check on the invariant's power: with negatives (whose edges
    are deliberately not symmetrised) the field does NOT sum to zero."""
    n, d = 52, 2
    cfg, st_ = _random_forces_state(n, 6, 4, 16, d, seed=11,
                                    sentinel_frac=0.1, inactive_frac=0.0)
    st_ = st_._replace(vel=jnp.zeros((n, d), jnp.float32),
                       gains=jnp.ones((n, d), jnp.float32))
    hp = funcsne.default_hparams(n)
    out = funcsne._forces_update(cfg, st_, hp, jax.random.PRNGKey(0),
                                 funcsne.AxisCtx())
    dY = np.asarray(out.Y - st_.Y)
    budget = np.abs(dY).sum() + 1e-6
    assert np.abs(dY.sum(axis=0)).max() > 1e-4 * budget


# --------------------------------------------------------------------------
# HLO: the (n, K, d) per-edge force tensors are gone


def _edge_shapes_in_step_hlo(cfg, n):
    X = jnp.asarray(np.random.default_rng(0)
                    .normal(size=(n, cfg.dim_hd)).astype(np.float32))
    st_ = funcsne.init_state(jax.random.PRNGKey(0), X, cfg)
    hp = funcsne.default_hparams(n)
    step = jax.jit(lambda s, x, h: funcsne.funcsne_step(cfg, s, x, h))
    text = step.lower(st_, X, hp).compile().as_text()
    from repro.launch.hlo_analysis import module_array_shapes
    shapes = module_array_shapes(text)
    edge_tails = {(cfg.k_hd, cfg.dim_ld), (cfg.k_ld, cfg.dim_ld)}
    return [dims for dtype, dims in shapes
            if dtype == "f32" and len(dims) == 3
            and dims[1:] in edge_tails and dims[0] >= n]


def test_scatter_fused_step_hlo_has_no_edge_tensor():
    """Acceptance: no full-size (n, K, d) per-edge force buffer may appear
    anywhere in the scatter-fused step's compiled module (interpret
    backend = the Pallas kernel data path, lowered on CPU).  The legacy
    edge-emitting path is the positive control for the detector."""
    n = 257
    kw = dict(n_points=n, dim_hd=7, backend="interpret", gather_fused=True)
    fused = _edge_shapes_in_step_hlo(
        funcsne.FuncSNEConfig(scatter_fused=True, **kw), n)
    assert fused == [], f"per-edge tensors back in the hot path: {fused}"
    legacy = _edge_shapes_in_step_hlo(
        funcsne.FuncSNEConfig(scatter_fused=False, **kw), n)
    assert legacy, "detector is blind: legacy path shows no edge tensor"

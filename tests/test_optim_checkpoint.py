"""Optimiser, quantised state, compression, and checkpointing tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.checkpoint import Checkpointer
from repro.optim import adamw, clip_by_global_norm, sgdm, warmup_cosine
from repro.optim.compression import (EFState, compress_with_error_feedback,
                                     init_ef)
from repro.optim.quantized import BLOCK, dequantize, quantize


def _quadratic_params():
    return {"w": jnp.asarray([3.0, -2.0, 5.0]),
            "b": {"x": jnp.asarray([[1.0, -1.0], [0.5, 0.25]])}}


@pytest.mark.parametrize("moment_dtype", ["float32", "int8"])
def test_adamw_decreases_quadratic(moment_dtype):
    opt = adamw(0.1, weight_decay=0.0, moment_dtype=moment_dtype)
    params = _quadratic_params()
    state = opt.init(params)
    loss = lambda p: (jnp.sum(p["w"] ** 2)
                      + jnp.sum(p["b"]["x"] ** 2))
    l0 = float(loss(params))
    for _ in range(60):
        grads = jax.grad(loss)(params)
        params, state = opt.update(grads, state, params)
    assert float(loss(params)) < 0.05 * l0


def test_int8_and_fp32_adam_agree_early():
    params = _quadratic_params()
    o1, o2 = adamw(0.05), adamw(0.05, moment_dtype="int8")
    s1, s2 = o1.init(params), o2.init(params)
    p1 = p2 = params
    loss = lambda p: jnp.sum(p["w"] ** 2) + jnp.sum(p["b"]["x"] ** 2)
    for _ in range(10):
        p1, s1 = o1.update(jax.grad(loss)(p1), s1, p1)
        p2, s2 = o2.update(jax.grad(loss)(p2), s2, p2)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=0.08, atol=0.02)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 2000), scale=st.floats(1e-6, 1e4), seed=st.integers(0, 99))
def test_quantize_roundtrip_error_bound(n, scale, seed):
    x = np.random.default_rng(seed).normal(size=n).astype(np.float32) * scale
    q = quantize(jnp.asarray(x))
    back = np.asarray(dequantize(q))
    assert q.q.shape == x.shape            # shape-preserving layout (H3)
    # blockwise absmax int8: error <= absmax_block / 127 per element
    b = q.block
    blocks = x.reshape(-1, b)
    bound = np.repeat(np.abs(blocks).max(1) / 127.0, b)[:n] + 1e-12
    assert (np.abs(back - x) <= bound * 1.01).all()
    assert q.q.dtype == np.int8


def test_quantize_2d_shape_and_block():
    x = np.random.default_rng(0).normal(size=(8, 192)).astype(np.float32)
    q = quantize(jnp.asarray(x))
    assert q.q.shape == (8, 192) and q.block == 192
    assert q.scale.shape == (8, 1)
    np.testing.assert_allclose(np.asarray(dequantize(q)), x, atol=np.abs(
        x).max() / 100)


def test_clip_by_global_norm():
    g = {"a": jnp.ones((4,)) * 10.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(norm), 20.0)
    np.testing.assert_allclose(
        float(jnp.linalg.norm(clipped["a"])), 1.0, rtol=1e-6)


def test_warmup_cosine_shape():
    f = warmup_cosine(1.0, 10, 100)
    assert float(f(jnp.asarray(5))) == pytest.approx(0.5)
    assert float(f(jnp.asarray(10))) == pytest.approx(1.0)
    assert float(f(jnp.asarray(100))) == pytest.approx(0.1, abs=1e-6)


def test_error_feedback_compression_is_lossless_over_time():
    """Top-k with error feedback transmits everything eventually: the sum
    of sparsified tensors + final residual equals the sum of inputs."""
    rng = np.random.default_rng(0)
    shape = (64,)
    ef = init_ef({"g": jnp.zeros(shape)})
    total_in = np.zeros(shape, np.float32)
    total_sent = np.zeros(shape, np.float32)
    for step in range(20):
        g = {"g": jnp.asarray(rng.normal(size=shape).astype(np.float32))}
        total_in += np.asarray(g["g"])
        sparse, ef, dens = compress_with_error_feedback(g, ef, k_frac=0.1)
        total_sent += np.asarray(sparse["g"])
        assert float(dens) <= 0.15
    np.testing.assert_allclose(total_sent + np.asarray(ef.residual["g"]),
                               total_in, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Checkpointer


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"params": {"w": jnp.asarray(rng.normal(size=(8, 4))
                                        .astype(np.float32)),
                       "b": jnp.asarray(rng.normal(size=(4,))
                                        .astype(np.float32))},
            "opt": {"count": jnp.asarray(7, jnp.int32)}}


def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(tmp_path, keep_last=2)
    t = _tree()
    ck.save(10, t, metadata={"cursor": 1234}, blocking=True)
    got, meta = ck.restore(jax.tree.map(jnp.zeros_like, t))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert meta["step"] == 10 and meta["cursor"] == 1234


def test_checkpoint_async_and_prune(tmp_path):
    ck = Checkpointer(tmp_path, keep_last=2)
    for s in (1, 2, 3, 4):
        ck.save(s, _tree(s))
    ck.wait()
    assert ck.all_steps() == [3, 4]
    got, meta = ck.restore(jax.tree.map(jnp.zeros_like, _tree()))
    assert meta["step"] == 4


def test_checkpoint_restore_with_quantized_state(tmp_path):
    from repro.optim import adamw
    params = {"w": jnp.ones((300,))}
    opt = adamw(0.1, moment_dtype="int8")
    state = opt.init(params)
    _, state = opt.update({"w": jnp.ones((300,)) * 0.3}, state, params)
    ck = Checkpointer(tmp_path)
    ck.save(1, {"opt": state}, blocking=True)
    like = {"opt": opt.init(params)}
    got, _ = ck.restore(like)
    np.testing.assert_array_equal(np.asarray(got["opt"].m["w"].q),
                                  np.asarray(state.m["w"].q))


def test_checkpoint_atomicity_no_partial_dirs(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(5, _tree(), blocking=True)
    assert not list(tmp_path.glob(".tmp-*"))
    assert ck.latest_step() == 5


def _broken_savez(monkeypatch):
    import repro.checkpoint.checkpointer as ckm

    def boom(*a, **kw):
        raise OSError("disk full (injected)")

    monkeypatch.setattr(ckm.np, "savez", boom)


def test_checkpoint_blocking_save_raises_immediately(tmp_path, monkeypatch):
    ck = Checkpointer(tmp_path)
    _broken_savez(monkeypatch)
    with pytest.raises(OSError, match="disk full"):
        ck.save(1, _tree(), blocking=True)
    # the error was delivered, not left armed for the next caller
    assert ck.last_error is None


def test_checkpoint_async_save_error_surfaces_on_wait(tmp_path, monkeypatch):
    ck = Checkpointer(tmp_path)
    _broken_savez(monkeypatch)
    ck.save(1, _tree())
    with pytest.raises(OSError, match="disk full"):
        ck.wait()
    assert ck.last_error is None


def test_checkpoint_async_save_error_surfaces_on_next_save(tmp_path,
                                                           monkeypatch):
    ck = Checkpointer(tmp_path)
    _broken_savez(monkeypatch)
    ck.save(1, _tree())
    ck._thread.join()                     # error is parked in last_error
    with pytest.raises(OSError, match="disk full"):
        ck.save(2, _tree())               # save() waits on the prior write


@pytest.mark.parametrize("keep_last", [0, 1])
def test_checkpoint_prune_keep_last_small(tmp_path, keep_last):
    """keep_last=1 keeps exactly the newest step; keep_last=0 keeps
    NOTHING (regression: `steps[:-0]` is the empty slice, so the old
    prune silently kept everything)."""
    ck = Checkpointer(tmp_path, keep_last=keep_last)
    for s in (1, 2, 3):
        ck.save(s, _tree(s), blocking=True)
    assert ck.all_steps() == ([] if keep_last == 0 else [3])


def test_checkpoint_close_warns_on_unobserved_error(tmp_path, monkeypatch):
    ck = Checkpointer(tmp_path)
    _broken_savez(monkeypatch)
    ck.save(1, _tree())
    with pytest.warns(RuntimeWarning, match="never observed"):
        ck.close()                        # error path: warn, don't raise
    assert ck.last_error is None          # delivered, not re-armed


def test_checkpoint_del_warns_on_unobserved_error(tmp_path, monkeypatch):
    ck = Checkpointer(tmp_path)
    _broken_savez(monkeypatch)
    ck.save(1, _tree())
    ck._thread.join()                     # error parked in last_error
    with pytest.warns(RuntimeWarning, match="garbage-collected"):
        ck.__del__()


def test_checkpoint_close_is_quiet_after_wait(tmp_path):
    import warnings as _w
    ck = Checkpointer(tmp_path)
    ck.save(1, _tree())
    ck.wait()
    with _w.catch_warnings():
        _w.simplefilter("error")
        ck.close()                        # clean shutdown: no warning


def test_checkpoint_funcsne_state_roundtrip_bitwise(tmp_path):
    """The resilience contract: a FuncSNEState (embedding, KNN tables,
    RNG key, reverse-edge cache) survives save/restore bit-for-bit."""
    from repro.core import funcsne

    n, dim = 24, 4
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.normal(size=(n, dim)).astype(np.float32))
    cfg = funcsne.FuncSNEConfig(n_points=n, dim_hd=dim, k_hd=8, k_ld=4,
                                n_negatives=4, c_hd_rev=2, backend="xla")
    st = funcsne.init_state(jax.random.PRNGKey(1), X, cfg)
    step = jax.jit(lambda s: funcsne.funcsne_step(cfg, s, X,
                                                  funcsne.default_hparams(n)))
    for _ in range(3):                    # populate rev_idx and EMAs
        st = step(st)
    ck = Checkpointer(tmp_path)
    ck.save(3, st, blocking=True)
    got, meta = ck.restore(jax.tree.map(jnp.zeros_like, st))
    assert meta["step"] == 3
    for name, a, b in zip(st._fields, st, got):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"field {name!r}")

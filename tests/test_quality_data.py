"""Quality criteria (R_NX), synthetic data, token stream."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core.knn import exact_knn
from repro.core.quality import (embedding_quality, one_nn_accuracy,
                                qnx_curve, rnx_auc, rnx_curve)
from repro.data import synthetic
from repro.data.tokens import TokenStream, TokenStreamConfig


def test_rnx_identity_is_one():
    X, _ = synthetic.blobs(n=300, dim=8, seed=0)
    assert float(embedding_quality(jnp.asarray(X), jnp.asarray(X))) \
        > 0.999


def test_rnx_random_is_zero():
    X, _ = synthetic.blobs(n=300, dim=8, seed=0)
    Y = np.random.default_rng(1).normal(size=(300, 2)).astype(np.float32)
    assert abs(float(embedding_quality(jnp.asarray(X), jnp.asarray(Y)))) \
        < 0.05


@settings(max_examples=20, deadline=None)
@given(n=st.integers(20, 120), k=st.integers(2, 10), seed=st.integers(0, 99))
def test_qnx_bounds_and_monotone_overlap(n, k, seed):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, 5)).astype(np.float32)
    t, _ = exact_knn(jnp.asarray(X), k)
    e, _ = exact_knn(jnp.asarray(X + 0.01 * rng.normal(size=X.shape)
                                 .astype(np.float32)), k)
    q = np.asarray(qnx_curve(e, t))
    assert (q >= 0).all() and (q <= 1 + 1e-6).all()
    r = np.asarray(rnx_curve(e, t, n))
    assert (r <= 1 + 1e-6).all()


def test_rnx_auc_weighting_prefers_local():
    # a curve good at small K must beat one good at large K under 1/K
    k = 50
    good_local = jnp.asarray([1.0] * 10 + [0.0] * (k - 10))
    good_global = jnp.asarray([0.0] * (k - 10) + [1.0] * 10)
    assert float(rnx_auc(good_local)) > float(rnx_auc(good_global))


def test_one_nn_leave_one_out():
    X, labels = synthetic.blobs(n=300, dim=8, n_centers=3, center_std=10.0,
                                blob_std=0.5, seed=2)
    acc = one_nn_accuracy(jnp.asarray(X), jnp.asarray(labels),
                          jax.random.PRNGKey(0))
    assert float(acc) > 0.95


def test_one_nn_one_shot():
    X, labels = synthetic.blobs(n=200, dim=8, n_centers=4, center_std=12.0,
                                blob_std=0.5, seed=3)
    acc = one_nn_accuracy(jnp.asarray(X), jnp.asarray(labels),
                          jax.random.PRNGKey(0), n_trials=3, one_shot=True)
    assert float(acc) > 0.8


def test_synthetic_shapes_and_labels():
    X, l = synthetic.blobs(n=100, dim=7)
    assert X.shape == (100, 7) and l.shape == (100,)
    X, l = synthetic.s_curve(n=50, unbalanced=True)
    assert X.shape == (50, 3) and set(np.unique(l)) <= {0, 1}
    X, l = synthetic.coil_rings(n_objects=3, n_per_object=10, dim=12)
    assert X.shape == (30, 12) and len(np.unique(l)) == 3
    X, major, minor = synthetic.hierarchical_cells(n=160, dim=10)
    assert X.shape[0] == len(major) == len(minor)
    X, l = synthetic.mnist_like(n=100, dim=16)
    assert X.shape == (100, 16)


def test_token_stream_deterministic_and_host_sharded():
    cfg = TokenStreamConfig(vocab_size=128, seq_len=16, global_batch=8)
    a = TokenStream(cfg).batch(3)
    b = TokenStream(cfg).batch(3)
    np.testing.assert_array_equal(a, b)
    c = TokenStream(cfg).batch(4)
    assert not np.array_equal(a, c)
    h0 = TokenStream(cfg, host_id=0, n_hosts=2).batch(3)
    h1 = TokenStream(cfg, host_id=1, n_hosts=2).batch(3)
    assert h0.shape == (4, 17)
    assert not np.array_equal(h0, h1)
    assert a.max() < 128 and a.min() >= 0


def test_dbscan_two_blobs():
    from repro.core.dbscan import dbscan, relabel_compact
    rng = np.random.default_rng(0)
    a = rng.normal(size=(60, 2)) * 0.2
    b = rng.normal(size=(60, 2)) * 0.2 + 10.0
    Y = np.concatenate([a, b]).astype(np.float32)
    labels, k = relabel_compact(dbscan(jnp.asarray(Y), eps=1.0, min_pts=4))
    assert k == 2
    assert len(set(labels[:60]) - {-1}) == 1
    assert set(labels[:60]) - {-1} != set(labels[60:]) - {-1}

"""Fault tolerance: checkpoint/restart determinism, failure injection,
straggler detection."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.data.tokens import TokenStream, TokenStreamConfig
from repro.launch.steps import make_model, make_optimizer, make_train_step
from repro.launch.train import reduced_variant
from repro.runtime.straggler import StepTimeMonitor
from repro.runtime.trainer import (SimulatedFailure, Trainer, TrainerConfig)


def _setup(tmp_path, total=24, fail_at=None, ckpt_every=8):
    cfg = dataclasses.replace(reduced_variant(get_arch("qwen2-7b"),
                                              d_model=64, n_layers=2),
                              vocab_size=256)
    model = make_model(cfg)
    opt = make_optimizer(cfg, peak_lr=1e-3, warmup=5, total=total)
    step_fn = jax.jit(make_train_step(model, opt), donate_argnums=(0, 1))
    stream = TokenStream(TokenStreamConfig(vocab_size=cfg.vocab_size,
                                           seq_len=32, global_batch=4))

    def data_fn(step):
        x, y = stream.train_pair(step)
        return {"inputs": jnp.asarray(x), "labels": jnp.asarray(y)}

    params = model.init_params(jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    trainer = Trainer(TrainerConfig(
        total_steps=total, checkpoint_every=ckpt_every,
        checkpoint_dir=str(tmp_path), log_every=1000,
        fail_at_step=fail_at), step_fn, data_fn, params, opt_state,
        logger=lambda s: None)
    return trainer


def test_loss_decreases(tmp_path):
    trainer = _setup(tmp_path / "a", total=30)
    hist = trainer.run()
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first, (first, last)


def test_failure_injection_and_exact_restart(tmp_path):
    # uninterrupted run
    ref = _setup(tmp_path / "ref", total=20, ckpt_every=8)
    ref_hist = ref.run()

    # crashed run: dies at step 13 (after the step-8 checkpoint)
    crash = _setup(tmp_path / "crash", total=20, fail_at=13, ckpt_every=8)
    with pytest.raises(SimulatedFailure):
        crash.run()

    # relaunch: restores step-8 checkpoint, resumes the same data order
    resume = _setup(tmp_path / "crash", total=20, ckpt_every=8)
    assert resume.maybe_restore()
    assert resume.start_step == 8
    resume_hist = resume.run()
    ref_by_step = {h["step"]: h["loss"] for h in ref_hist}
    for h in resume_hist:
        np.testing.assert_allclose(h["loss"], ref_by_step[h["step"]],
                                   rtol=1e-4, atol=1e-5)


def test_straggler_monitor_flags_spike():
    mon = StepTimeMonitor(warmup_steps=3, z_thresh=3.0)
    alarms = [mon.observe(0.10 + 0.001 * i) for i in range(20)]
    assert not any(alarms)
    assert mon.observe(1.5) is not None


def test_straggler_monitor_hang():
    mon = StepTimeMonitor(warmup_steps=1, hang_timeout=2.0)
    mon.observe(0.1)
    assert "hang" in mon.observe(3.0)


def test_elastic_remesh_shapes():
    from repro.runtime.elastic import remesh, surviving_pods
    mesh = remesh(1, model=16)
    assert mesh.devices.size == 1
    # observer-stamped beat records: (counter, stamped-by-observer)
    assert surviving_pods({0: (7, 100.0), 1: (3, 50.0)}, timeout_s=30.0,
                          now=110.0) == [0]

"""End-to-end behaviour: the paper's central claims on small data.

1. FUnc-SNE reaches near-exact KNN sets while embedding (joint iteration).
2. Embedding quality is competitive with exact variable-tail t-SNE and
   beats the negative-sampling-only (UMAP-regime) ablation at equal cost
   (paper Table 1 / Fig. 6).
3. Heavier LD tails (smaller alpha) fragment the embedding into more
   clusters (paper Fig. 3/5).
4. Arbitrary embedding dimensionality works (d_ld = 8) and helps the
   downstream 1-NN task (paper Sec. 4.2 / Table 2 direction).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines, funcsne
from repro.core.dbscan import dbscan, relabel_compact
from repro.core.quality import (embedding_quality, knn_set_quality,
                                one_nn_accuracy)
from repro.data.synthetic import blobs, hierarchical_cells


@pytest.fixture(scope="module")
def cells():
    X, major, minor = hierarchical_cells(n=800, dim=24, seed=0)
    return jnp.asarray(X), jnp.asarray(major), jnp.asarray(minor)


@pytest.fixture(scope="module")
def funcsne_result(cells):
    X, major, minor = cells
    hp = funcsne.default_hparams(X.shape[0], perplexity=10.0)
    st, _ = funcsne.fit(np.asarray(X), n_iter=500, hparams=hp)
    return st


def test_joint_knn_converges(cells, funcsne_result):
    X, _, _ = cells
    assert float(knn_set_quality(funcsne_result.hd_idx, X)) > 0.9


def test_quality_beats_ns_only_and_tracks_exact(cells, funcsne_result):
    X, _, _ = cells
    q_ours = float(embedding_quality(X, funcsne_result.Y))
    Yn = baselines.negative_sampling_embed(np.asarray(X), n_iter=500,
                                           hparams=funcsne.default_hparams(
                                               X.shape[0], perplexity=10.0))
    q_ns = float(embedding_quality(X, Yn))
    Yt = baselines.exact_tsne(np.asarray(X), n_iter=300, perplexity=10.0)
    q_exact = float(embedding_quality(X, Yt))
    # competitive with exact, clearly better than NS-only
    assert q_ours > q_ns, (q_ours, q_ns)
    assert q_ours > 0.5 * q_exact, (q_ours, q_exact)


def test_cluster_separation_downstream(cells, funcsne_result):
    _, major, _ = cells
    acc = one_nn_accuracy(funcsne_result.Y, major, jax.random.PRNGKey(0))
    assert float(acc) > 0.9


def test_alpha_controls_fragmentation(cells):
    """Paper Fig. 3/5: smaller alpha (heavier tails) -> more clusters."""
    X, _, _ = cells
    counts = {}
    for alpha in (3.0, 0.5):
        hp = funcsne.default_hparams(X.shape[0], alpha=alpha,
                                     perplexity=10.0)
        st, _ = funcsne.fit(np.asarray(X), n_iter=400, hparams=hp,
                            rng=jax.random.PRNGKey(1))
        Y = np.asarray(st.Y)
        d = np.sqrt(((Y[::8, None] - Y[None, ::8]) ** 2).sum(-1))
        eps = np.quantile(d[d > 0], 0.03)
        _, k = relabel_compact(dbscan(jnp.asarray(Y), float(eps), 5))
        counts[alpha] = k
    assert counts[0.5] >= counts[3.0], counts


def test_higher_dim_embedding_preserves_one_shot():
    """d_ld=8 NE keeps one-shot 1-NN transfer on manifold-mixture data
    (paper Table 2 direction; the paper's gain shows on data where raw
    distances are weak -- on separable synthetics parity is the bar).
    NB: not run on `cells`: NE deliberately fragments major types into
    sub-types (the paper's Fig. 3 behaviour), which hurts *major-label*
    one-shot there by design."""
    from repro.data.synthetic import mnist_like
    X, labels = mnist_like(n=800, dim=64, n_classes=10, seed=0)
    lj = jnp.asarray(labels)
    n = X.shape[0]
    cfg = funcsne.FuncSNEConfig(n_points=n, dim_hd=X.shape[1], dim_ld=8)
    hp = funcsne.default_hparams(n, perplexity=10.0)
    st, _ = funcsne.fit(X, cfg=cfg, n_iter=500, hparams=hp)
    acc_ne = float(one_nn_accuracy(st.Y, lj, jax.random.PRNGKey(2),
                                   n_trials=3, one_shot=True))
    acc_raw = float(one_nn_accuracy(jnp.asarray(X), lj,
                                    jax.random.PRNGKey(2),
                                    n_trials=3, one_shot=True))
    assert acc_ne >= acc_raw - 0.05, (acc_ne, acc_raw)
    assert bool(jnp.isfinite(st.Y).all())

"""Hierarchy extraction units: subsampled DBSCAN-eps selection + the
scan-chunked inner optimisation."""
import numpy as np

from repro.core.hierarchy import extract_hierarchy, select_eps


def _snapshot(n=900, seed=0):
    """Blob-ish 2-D snapshot resembling a mid-optimisation embedding."""
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(6, 2)) * 8.0
    lab = rng.integers(0, 6, n)
    return (centers[lab] + rng.normal(size=(n, 2))).astype(np.float32)


def test_select_eps_subsample_close_to_full_matrix():
    """Regression for the O(N^2) fix: the seeded-subsample quantile must
    stay within tolerance of the full-pairwise-matrix value."""
    Y = _snapshot()
    for q in (0.02, 0.05):
        eps_full = select_eps(Y, q, max_rows=Y.shape[0])
        eps_sub = select_eps(Y, q, max_rows=256)
        assert abs(eps_sub - eps_full) / eps_full < 0.2, (
            q, eps_sub, eps_full)


def test_select_eps_seeded_and_capped():
    Y = _snapshot(seed=3)
    a = select_eps(Y, 0.02, max_rows=128, seed=7)
    b = select_eps(Y, 0.02, max_rows=128, seed=7)
    assert a == b                        # deterministic for a fixed seed
    c = select_eps(Y, 0.02, max_rows=128, seed=8)
    assert a != c                        # and actually subsampled
    assert select_eps(Y, 0.02, max_rows=10 ** 6) > 0   # cap at n rows


def test_select_eps_collapsed_snapshot():
    """A fully collapsed snapshot has no distance scale: return 0 rather
    than crash on an empty quantile."""
    Y = np.zeros((64, 2), np.float32)
    assert select_eps(Y, 0.02, max_rows=32) == 0.0


# --------------------------------------------------------------------------
# Chunked inner optimisation (funcsne §Perf H15 wiring)


def _hierarchy_problem(n=120, dim=8, seed=0, center_std=8.0):
    from repro.data.synthetic import blobs
    X, _ = blobs(n=n, dim=dim, n_centers=3, center_std=center_std,
                 seed=seed)
    return X


def test_extract_hierarchy_chunk_size_invariant():
    """Chunk boundaries are a dispatch-granularity knob, never a numerics
    knob (the driver's bit-exact composition contract): any chunk_size
    must produce the identical cluster graph, labels included."""
    from repro.core import funcsne

    X = _hierarchy_problem()
    kw = dict(alphas=(1.0, 0.6), warmup_iters=25, iters_per_level=20,
              cfg=funcsne.FuncSNEConfig(n_points=120, dim_hd=8, dim_ld=2,
                                        backend="xla"))
    g_a = extract_hierarchy(X, chunk_size=7, **kw)
    g_b = extract_hierarchy(X, chunk_size=50, **kw)
    assert len(g_a.levels) == len(g_b.levels) == 2
    for la, lb in zip(g_a.levels, g_b.levels):
        assert la.n_clusters == lb.n_clusters
        np.testing.assert_array_equal(la.labels, lb.labels)
    assert g_a.edges == g_b.edges


def test_extract_hierarchy_matches_per_step_host_loop():
    """Regression vs the path this replaces: the same sweep driven by
    per-dispatch make_step calls.  Scan vs sequential dispatch agrees to
    fp32 tolerance over short horizons only (ulp drift forks discrete KNN
    choices past ~tens of steps -- the test_chunked_driver contract), so
    this pins a short sweep whose embeddings are tolerance-identical: the
    PCA init of well-separated blobs is already crisply 3-clustered, and
    both paths must produce the SAME labels at every level, ragged chunks
    (6 = 4+2, 5 = 4+1) included."""
    import jax
    import jax.numpy as jnp

    from repro.core import funcsne
    from repro.core.dbscan import dbscan, relabel_compact

    X = _hierarchy_problem(seed=2, center_std=10.0)
    Xj = jnp.asarray(X)
    cfg = funcsne.FuncSNEConfig(n_points=120, dim_hd=8, dim_ld=2,
                                backend="xla")
    hparams = funcsne.default_hparams(120, perplexity=10.0)
    alphas, warmup, per_level, quantile = (1.0, 0.8), 6, 5, 0.05

    # the pre-chunking host loop, verbatim
    st = funcsne.init_state(jax.random.PRNGKey(0), Xj, cfg)
    step = funcsne.make_step(cfg)
    for it in range(warmup):
        hp = funcsne.default_schedule(
            it, warmup, hparams._replace(alpha=jnp.float32(alphas[0])))
        st = step(st, Xj, hp)
    want_levels = []
    for alpha in alphas:
        hp = hparams._replace(alpha=jnp.float32(alpha))
        for _ in range(per_level):
            st = step(st, Xj, hp)
        Y = np.asarray(jax.device_get(st.Y))
        eps = select_eps(Y, quantile, max_rows=1024, seed=0)
        labels, k = relabel_compact(dbscan(Y, eps, 5))
        want_levels.append((k, labels))

    got = extract_hierarchy(X, alphas=alphas, warmup_iters=warmup,
                            iters_per_level=per_level, cfg=cfg,
                            hparams=hparams, eps_quantile=quantile,
                            chunk_size=4)
    assert len(got.levels) == len(want_levels)
    assert got.levels[0].n_clusters >= 3       # the blobs, not one glob
    for lv, (k, labels) in zip(got.levels, want_levels):
        assert lv.n_clusters == k, (lv.n_clusters, k)
        np.testing.assert_array_equal(lv.labels, labels)

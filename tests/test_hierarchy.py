"""Hierarchy extraction units: subsampled DBSCAN-eps selection."""
import numpy as np

from repro.core.hierarchy import select_eps


def _snapshot(n=900, seed=0):
    """Blob-ish 2-D snapshot resembling a mid-optimisation embedding."""
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(6, 2)) * 8.0
    lab = rng.integers(0, 6, n)
    return (centers[lab] + rng.normal(size=(n, 2))).astype(np.float32)


def test_select_eps_subsample_close_to_full_matrix():
    """Regression for the O(N^2) fix: the seeded-subsample quantile must
    stay within tolerance of the full-pairwise-matrix value."""
    Y = _snapshot()
    for q in (0.02, 0.05):
        eps_full = select_eps(Y, q, max_rows=Y.shape[0])
        eps_sub = select_eps(Y, q, max_rows=256)
        assert abs(eps_sub - eps_full) / eps_full < 0.2, (
            q, eps_sub, eps_full)


def test_select_eps_seeded_and_capped():
    Y = _snapshot(seed=3)
    a = select_eps(Y, 0.02, max_rows=128, seed=7)
    b = select_eps(Y, 0.02, max_rows=128, seed=7)
    assert a == b                        # deterministic for a fixed seed
    c = select_eps(Y, 0.02, max_rows=128, seed=8)
    assert a != c                        # and actually subsampled
    assert select_eps(Y, 0.02, max_rows=10 ** 6) > 0   # cap at n rows


def test_select_eps_collapsed_snapshot():
    """A fully collapsed snapshot has no distance scale: return 0 rather
    than crash on an empty quantile."""
    Y = np.zeros((64, 2), np.float32)
    assert select_eps(Y, 0.02, max_rows=32) == 0.0

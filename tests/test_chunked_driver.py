"""Scan-chunked on-device driver: parity, composition, schedule, HLO.

Contract layers (tests/test_scatter_fused.py precedent for what XLA does
and does not guarantee bit-wise):

  * scan vs sequential -- a chunk of T steps runs the *same traced*
    ``funcsne_step`` as T ``make_step`` dispatches, but XLA compiles a
    while-loop body in a different codegen context than straight-line
    code (scatter-add application order, fused-reduction tails), so
    1-ulp differences per step are unavoidable and the KNN merge / gains
    sign logic eventually amplify them.  What must hold over a short
    horizon: every discrete field bit-equal (indices, flags, rng, the
    do_hd/do_sigma cond outcomes they encode) and every float field
    equal to fp32 tolerance.
  * within the chunked stack the driver IS bit-exact: chunk(T1) then
    chunk(T2) == chunk(T1+T2) including the snapshot ring and metrics,
    rerunning a chunk is deterministic, and ``fit`` is invariant to
    ``chunk_size`` bit-for-bit.
  * the device-side schedule evaluates bit-identically traced (from the
    carried ``st.step``) and on host (Python ``it``).
  * HLO: the compiled chunk contains exactly ONE top-level loop, its
    trip count is T, and no host transfer (infeed/outfeed/send/recv)
    exists anywhere in the module -- the per-step host round-trips this
    driver removes cannot silently come back.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import funcsne
from repro.data.synthetic import blobs


def _setup(n=96, dim=9, seed=0, **cfg_kw):
    X, _ = blobs(n=n, dim=dim, n_centers=3, center_std=5.0, seed=seed)
    Xj = jnp.asarray(X)
    kw = dict(n_points=n, dim_hd=dim, backend="xla")
    kw.update(cfg_kw)
    cfg = funcsne.FuncSNEConfig(**kw)
    hp = funcsne.default_hparams(n)
    st0 = funcsne.init_state(jax.random.PRNGKey(seed), Xj, cfg)
    return cfg, st0, Xj, hp


def _copy(st):
    return jax.tree.map(lambda a: jnp.array(a, copy=True), st)


def _assert_states_match(a, b, *, bitwise):
    for name in funcsne.FuncSNEState._fields:
        x, y = np.asarray(getattr(a, name)), np.asarray(getattr(b, name))
        if bitwise or x.dtype.kind != "f":
            np.testing.assert_array_equal(x, y, err_msg=name)
        else:
            finite = np.isfinite(y)
            np.testing.assert_array_equal(finite, np.isfinite(x),
                                          err_msg=name)
            scale = float(np.max(np.abs(y[finite]))) + 1e-9
            np.testing.assert_allclose(x[finite], y[finite], rtol=1e-4,
                                       atol=1e-5 * scale, err_msg=name)


def test_chunked_matches_sequential_with_conds_and_ring():
    """scan-of-T == T sequential make_step calls: discrete state (incl.
    both do_hd/do_sigma cond branches -- sigma_refresh_every=2 fires the
    refresh several times in T=8) bit-equal, float state to fp32
    tolerance, snapshot ring slots == the host loop's device_get points."""
    cfg, st0, Xj, hp = _setup(sigma_refresh_every=2)
    T, every = 8, 3
    step = jax.jit(lambda s, x, h: funcsne.funcsne_step(cfg, s, x, h))
    st_seq = _copy(st0)
    host_snaps = []
    for it in range(T):
        st_seq = step(st_seq, Xj, hp)
        if (it + 1) % every == 0:
            host_snaps.append(np.asarray(jax.device_get(st_seq.Y)))

    chunk = funcsne.make_chunked_step(cfg, T, snapshot_every=every)
    st_c, snaps, metrics = chunk(_copy(st0), Xj, hp)
    _assert_states_match(st_c, st_seq, bitwise=False)
    assert int(metrics.step) == T
    k = int(metrics.n_snapshots)
    assert k == len(host_snaps), (k, len(host_snaps))
    for i in range(k):
        scale = float(np.max(np.abs(host_snaps[i]))) + 1e-9
        np.testing.assert_allclose(np.asarray(snaps[i]), host_snaps[i],
                                   rtol=1e-4, atol=1e-5 * scale)


def test_chunk_composition_and_determinism_bit_exact():
    """chunk(6) then chunk(7) == chunk(13) bit-for-bit -- state, snapshot
    ring and metrics -- and rerunning is deterministic: chunk boundaries
    are a pure dispatch-granularity knob, never a numerics knob."""
    cfg, st0, Xj, hp = _setup()
    every = 4
    c6 = funcsne.make_chunked_step(cfg, 6, snapshot_every=every)
    c7 = funcsne.make_chunked_step(cfg, 7, snapshot_every=every)
    c13 = funcsne.make_chunked_step(cfg, 13, snapshot_every=every)

    s, sn_a, m_a = c6(_copy(st0), Xj, hp)
    s, sn_b, m_b = c7(s, Xj, hp)
    s13, sn_c, m_c = c13(_copy(st0), Xj, hp)
    _assert_states_match(s, s13, bitwise=True)
    ring_split = (list(np.asarray(sn_a[:int(m_a.n_snapshots)]))
                  + list(np.asarray(sn_b[:int(m_b.n_snapshots)])))
    ring_whole = list(np.asarray(sn_c[:int(m_c.n_snapshots)]))
    assert len(ring_split) == len(ring_whole) == 3
    for a, b in zip(ring_split, ring_whole):
        np.testing.assert_array_equal(a, b)
    assert int(m_b.step) == int(m_c.step) == 13

    s13_again, _, _ = c13(_copy(st0), Xj, hp)
    _assert_states_match(s13_again, s13, bitwise=True)


def test_fit_invariant_to_chunk_size_bit_exact():
    """fit(chunk_size=a) == fit(chunk_size=b) bit-for-bit, snapshots
    included (exercises the ragged final chunk: 29 % 8 != 0)."""
    X, _ = blobs(n=80, dim=7, n_centers=3, center_std=5.0, seed=1)
    kw = dict(n_iter=29, snapshot_every=10,
              cfg=funcsne.FuncSNEConfig(n_points=80, dim_hd=7,
                                        backend="xla"))
    st_a, snaps_a = funcsne.fit(X, chunk_size=8, **kw)
    st_b, snaps_b = funcsne.fit(X, chunk_size=29, **kw)
    _assert_states_match(st_a, st_b, bitwise=True)
    assert len(snaps_a) == len(snaps_b) == 2
    for a, b in zip(snaps_a, snaps_b):
        np.testing.assert_array_equal(a, b)


def test_device_schedule_bit_matches_host_schedule():
    """default_schedule(traced it) == default_schedule(python it): the
    on-device schedule uploads nothing and changes nothing."""
    hp = funcsne.default_hparams(500)
    n_iter = 750
    traced = jax.jit(lambda it: funcsne.default_schedule(it, n_iter, hp))
    for it in (0, 1, 187, 188, 300, 749):
        host = funcsne.default_schedule(it, n_iter, hp)
        dev = traced(jnp.int32(it))
        for f in funcsne.HParams._fields:
            np.testing.assert_array_equal(np.asarray(getattr(dev, f)),
                                          np.asarray(getattr(host, f)),
                                          err_msg=f"{f}@it={it}")


def test_chunked_with_schedule_matches_host_scheduled_loop():
    """Chunk with the traced schedule == host loop feeding per-step
    schedule(it) hparams into make_step (discrete bit-equal + fp32)."""
    cfg, st0, Xj, hp = _setup(seed=2)
    T, n_iter = 8, 40
    step = jax.jit(lambda s, x, h: funcsne.funcsne_step(cfg, s, x, h))
    st_seq = _copy(st0)
    for it in range(T):
        st_seq = step(st_seq, Xj, funcsne.default_schedule(it, n_iter, hp))
    chunk = funcsne.make_chunked_step(cfg, T,
                                      schedule=funcsne.default_schedule,
                                      n_iter=n_iter)
    st_c, _, _ = chunk(_copy(st0), Xj, hp)
    _assert_states_match(st_c, st_seq, bitwise=False)


def test_schedule_requires_horizon():
    cfg, _, _, _ = _setup()
    with pytest.raises(ValueError):
        funcsne.make_chunked_step(cfg, 4,
                                  schedule=funcsne.default_schedule)


def test_chunked_hlo_one_scan_no_host_transfers():
    """The compiled chunk is ONE device program: exactly one top-level
    while whose trip count is T (the scan), and no infeed / outfeed /
    send / recv anywhere -- the per-step host dispatches and device_get
    round-trips the driver removes are structurally absent."""
    from repro.launch.hlo_analysis import analyze

    cfg, st0, Xj, hp = _setup()
    T = 17
    fn = funcsne._chunk_fn(cfg, T, schedule=funcsne.default_schedule,
                           n_iter=100, snapshot_every=5)
    text = jax.jit(fn).lower(st0, Xj, hp).compile().as_text()
    top = [l for l in analyze(text).loops if l["depth"] == 0]
    assert len(top) == 1, top
    assert top[0]["trip"] == T, top
    for marker in ("infeed", "outfeed", " send(", " recv("):
        assert not any(marker in line for line in text.splitlines()), marker


def test_chunk_metrics_sync_once_per_chunk():
    """ChunkMetrics carries everything a driver/GUI needs from one sync:
    global step, ring occupancy, EMA'd displacement, zhat, refresh EMA."""
    cfg, st0, Xj, hp = _setup()
    chunk = funcsne.make_chunked_step(cfg, 10, snapshot_every=4)
    st, snaps, m = chunk(_copy(st0), Xj, hp)
    assert int(m.step) == 10 and int(st.step) == 10
    assert int(m.n_snapshots) == 2 and snaps.shape[0] == 10 // 4 + 1
    assert np.isfinite(float(m.disp_ema)) and float(m.disp_ema) > 0.0
    np.testing.assert_array_equal(np.asarray(m.zhat), np.asarray(st.zhat))
    np.testing.assert_array_equal(np.asarray(m.ema_new_frac),
                                  np.asarray(st.ema_new_frac))

    st2, _, m2 = chunk(st, Xj, hp)
    assert int(m2.step) == 20


def test_fit_early_stop_halts_converged_run():
    """First ChunkMetrics consumer: with lr=0 the embedding cannot move
    (vel stays 0 -> disp_ema == 0), so fit(early_stop=...) must stop
    after the first chunk instead of burning the remaining dispatches."""
    X, _ = blobs(n=64, dim=6, n_centers=2, center_std=5.0, seed=4)
    cfg = funcsne.FuncSNEConfig(n_points=64, dim_hd=6, backend="xla")
    hp = funcsne.default_hparams(64)._replace(lr=jnp.float32(0.0))
    st, _ = funcsne.fit(X, cfg=cfg, n_iter=60, hparams=hp,
                        schedule=lambda it, n, h: h,   # keep lr pinned at 0
                        chunk_size=10, early_stop=1e-9)
    assert int(st.step) == 10, int(st.step)     # stopped after one chunk


def test_fit_early_stop_lets_moving_run_finish():
    """A run that is still moving must never trip an (absurdly small)
    threshold -- and early_stop=None must not change behaviour at all."""
    X, _ = blobs(n=64, dim=6, n_centers=2, center_std=5.0, seed=4)
    cfg = funcsne.FuncSNEConfig(n_points=64, dim_hd=6, backend="xla")
    st, _ = funcsne.fit(X, cfg=cfg, n_iter=40, chunk_size=10,
                        early_stop=1e-30)
    assert int(st.step) == 40, int(st.step)
    st_none, _ = funcsne.fit(X, cfg=cfg, n_iter=40, chunk_size=10)
    _assert_states_match(st_none, st, bitwise=True)


def test_fit_early_stop_host_loop_fallback():
    """Host-only schedules (Python control flow on ``it``) route through
    the per-step host loop; early_stop must work there too via the
    mirrored displacement EMA."""
    X, _ = blobs(n=48, dim=5, n_centers=2, center_std=5.0, seed=5)
    cfg = funcsne.FuncSNEConfig(n_points=48, dim_hd=5, backend="xla")
    hp = funcsne.default_hparams(48)._replace(lr=jnp.float32(0.0))

    def host_schedule(it, n, h):          # int(it): host loop required
        return h if int(it) >= 0 else h

    st, _ = funcsne.fit(X, cfg=cfg, n_iter=30, hparams=hp,
                        schedule=host_schedule, early_stop=1e-9)
    assert int(st.step) < 30, int(st.step)


@pytest.mark.slow
def test_chunked_trajectory_statistically_equivalent_long_horizon():
    """Over 60 steps the ulp-level codegen differences fork discrete KNN
    choices (see module docstring), so the long-horizon contract is the
    trajectory-equivalence one: same Z estimator, same embedding scale,
    finite everywhere."""
    cfg, st0, Xj, hp = _setup(n=128, seed=3)
    T = 60
    step = jax.jit(lambda s, x, h: funcsne.funcsne_step(cfg, s, x, h))
    st_seq = _copy(st0)
    for _ in range(T):
        st_seq = step(st_seq, Xj, hp)
    st_c, _, _ = funcsne.make_chunked_step(cfg, T)(_copy(st0), Xj, hp)
    assert bool(jnp.isfinite(st_c.Y).all())
    np.testing.assert_allclose(float(st_c.zhat), float(st_seq.zhat),
                               rtol=0.02)
    np.testing.assert_allclose(float(jnp.std(st_c.Y)),
                               float(jnp.std(st_seq.Y)), rtol=0.1)

"""Resilient runtime: health telemetry, rollback/retry, checkpoint-resume,
sticky kernel fallback, fault injection, and input validation.

The recovery contracts pinned here are the ones ISSUE 6 promises:
  * injected NaN chunk -> telemetry trip -> rollback + backoff -> a fully
    finite final embedding (and a structured event log saying so);
  * persistent divergence -> bounded retries -> EmbeddingDiverged;
  * kill-and-resume through the Checkpointer is bit-deterministic;
  * injected Pallas launch failure -> sticky XLA demotion whose output is
    bit-identical to a run with the family demoted up front;
  * a clean run under a ResiliencePolicy is bit-identical to one without.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import funcsne
from repro.core.funcsne import FuncSNEConfig
from repro.core.resilience import EmbeddingDiverged, ResiliencePolicy
from repro.kernels import fallback
from repro.runtime import faults
from repro.runtime.faults import (FaultScript, KernelLaunchFault, NaNChunk,
                                  Preempted, Preemption)

N, DIM = 48, 5


def _data(n=N, dim=DIM, seed=0):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(2, dim)) * 5.0
    X = centers[rng.integers(0, 2, size=n)] + rng.normal(size=(n, dim))
    return jnp.asarray(X, jnp.float32)


def _cfg(n=N, dim=DIM, **kw):
    kw.setdefault("backend", "xla")
    kw.setdefault("n_negatives", 4)
    kw.setdefault("k_hd", min(32, n // 2))
    kw.setdefault("k_ld", min(16, n // 4))
    return FuncSNEConfig(n_points=n, dim_hd=dim, **kw)


def _assert_state_equal(a, b):
    for name in a._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(a, name)), np.asarray(getattr(b, name)),
            err_msg=f"state field {name!r} differs")


# ---------------------------------------------------------------------------
# On-device health telemetry (tentpole part 1)


def test_health_metrics_healthy_run():
    X, cfg = _data(), _cfg()
    hp = funcsne.default_hparams(N)
    st = funcsne.init_state(jax.random.PRNGKey(0), X, cfg)
    _, _, m = funcsne.make_chunked_step(cfg, 4)(st, X, hp)
    assert float(m.finite_frac) == 1.0
    assert float(m.y_max_abs) > 0.0
    assert int(m.bad_step) == -1


def test_health_metrics_flag_nan_and_first_bad_step():
    X, cfg = _data(), _cfg()
    hp = funcsne.default_hparams(N)
    st = funcsne.init_state(jax.random.PRNGKey(0), X, cfg)
    st = st._replace(Y=st.Y.at[0].set(jnp.nan))
    _, _, m = funcsne.make_chunked_step(cfg, 4)(st, X, hp)
    assert float(m.finite_frac) < 1.0
    assert int(m.bad_step) == 0          # poisoned before the first step
    # the max-|Y| probe must ignore the non-finite entries it reports
    assert np.isfinite(float(m.y_max_abs))


def test_policy_check_trips_and_fails_closed():
    p = ResiliencePolicy()
    healthy = {"finite_frac": 1.0, "y_max_abs": 3.0, "bad_step": -1}

    class M:
        def __init__(self, **kw):
            self.__dict__.update(kw)

    assert p.check(M(**healthy)) is None
    assert "non-finite" in p.check(M(**{**healthy, "finite_frac": 0.9,
                                        "bad_step": 7}))
    assert "explosion" in p.check(M(**{**healthy, "y_max_abs": 1e12}))
    # NaN telemetry must trip, not pass, every comparison
    assert p.check(M(**{**healthy, "finite_frac": float("nan")})) is not None
    assert p.check(M(**{**healthy, "y_max_abs": float("nan")})) is not None


# ---------------------------------------------------------------------------
# Rollback-and-retry (tentpole part 2)


def test_nan_fault_rollback_recovers():
    X, cfg = _data(), _cfg()
    policy = ResiliencePolicy(max_retries=2)
    with faults.active(FaultScript(NaNChunk(at_step=4))):
        st, _ = funcsne.fit(X, cfg=cfg, n_iter=12, chunk_size=4,
                            resilience=policy)
    assert bool(jnp.isfinite(st.Y).all())
    assert int(st.step) == 12
    rollbacks = [e for e in policy.events if e["kind"] == "rollback"]
    assert len(rollbacks) == 1
    assert rollbacks[0]["lr_scale"] == pytest.approx(0.5)
    assert "non-finite" in rollbacks[0]["reason"]


def test_persistent_divergence_exhausts_retries():
    X, cfg = _data(), _cfg()
    policy = ResiliencePolicy(max_retries=2)
    with faults.active(FaultScript(NaNChunk(at_step=0, once=False))):
        with pytest.raises(EmbeddingDiverged) as ei:
            funcsne.fit(X, cfg=cfg, n_iter=8, chunk_size=4,
                        resilience=policy)
    assert ei.value.retries == 2
    assert ei.value.step == 0
    kinds = [e["kind"] for e in policy.events]
    assert kinds.count("rollback") == 2 and "giving_up" in kinds


def test_clean_run_under_policy_is_bit_identical():
    X, cfg = _data(), _cfg()
    kw = dict(cfg=cfg, n_iter=8, chunk_size=4)
    st_plain, _ = funcsne.fit(X, **kw)
    policy = ResiliencePolicy()
    st_pol, _ = funcsne.fit(X, resilience=policy, **kw)
    _assert_state_equal(st_plain, st_pol)
    assert policy.events == []


# ---------------------------------------------------------------------------
# Checkpoint / preemption / resume (tentpole part 2, satellite d)


def test_preempt_and_resume_is_bit_identical(tmp_path):
    X, cfg = _data(), _cfg()
    kw = dict(cfg=cfg, n_iter=12, chunk_size=4)
    st_ref, _ = funcsne.fit(X, **kw)

    ckdir = str(tmp_path / "ck")
    with faults.active(FaultScript(Preemption(at_step=8))):
        with pytest.raises(Preempted) as ei:
            funcsne.fit(X, resilience=ResiliencePolicy(
                checkpoint_dir=ckdir), **kw)
    assert ei.value.step == 8
    st_res, _ = funcsne.fit(X, resume_from=ckdir, resilience=ResiliencePolicy(
        checkpoint_dir=ckdir), **kw)
    assert int(st_res.step) == 12
    _assert_state_equal(st_ref, st_res)


def test_resume_restores_backoff_scales(tmp_path):
    """lr/exaggeration backoff survives a kill: the scales ride in the
    checkpoint metadata, so a resumed run keeps the demoted trust."""
    X, cfg = _data(), _cfg()
    ckdir = str(tmp_path / "ck")
    policy = ResiliencePolicy(checkpoint_dir=ckdir, max_retries=2)
    with faults.active(FaultScript(NaNChunk(at_step=4),
                                   Preemption(at_step=8))):
        with pytest.raises(Preempted):
            funcsne.fit(X, cfg=cfg, n_iter=12, chunk_size=4,
                        resilience=policy)
    from repro.checkpoint import Checkpointer
    _, meta = Checkpointer(ckdir).restore(
        funcsne.init_state(jax.random.PRNGKey(0), X, cfg))
    assert meta["lr_scale"] == pytest.approx(0.5)


def test_fit_surfaces_async_checkpoint_failure(tmp_path, monkeypatch):
    X, cfg = _data(), _cfg()
    import repro.checkpoint.checkpointer as ckm

    def boom(*a, **kw):
        raise OSError("disk full (injected)")

    monkeypatch.setattr(ckm.np, "savez", boom)
    with pytest.raises(OSError, match="disk full"):
        funcsne.fit(X, cfg=cfg, n_iter=8, chunk_size=4,
                    resilience=ResiliencePolicy(
                        checkpoint_dir=str(tmp_path / "ck")))


# ---------------------------------------------------------------------------
# Sticky kernel fallback (tentpole part 3)


def test_guarded_passthrough_when_disabled():
    fallback.reset()

    def boom():
        raise RuntimeError("lowering failed")

    with pytest.raises(RuntimeError, match="lowering failed"):
        fallback.guarded("fam_test", boom, lambda: "ref")
    assert not fallback.is_demoted("fam_test")


def test_guarded_demotes_sticky_when_enabled():
    fallback.reset()
    calls = {"pallas": 0}

    def boom():
        calls["pallas"] += 1
        raise RuntimeError("lowering failed")

    try:
        with fallback.enabled():
            assert fallback.guarded("fam_test", boom, lambda: "ref") == "ref"
            assert fallback.guarded("fam_test", boom, lambda: "ref") == "ref"
        assert calls["pallas"] == 1          # sticky: no second launch try
        assert fallback.is_demoted("fam_test")
        (ev,) = fallback.events()
        assert ev["kind"] == "kernel_demoted" and ev["family"] == "fam_test"
    finally:
        fallback.reset()


def test_kernel_fault_demotes_and_matches_predemoted_run():
    n = 32
    X, cfg = _data(n=n), _cfg(n=n, backend="interpret")
    kw = dict(cfg=cfg, n_iter=4, chunk_size=2)
    try:
        fallback.reset()
        policy = ResiliencePolicy()
        with faults.active(FaultScript(KernelLaunchFault("knn_merge"))):
            st_fault, _ = funcsne.fit(X, resilience=policy, **kw)
        assert "knn_merge" in fallback.demotions()
        assert any(e["kind"] == "kernel_demoted" for e in policy.events)

        fallback.reset()
        with pytest.warns(RuntimeWarning):
            fallback.demote("knn_merge", "pre-demoted (parity reference)")
        with fallback.enabled():
            st_ref, _ = funcsne.fit(X, resilience=ResiliencePolicy(), **kw)
        _assert_state_equal(st_fault, st_ref)
    finally:
        fallback.reset()


def test_fallback_registry_is_thread_safe_under_churn():
    """Two threads hammer the registry -- one demoting/noting fresh
    families, one reading events()/demotions()/is_demoted() -- while the
    readers iterate snapshots.  Before the lock fix the readers copied
    the shared dict/list WHILE the writer appended (a genuine race:
    `dict(_DEMOTED)` and `list(_EVENTS[...])` iterate the live
    containers outside _LOCK); this drives it hard enough to blow up
    with RuntimeError('dictionary changed size during iteration') under
    the old code."""
    import threading
    import warnings as _w

    fallback.reset()
    stop = threading.Event()
    errors = []

    def writer():
        try:
            with _w.catch_warnings():
                _w.simplefilter("ignore", RuntimeWarning)
                i = 0
                while not stop.is_set():
                    fallback.demote(f"fam_{i}", "stress")
                    fallback.note(f"fam_{i}", f"reason_{i}")
                    i += 1
        except Exception as e:          # pragma: no cover - fail surface
            errors.append(e)

    def reader():
        try:
            while not stop.is_set():
                for ev in fallback.events():
                    assert "kind" in ev
                d = fallback.demotions()
                assert all(isinstance(r, str) for r in d.values())
                fallback.is_demoted("fam_0")
                fallback.n_events()
                fallback.is_enabled()
        except Exception as e:          # pragma: no cover - fail surface
            errors.append(e)

    threads = [threading.Thread(target=writer),
               threading.Thread(target=reader)]
    try:
        for t in threads:
            t.start()
        time.sleep(1.0)
    finally:
        stop.set()
        for t in threads:
            t.join()
        fallback.reset()
    assert not errors, errors


# ---------------------------------------------------------------------------
# Threshold semantics parity (satellite c)


def test_early_stop_units_match_host_loop():
    """The chunked driver's normalised disp_ema at T=1 IS the host loop's
    per-step displacement: thresholds read in the same units on both."""
    X, cfg = _data(), _cfg()
    hp = funcsne.default_hparams(N)
    st = funcsne.init_state(jax.random.PRNGKey(0), X, cfg)
    st1, _, m = funcsne.make_chunked_step(cfg, 1)(st, X, hp)
    disp_norm = float(m.disp_ema) / (1.0 - funcsne._METRICS_DECAY)
    n_act = max(float(jnp.sum(st1.active.astype(jnp.float32))), 1.0)
    act_disp = float(jnp.sum(
        jnp.abs(st1.vel) * st1.active[:, None].astype(jnp.float32))) \
        / (n_act * cfg.dim_ld)
    assert disp_norm == pytest.approx(act_disp, rel=1e-5)


def test_early_stop_threshold_is_chunk_size_invariant():
    """A converged run (lr=0 -> zero displacement) stops at the first
    chunk whatever the chunk size; a live run never trips a 0 threshold."""
    X, cfg = _data(), _cfg()
    hp = funcsne.default_hparams(N)._replace(lr=jnp.float32(0.0))
    for cs in (2, 5):
        st, _ = funcsne.fit(X, cfg=cfg, n_iter=10, chunk_size=cs,
                            hparams=hp, early_stop=1e-9,
                            schedule=lambda it, n, h: h)
        assert int(st.step) == cs
    st, _ = funcsne.fit(X, cfg=cfg, n_iter=10, chunk_size=5,
                        early_stop=0.0)
    assert int(st.step) == 10


# ---------------------------------------------------------------------------
# Input validation (satellite b)


def test_validate_rejects_bad_ndim_dtype_shape():
    cfg = _cfg(n=16, dim=4)
    with pytest.raises(ValueError, match="2-D"):
        funcsne.validate_inputs(jnp.zeros((16,)), cfg)
    with pytest.raises(ValueError, match="real-numeric"):
        funcsne.validate_inputs(jnp.zeros((16, 4), jnp.complex64), cfg)
    with pytest.raises(ValueError, match="does not match cfg"):
        funcsne.validate_inputs(jnp.zeros((16, 5)), cfg)


def test_validate_rejects_k_ge_n():
    cfg = FuncSNEConfig(n_points=16, dim_hd=4, k_hd=16, backend="xla")
    with pytest.raises(ValueError, match="k_hd"):
        funcsne.validate_inputs(jnp.zeros((16, 4)), cfg)


def test_validate_counts_nonfinite_rows():
    cfg = _cfg(n=16, dim=4)
    X = np.zeros((16, 4), np.float32)
    X[3, 0] = np.nan
    X[7, 2] = np.inf
    with pytest.raises(ValueError, match="2 row"):
        funcsne.validate_inputs(jnp.asarray(X), cfg)
    with pytest.raises(ValueError, match="non-finite"):
        funcsne.fit(jnp.asarray(X), cfg=cfg, n_iter=1)
    # opt-out keeps the old behaviour for callers who sanitise upstream
    funcsne.validate_inputs(jnp.asarray(X), cfg, check_finite=False)


def test_init_state_validates_and_can_opt_out():
    cfg = _cfg(n=16, dim=4)
    with pytest.raises(ValueError, match="does not match cfg"):
        funcsne.init_state(jax.random.PRNGKey(0), jnp.zeros((16, 5)), cfg)
    st = funcsne.init_state(jax.random.PRNGKey(0),
                            jnp.zeros((16, 5))[:, :4], cfg, validate=False)
    assert st.Y.shape == (16, 2)


# ---------------------------------------------------------------------------
# fit() surface contracts


def test_host_only_schedule_rejects_resilience():
    X, cfg = _data(n=16, dim=4), _cfg(n=16, dim=4)

    def host_schedule(it, n_iter, hp):     # needs a Python int
        return hp if int(it) < 2 else hp._replace(lr=hp.lr * 0.5)

    with pytest.raises(ValueError, match="traceable schedule"):
        funcsne.fit(X, cfg=cfg, n_iter=4, schedule=host_schedule,
                    resilience=ResiliencePolicy())


def test_fit_state_continuation():
    X, cfg = _data(), _cfg()
    ident = lambda it, n, hp: hp
    kw = dict(cfg=cfg, chunk_size=4, schedule=ident)
    st_full, _ = funcsne.fit(X, n_iter=8, **kw)
    st_half, _ = funcsne.fit(X, n_iter=4, **kw)
    st_cont, _ = funcsne.fit(X, n_iter=4, state=st_half, **kw)
    _assert_state_equal(st_full, st_cont)


# ---------------------------------------------------------------------------
# Chunk-boundary state auditor in the fit loop (ISSUE 9)


def test_audit_trips_rollback_in_fit_and_control_misses():
    """Finite index corruption is invisible to the NaN probes; with
    audit_every it trips the EXISTING rollback path, without it the
    damage survives to the final state (the positive control)."""
    from repro.runtime.faults import IndexCorruption

    X, cfg = _data(), _cfg()
    kw = dict(cfg=cfg, n_iter=16, chunk_size=4)

    policy = ResiliencePolicy(max_retries=2, audit_every=1)
    with faults.active(FaultScript(IndexCorruption(at_step=8))):
        st, _ = funcsne.fit(X, resilience=policy, **kw)
    kinds = [e["kind"] for e in policy.events]
    assert "audit_violation" in kinds and "rollback" in kinds, kinds
    assert int(st.step) == 16
    res = jax.device_get(funcsne.audit_state(st, cfg, X))
    assert policy.audit_check(res) is None

    ctrl = ResiliencePolicy(max_retries=2, audit_every=0)
    with faults.active(FaultScript(IndexCorruption(at_step=8))):
        st0, _ = funcsne.fit(X, resilience=ctrl, **kw)
    assert "rollback" not in [e["kind"] for e in ctrl.events]
    res0 = jax.device_get(funcsne.audit_state(st0, cfg, X))
    assert ctrl.audit_check(res0) is not None


def test_clean_run_with_audit_is_bit_identical():
    """Auditing is read-only: a clean run with audit_every=1 matches the
    no-policy run bit for bit (same guarantee as the health probes)."""
    X, cfg = _data(), _cfg()
    kw = dict(cfg=cfg, n_iter=8, chunk_size=4)
    st_plain, _ = funcsne.fit(X, **kw)
    policy = ResiliencePolicy(audit_every=1)
    st_aud, _ = funcsne.fit(X, resilience=policy, **kw)
    _assert_state_equal(st_plain, st_aud)
    assert not [e for e in policy.events
                if e["kind"] in ("rollback", "audit_violation")]


# ---------------------------------------------------------------------------
# Straggler-alarm escalation: early checkpoint (ISSUE 9 satellite)


def test_straggler_alarm_triggers_early_checkpoint(tmp_path):
    """With the checkpoint cadence effectively off, every alarm must
    still commit the just-advanced boundary (straggler.py's contract:
    a kill after an alarm loses at most one chunk)."""
    from repro.checkpoint import Checkpointer

    X, cfg = _data(), _cfg()
    # hang_timeout=0 makes every chunk dispatch an alarm; cadence 1000
    # means every committed boundary below is escalation-only
    policy = ResiliencePolicy(checkpoint_dir=str(tmp_path),
                              checkpoint_every=1000,
                              hang_timeout=0.0, straggler_warmup=0)
    st, _ = funcsne.fit(X, cfg=cfg, n_iter=16, chunk_size=4,
                        resilience=policy)
    kinds = [e["kind"] for e in policy.events]
    assert kinds.count("early_checkpoint") == 4, kinds
    ck = Checkpointer(tmp_path)
    assert ck.latest_step() == 16
    # the escalated boundary is a real, verified, resumable checkpoint
    st_res, _ = funcsne.fit(X, cfg=cfg, n_iter=16, chunk_size=4,
                            resilience=ResiliencePolicy(),
                            resume_from=str(tmp_path))
    _assert_state_equal(st, st_res)

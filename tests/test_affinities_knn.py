"""Perplexity calibration + iterative-KNN machinery unit/property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import affinities, knn as knn_lib
from repro.core.knn import SENTINEL
from repro.core.nnd import NNDConfig, nnd
from repro.data.synthetic import blobs, disjoint_blobs


def test_solve_beta_hits_target_entropy():
    rng = np.random.default_rng(0)
    d2 = jnp.asarray(np.sort(rng.random((64, 40)).astype(np.float32) * 10))
    for perp in (5.0, 15.0, 30.0):
        beta = affinities.solve_beta(d2, perp)
        h = affinities.entropy_of_beta(d2, beta, jnp.isfinite(d2))
        np.testing.assert_allclose(np.asarray(h), np.log(perp), atol=2e-3)


def test_solve_beta_warm_start_consistent():
    rng = np.random.default_rng(1)
    d2 = jnp.asarray(rng.random((32, 24)).astype(np.float32))
    cold = affinities.solve_beta(d2, 10.0)
    warm = affinities.solve_beta(d2, 10.0, beta0=cold, n_iter=8)
    np.testing.assert_allclose(np.asarray(warm), np.asarray(cold), rtol=0.05)


def test_entropy_monotone_in_beta():
    rng = np.random.default_rng(2)
    d2 = jnp.asarray(rng.random((8, 16)).astype(np.float32))
    valid = jnp.isfinite(d2)
    hs = [float(affinities.entropy_of_beta(d2, jnp.full((8,), b),
                                           valid).mean())
          for b in (0.1, 1.0, 10.0, 100.0)]
    assert hs == sorted(hs, reverse=True)


def test_p_rows_normalised_and_masked():
    d2 = jnp.asarray([[0.1, 0.2, jnp.inf, 0.3]])
    p = affinities.p_rows(d2, jnp.ones((1,)))
    assert float(p[0, 2]) == 0.0
    np.testing.assert_allclose(float(p.sum()), 1.0, rtol=1e-6)


@settings(max_examples=30, deadline=None)
@given(n=st.integers(12, 64), k=st.integers(2, 8), c=st.integers(1, 10),
       seed=st.integers(0, 10_000))
def test_merge_knn_invariants(n, k, c, seed):
    """Merged lists are sorted, self-free, duplicate-free, and no worse
    than before (distances can only shrink)."""
    rng = np.random.default_rng(seed)
    rows = np.arange(n, dtype=np.int32)
    # distinct-per-row current lists (the init_knn_idx invariant)
    cur_idx = np.stack([rng.permutation(np.delete(np.arange(n), i))[:k]
                        for i in range(n)]).astype(np.int32)
    cur_d = np.sort(rng.random((n, k)).astype(np.float32), axis=1)
    cand = rng.integers(0, n, (n, c)).astype(np.int32)
    cand_d = rng.random((n, c)).astype(np.float32)
    valid = knn_lib.dedup_candidates(jnp.asarray(rows), jnp.asarray(cur_idx),
                                     jnp.asarray(cand))
    new_idx, new_d, improved = knn_lib.merge_knn(
        jnp.asarray(cur_idx), jnp.asarray(cur_d), jnp.asarray(cand),
        jnp.asarray(cand_d), valid)
    new_idx, new_d = np.asarray(new_idx), np.asarray(new_d)
    assert (np.diff(new_d, axis=1) >= 0).all()          # sorted
    assert (new_d <= cur_d + 1e-7).all()                # monotone improvement
    assert not (new_idx == rows[:, None]).any()         # no self
    for i in range(n):                                  # no dupes among finite
        fin = new_idx[i][np.isfinite(new_d[i])]
        assert len(set(fin.tolist())) == len(fin)


def test_dedup_rejects_existing_and_self():
    rows = jnp.arange(4, dtype=jnp.int32)
    cur = jnp.asarray([[1, 2], [0, 2], [0, 1], [0, 1]], jnp.int32)
    cand = jnp.asarray([[0, 1, 3], [1, 3, 3], [2, 3, 0], [3, 2, 2]],
                       jnp.int32)
    valid = np.asarray(knn_lib.dedup_candidates(rows, cur, cand))
    assert not valid[0, 0]      # self
    assert not valid[0, 1]      # already a neighbour
    assert valid[0, 2]
    assert valid[1, 1] and not valid[1, 2]   # duplicate within candidates
    assert not valid[3, 0]      # self


def test_reverse_neighbors_contains_true_reverse_edges():
    idx = jnp.asarray([[1, 2], [2, 3], [3, 0], [0, 1]], jnp.int32)
    rev = np.asarray(knn_lib.reverse_neighbors(idx, 4, 3,
                                               jax.random.PRNGKey(0)))
    # point 0 is listed by 2 and 3
    assert {2, 3} <= set(rev[0].tolist()) | {2, 3}
    for tgt in range(4):
        srcs = {s for s in range(4) if tgt in np.asarray(idx[s])}
        assert srcs & set(rev[tgt].tolist())


def test_exact_knn_correct():
    X, _ = blobs(n=100, dim=4, seed=3)
    idx, d = knn_lib.exact_knn(jnp.asarray(X), 5)
    d_full = ((X[:, None, :] - X[None, :, :]) ** 2).sum(-1)
    np.fill_diagonal(d_full, np.inf)
    want = np.argsort(d_full, axis=1)[:, :5]
    got_sets = [set(r.tolist()) for r in np.asarray(idx)]
    want_sets = [set(r.tolist()) for r in want]
    same = sum(g == w for g, w in zip(got_sets, want_sets))
    assert same >= 97   # ties may permute a couple of sets


def test_nnd_converges_on_overlapping_blobs():
    X, _ = blobs(n=400, dim=16, n_centers=5, center_std=1.0, blob_std=1.0,
                 seed=0)
    idx, d, hist = nnd(X, NNDConfig(k=10, backend="xla"), max_iter=50)
    from repro.core.quality import knn_set_quality
    q = float(knn_set_quality(idx, jnp.asarray(X)))
    assert q > 0.95, q


def test_nnd_gather_fused_bit_equivalent_to_pregather():
    """The nnd.py port onto the index-taking pairwise_sqdist_gather kernel
    is a pure data-path change: init + steps must match the legacy
    pre-gather wiring bit-for-bit on the XLA backend."""
    import dataclasses

    import jax
    from repro.core.nnd import nnd_init, nnd_step

    X, _ = blobs(n=150, dim=12, n_centers=4, seed=9)
    Xj = jnp.asarray(X)
    cfg_g = NNDConfig(k=8, c_fwd=4, c_rev=2, backend="xla",
                      gather_fused=True)
    cfg_l = dataclasses.replace(cfg_g, gather_fused=False)
    rng = jax.random.PRNGKey(0)

    def run(cfg):
        idx, d = nnd_init(rng, Xj, cfg)
        fracs = []
        for it in range(5):
            idx, d, frac = nnd_step(jax.random.fold_in(rng, it), Xj, idx, d,
                                    cfg)
            fracs.append(float(frac))
        return np.asarray(idx), np.asarray(d), fracs

    idx_g, d_g, f_g = run(cfg_g)
    idx_l, d_l, f_l = run(cfg_l)
    np.testing.assert_array_equal(idx_g, idx_l)
    np.testing.assert_array_equal(d_g, d_l)
    assert f_g == f_l


def test_nnd_struggles_on_disjoint_blobs():
    """Paper Fig. 7: the greedy local join stalls on isolated clusters."""
    X, _ = disjoint_blobs(n=600, dim=16, n_centers=100, seed=0)
    idx, d, hist = nnd(X, NNDConfig(k=5, c_rev=0, backend="xla"),
                       max_iter=12)
    from repro.core.quality import knn_set_quality
    q = float(knn_set_quality(idx, jnp.asarray(X)))
    assert q < 0.9      # it should NOT fully solve this one quickly

"""Per-arch smoke tests + decode/prefill consistency + layer unit tests."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES, get_arch, list_archs, smoke_variant
from repro.models import mamba2 as mamba_lib
from repro.models import moe as moe_lib
from repro.models.common import cross_entropy_chunked
from repro.models.transformer import LMModel

ARCHS = list_archs()


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_forward_and_grad(arch):
    """Assignment requirement: reduced same-family config, one train step
    on CPU, output shapes + no NaNs."""
    cfg = smoke_variant(get_arch(arch))
    model = LMModel(cfg)
    p = model.init_params(jax.random.PRNGKey(1))
    B, S = 2, 64
    rx, ry = jax.random.split(jax.random.PRNGKey(7))
    if cfg.input_mode == "tokens":
        x = jax.random.randint(rx, (B, S), 0, cfg.vocab_size)
    else:
        x = jax.random.normal(rx, (B, S, cfg.d_model))
    y = jax.random.randint(ry, (B, S), 0, cfg.vocab_size)
    (loss, metrics), grads = jax.value_and_grad(
        model.loss_and_aux, has_aux=True)(p, x, y)
    assert bool(jnp.isfinite(loss)), arch
    assert all(bool(jnp.isfinite(g).all()) for g in jax.tree.leaves(grads))
    h = model.hidden_states(p, x)
    assert h.shape == (B, S, cfg.d_model)
    logits, _ = model.serve_step(p, model.init_cache(B, 8),
                                 x[:, :1] if cfg.input_mode == "tokens"
                                 else x[:, :1, :], jnp.int32(1))
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ["qwen2-7b", "gemma2-2b", "mamba2-130m",
                                  "zamba2-2.7b", "olmoe-1b-7b",
                                  "deepseek-v2-236b", "musicgen-large"])
def test_decode_matches_prefill(arch):
    """Teacher-forced decode must reproduce the training forward logits
    (validates KV caches, RoPE offsets, masks, SSM states)."""
    cfg = smoke_variant(get_arch(arch))
    cfg = dataclasses.replace(cfg, capacity_factor=8.0)   # no MoE drops
    model = LMModel(cfg)
    p = model.init_params(jax.random.PRNGKey(1))
    B, S = 2, 24
    if cfg.input_mode == "tokens":
        x = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0,
                               cfg.vocab_size)
        step_in = lambda t: x[:, t:t + 1]
    else:
        x = jax.random.normal(jax.random.PRNGKey(3), (B, S, cfg.d_model))
        step_in = lambda t: x[:, t:t + 1, :]
    h = model.hidden_states(p, x)
    full = model._logits_fn(p)(h).astype(jnp.float32)
    if cfg.final_softcap:
        full = cfg.final_softcap * jnp.tanh(full / cfg.final_softcap)
    cache = model.init_cache(B, S)
    outs = []
    for t in range(S):
        lg, cache = model.serve_step(p, cache, step_in(t), jnp.int32(t + 1))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1).astype(jnp.float32)
    scale = float(jnp.max(jnp.abs(full))) + 1e-9
    assert float(jnp.max(jnp.abs(dec - full))) / scale < 2e-2, arch


def test_ssd_chunked_equals_reference():
    rng = jax.random.PRNGKey(0)
    Bb, S, H, P, N = 2, 96, 4, 8, 16
    ks = jax.random.split(rng, 5)
    xh = jax.random.normal(ks[0], (Bb, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bb, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (Bb, S, N)) * 0.5
    Cm = jax.random.normal(ks[4], (Bb, S, N)) * 0.5
    for chunk in (16, 32, 96):
        y1 = mamba_lib._ssd_chunk_scan(xh, dt, A, Bm, Cm, jnp.ones((H,)),
                                       chunk=chunk)
        y2 = mamba_lib.ssd_reference(xh, dt, A, Bm, Cm, jnp.ones((H,)))
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=1e-4, atol=1e-4)


def test_moe_routes_all_tokens_with_ample_capacity():
    cfg = dataclasses.replace(smoke_variant(get_arch("olmoe-1b-7b")),
                              capacity_factor=8.0)
    p = moe_lib.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, cfg.d_model))
    out, aux = moe_lib.moe_apply(p, x, cfg, LMModel(cfg).ctx)
    assert float(aux["dropped_frac"]) == 0.0
    assert out.shape == x.shape
    # load-balance loss is ~1 for a (near) uniform random router
    assert 0.8 < float(aux["load_balance"]) < 1.6


def test_moe_capacity_drops_tokens():
    cfg = dataclasses.replace(smoke_variant(get_arch("olmoe-1b-7b")),
                              capacity_factor=0.05)
    p = moe_lib.init_moe(jax.random.PRNGKey(0), cfg)
    # enough tokens that per-expert load exceeds the 128-rounded capacity
    x = jax.random.normal(jax.random.PRNGKey(1), (8192, cfg.d_model))
    _, aux = moe_lib.moe_apply(p, x, cfg, LMModel(cfg).ctx)
    assert float(aux["dropped_frac"]) > 0.1


def test_gemma2_softcap_bounds_logits():
    cfg = smoke_variant(get_arch("gemma2-2b"))
    model = LMModel(cfg)
    p = model.init_params(jax.random.PRNGKey(0))
    x = jax.random.randint(jax.random.PRNGKey(1), (1, 16), 0, cfg.vocab_size)
    logits, _ = model.serve_step(p, model.init_cache(1, 16), x[:, :1],
                                 jnp.int32(1))
    assert float(jnp.max(jnp.abs(logits))) <= cfg.final_softcap + 1e-3


def test_cross_entropy_chunked_matches_unchunked():
    rng = jax.random.PRNGKey(0)
    B, S, D, V = 2, 32, 16, 64
    h = jax.random.normal(rng, (B, S, D))
    W = jax.random.normal(jax.random.fold_in(rng, 1), (D, V)) * 0.2
    y = jax.random.randint(jax.random.fold_in(rng, 2), (B, S), 0, V)
    fn = lambda hh: hh @ W
    l1, n1 = cross_entropy_chunked(fn, h, y, n_chunks=1)
    l4, n4 = cross_entropy_chunked(fn, h, y, n_chunks=4, final_softcap=0.0)
    np.testing.assert_allclose(float(l1), float(l4), rtol=1e-6)
    assert float(n1) == float(n4) == B * S


def test_sliding_window_restricts_context():
    """A local layer must not see past the window."""
    from repro.models.attention import flash_chunked
    rng = np.random.default_rng(0)
    S, D = 64, 16
    q = jnp.asarray(rng.normal(size=(1, S, 2, D)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, S, 2, D)).astype(np.float32))
    v0 = jnp.asarray(rng.normal(size=(1, S, 2, D)).astype(np.float32))
    out0 = flash_chunked(q, k, v0, chunk_k=16, scale=0.25, window=8)
    # perturb v at position 0: outputs at positions >= 8 must not change
    v1 = v0.at[:, 0].add(100.0)
    out1 = flash_chunked(q, k, v1, chunk_k=16, scale=0.25, window=8)
    diff = np.abs(np.asarray(out1 - out0)).max(axis=(0, 2, 3))
    assert diff[:8].max() > 0
    np.testing.assert_allclose(diff[8:], 0.0, atol=1e-5)


def test_param_counts_close_to_nominal():
    """Full configs instantiate (eval_shape only) near their nameplate
    parameter counts."""
    import re
    from repro.launch.roofline import count_params
    expected = {"yi-34b": 34e9, "qwen2.5-14b": 14e9, "qwen2-7b": 7.6e9,
                "gemma2-2b": 2.6e9, "mamba2-130m": 0.13e9,
                "deepseek-v2-236b": 236e9, "chameleon-34b": 34e9,
                "zamba2-2.7b": 2.7e9, "olmoe-1b-7b": 6.9e9}
    for arch, want in expected.items():
        cfg = get_arch(arch)
        model = LMModel(cfg)
        shapes = jax.eval_shape(
            lambda m=model: m.init_params(jax.random.PRNGKey(0)))
        got = count_params(shapes)
        assert 0.7 * want < got < 1.45 * want, (arch, got, want)

"""Per-kernel interpret-mode validation vs the pure-jnp oracles:
shape/dtype sweeps + hypothesis property checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.ne_forces.kernel import ne_forces_pallas
from repro.kernels.ne_forces.ref import ne_forces_ref
from repro.kernels.pairwise_sqdist.kernel import pairwise_sqdist_pallas
from repro.kernels.pairwise_sqdist.ref import pairwise_sqdist_ref


@pytest.mark.parametrize("b,c,m", [(8, 4, 16), (37, 11, 19), (64, 16, 128),
                                   (130, 3, 200)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pairwise_sqdist_sweep(b, c, m, dtype):
    rng = np.random.default_rng(b * 100 + c)
    q = jnp.asarray(rng.normal(size=(b, m)), dtype)
    cands = jnp.asarray(rng.normal(size=(b, c, m)), dtype)
    got = pairwise_sqdist_pallas(q, cands, interpret=True)
    want = pairwise_sqdist_ref(q, cands)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol * m)


@pytest.mark.parametrize("b,k,d", [(8, 4, 2), (33, 9, 4), (64, 32, 16)])
@pytest.mark.parametrize("mode", ["attraction", "repulsion"])
@pytest.mark.parametrize("alpha", [0.4, 1.0, 3.0])
def test_ne_forces_sweep(b, k, d, mode, alpha):
    rng = np.random.default_rng(b + k)
    y = jnp.asarray(rng.normal(size=(b, d)).astype(np.float32))
    nbr = jnp.asarray(rng.normal(size=(b, k, d)).astype(np.float32))
    coef = jnp.asarray(rng.random((b, k)).astype(np.float32))
    got = ne_forces_pallas(y, nbr, coef, alpha, mode=mode, interpret=True)
    want = ne_forces_ref(y, nbr, coef, alpha, mode=mode)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=2e-5, atol=2e-5)


def test_ne_forces_action_reaction():
    """Aggregated force equals the sum of edge forces (Newton pairs)."""
    rng = np.random.default_rng(3)
    y = jnp.asarray(rng.normal(size=(16, 3)).astype(np.float32))
    nbr = jnp.asarray(rng.normal(size=(16, 5, 3)).astype(np.float32))
    coef = jnp.ones((16, 5), jnp.float32)
    agg, edge, _ = ne_forces_ref(y, nbr, coef, 0.8, mode="repulsion")
    np.testing.assert_allclose(np.asarray(agg),
                               np.asarray(jnp.sum(edge, axis=1)), rtol=1e-6)


@pytest.mark.parametrize("s,d,hq,hkv", [(64, 32, 4, 2), (96, 64, 8, 8),
                                        (128, 32, 6, 1)])
@pytest.mark.parametrize("opts", [{}, {"softcap": 10.0}, {"window": 23},
                                  {"softcap": 5.0, "window": 17}])
def test_flash_attention_sweep(s, d, hq, hkv, opts):
    rng = np.random.default_rng(s + hq)
    q = jnp.asarray(rng.normal(size=(2, hq, s, d)).astype(np.float32)) * 0.4
    k = jnp.asarray(rng.normal(size=(2, hkv, s, d)).astype(np.float32)) * 0.4
    v = jnp.asarray(rng.normal(size=(2, hkv, s, d)).astype(np.float32))
    got = flash_attention_pallas(q, k, v, block_q=32, block_k=32,
                                 interpret=True, **opts)
    want = flash_attention_ref(q, k, v, **opts)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_bf16():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(1, 2, 64, 32)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 2, 64, 32)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(1, 2, 64, 32)), jnp.bfloat16)
    got = flash_attention_pallas(q, k, v, block_q=32, block_k=32,
                                 interpret=True)
    want = flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5e-2, atol=5e-2)


@settings(max_examples=25, deadline=None)
@given(b=st.integers(1, 40), c=st.integers(1, 12), m=st.integers(1, 48),
       scale=st.floats(0.1, 10.0))
def test_sqdist_properties(b, c, m, scale):
    """Non-negativity, exact zero on identical points, scale law."""
    rng = np.random.default_rng(b * 7 + c)
    q = jnp.asarray(rng.normal(size=(b, m)).astype(np.float32)) * scale
    cands = jnp.repeat(q[:, None, :], c, axis=1)
    d = pairwise_sqdist_pallas(q, cands, interpret=True)
    np.testing.assert_allclose(np.asarray(d), 0.0, atol=1e-4 * scale ** 2)
    other = jnp.asarray(rng.normal(size=(b, c, m)).astype(np.float32))
    d2 = pairwise_sqdist_pallas(q, other, interpret=True)
    assert bool(jnp.all(d2 >= 0.0))

"""Per-kernel interpret-mode validation vs the pure-jnp oracles:
shape/dtype sweeps + hypothesis property checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.ne_forces.kernel import (ne_forces_gather_pallas,
                                            ne_forces_pallas)
from repro.kernels.ne_forces.ref import ne_forces_gather_ref, ne_forces_ref
from repro.kernels.pairwise_sqdist.kernel import (
    pairwise_sqdist_gather_pallas, pairwise_sqdist_pallas)
from repro.kernels.pairwise_sqdist.ref import (pairwise_sqdist_gather_ref,
                                               pairwise_sqdist_ref)


@pytest.mark.parametrize("b,c,m", [(8, 4, 16), (37, 11, 19), (64, 16, 128),
                                   (130, 3, 200)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pairwise_sqdist_sweep(b, c, m, dtype):
    rng = np.random.default_rng(b * 100 + c)
    q = jnp.asarray(rng.normal(size=(b, m)), dtype)
    cands = jnp.asarray(rng.normal(size=(b, c, m)), dtype)
    got = pairwise_sqdist_pallas(q, cands, interpret=True)
    want = pairwise_sqdist_ref(q, cands)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol * m)


@pytest.mark.parametrize("b,k,d", [(8, 4, 2), (33, 9, 4), (64, 32, 16)])
@pytest.mark.parametrize("mode", ["attraction", "repulsion"])
@pytest.mark.parametrize("alpha", [0.4, 1.0, 3.0])
def test_ne_forces_sweep(b, k, d, mode, alpha):
    rng = np.random.default_rng(b + k)
    y = jnp.asarray(rng.normal(size=(b, d)).astype(np.float32))
    nbr = jnp.asarray(rng.normal(size=(b, k, d)).astype(np.float32))
    coef = jnp.asarray(rng.random((b, k)).astype(np.float32))
    got = ne_forces_pallas(y, nbr, coef, alpha, mode=mode, interpret=True)
    want = ne_forces_ref(y, nbr, coef, alpha, mode=mode)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=2e-5, atol=2e-5)


# --------------------------------------------------------------------------
# Gather-fused (index-taking) kernel variants


@pytest.mark.parametrize("n,m,b,c,bb,bm", [
    (50, 19, 37, 5, 16, 8),      # everything ragged; M not a mult of bm
    (64, 128, 64, 7, 32, 128),   # exact tiling, unpadded B
    (40, 300, 33, 3, 8, 128),    # padded B + clamped+masked final M chunk
    (30, 2, 30, 9, 16, 512),     # tiny M (the LD-space case)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pairwise_sqdist_gather_sweep(n, m, b, c, bb, bm, dtype):
    rng = np.random.default_rng(n + m + b)
    x = jnp.asarray(rng.normal(size=(n, m)), dtype)
    qid = jnp.asarray(rng.integers(0, n, b).astype(np.int32))
    # include out-of-range ids: the kernel must clip exactly like the ref
    cand = jnp.asarray(rng.integers(-2, n + 3, (b, c)).astype(np.int32))
    got = pairwise_sqdist_gather_pallas(x, qid, cand, block_b=bb,
                                        block_m=bm, interpret=True)
    want = pairwise_sqdist_gather_ref(x, qid, cand)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol * m)


@pytest.mark.parametrize("sub_b,persistent_q", [
    (8, False),      # 2-slot double buffer, per-chunk q staging
    (8, True),       # double buffer + persistent q slab
    (16, None),      # monolithic sub-block (no pipelining), auto q
    (None, True),    # auto sub_b, forced persistent q
])
def test_pairwise_sqdist_gather_pipeline_variants(sub_b, persistent_q):
    """The double-buffered b loop and the persistent-q slab are pure
    scheduling: every (sub_b, persistent_q) point must agree with the
    ref, including multi-M-chunk grids with a ragged final chunk."""
    rng = np.random.default_rng(17)
    n, m, b, c = 45, 300, 37, 5            # 5 ragged M-chunks at bm=64
    x = jnp.asarray(rng.normal(size=(n, m)).astype(np.float32))
    qid = jnp.asarray(rng.integers(0, n, b).astype(np.int32))
    cand = jnp.asarray(rng.integers(-2, n + 3, (b, c)).astype(np.int32))
    got = pairwise_sqdist_gather_pallas(x, qid, cand, block_b=16,
                                        block_m=64, sub_b=sub_b,
                                        persistent_q=persistent_q,
                                        interpret=True)
    want = pairwise_sqdist_gather_ref(x, qid, cand)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("sub_b", [8, 16, 32])
def test_ne_forces_gather_double_buffer_sub_blocks(sub_b):
    """Sub-block size is pure scheduling for the force kernel too."""
    rng = np.random.default_rng(23)
    n, b, d = 50, 37, 3
    segments = (("attraction", 4), ("repulsion", 3), ("repulsion", 2))
    k = 9
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    qid = jnp.asarray(rng.integers(0, n, b).astype(np.int32))
    nbr = jnp.asarray(rng.integers(-1, n + 2, (b, k)).astype(np.int32))
    coef = jnp.asarray(rng.random((b, k)).astype(np.float32))
    got = ne_forces_gather_pallas(x, qid, nbr, coef, 1.3, segments=segments,
                                  block_b=32, sub_b=sub_b, interpret=True)
    want = ne_forces_gather_ref(x, qid, nbr, coef, 1.3, segments=segments)
    for gs, ws, name in zip(got, want, ("agg", "edge", "wsum")):
        for s, (g, w) in enumerate(zip(gs, ws)):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                       rtol=2e-5, atol=2e-5,
                                       err_msg=f"{name}[{s}]@sub_b={sub_b}")


def test_dimension_semantics_annotated_kernels_parity():
    """The gather kernels carry grid ``dimension_semantics`` annotations
    ('parallel' row blocks, 'arbitrary' accumulation axes) for real-TPU
    tuning.  The annotation must be a pure scheduling hint: interpret-
    mode parity with the refs on multi-block grids (several row blocks
    AND several M chunks, so both axes actually iterate) pins that, and
    pins that the compat shim (TPUCompilerParams vs CompilerParams)
    resolves on this jax version."""
    from repro.compat import tpu_compiler_params

    params = tpu_compiler_params(dimension_semantics=("parallel",))
    assert params is not None

    rng = np.random.default_rng(31)
    n, m, b, c = 60, 200, 53, 5            # 4 ragged M chunks at bm=64
    x = jnp.asarray(rng.normal(size=(n, m)).astype(np.float32))
    qid = jnp.asarray(rng.integers(0, n, b).astype(np.int32))
    cand = jnp.asarray(rng.integers(-2, n + 3, (b, c)).astype(np.int32))
    got = pairwise_sqdist_gather_pallas(x, qid, cand, block_b=16,
                                        block_m=64, interpret=True)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(pairwise_sqdist_gather_ref(
                                   x, qid, cand)),
                               rtol=1e-5, atol=1e-4)

    d = 3
    segments = (("attraction", 4), ("repulsion", 3))
    y = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    nbr = jnp.asarray(rng.integers(-1, n + 2, (b, 7)).astype(np.int32))
    coef = jnp.asarray(rng.random((b, 7)).astype(np.float32))
    got = ne_forces_gather_pallas(y, qid, nbr, coef, 1.1, segments=segments,
                                  block_b=16, interpret=True)
    want = ne_forces_gather_ref(y, qid, nbr, coef, 1.1, segments=segments)
    for gs, ws in zip(got, want):
        for g, w in zip(gs, ws):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                       rtol=2e-5, atol=2e-5)

    from repro.kernels.knn_merge.kernel import knn_merge_pallas
    from repro.kernels.knn_merge.ref import knn_merge_ref
    xq = jnp.asarray((rng.integers(-8, 9, (n, m)) / 4.0).astype(np.float32))
    k = 6
    cur_idx = jnp.asarray(rng.integers(0, n, (b, k)).astype(np.int32))
    d0 = jnp.sort(jnp.sum((xq[cur_idx] - xq[qid][:, None, :]) ** 2, -1), 1)
    order = jnp.argsort(jnp.sum((xq[cur_idx] - xq[qid][:, None, :]) ** 2,
                                -1), 1)
    cur_idx = jnp.take_along_axis(cur_idx, order, 1)
    active = jnp.ones((b, c), bool)
    got = knn_merge_pallas(xq, qid, cur_idx, d0, cand, active,
                           rescore=False, block_b=16, block_m=64,
                           interpret=True)
    want = knn_merge_ref(xq, qid, cur_idx, d0, cand, cand_active=active)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_pairwise_sqdist_gather_matches_pregather():
    """Same answer as the pre-gather kernel fed the explicit X[cand]."""
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(size=(60, 23)).astype(np.float32))
    qid = jnp.arange(41, dtype=jnp.int32)
    cand = jnp.asarray(rng.integers(0, 60, (41, 6)).astype(np.int32))
    got = pairwise_sqdist_gather_pallas(x, qid, cand, block_b=16,
                                        block_m=16, interpret=True)
    want = pairwise_sqdist_pallas(x[qid], x[cand], interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("segments", [
    (("attraction", 5),),
    (("repulsion", 4),),
    (("attraction", 4), ("repulsion", 3), ("repulsion", 2)),
])
@pytest.mark.parametrize("b,d,bb", [(37, 2, 16),    # padded B, vis-scale d
                                    (64, 8, 32),    # unpadded B
                                    (21, 16, 8)])
@pytest.mark.parametrize("alpha", [0.4, 1.0, 3.0])
def test_ne_forces_gather_sweep(segments, b, d, bb, alpha):
    k = sum(s for _, s in segments)
    rng = np.random.default_rng(b * 10 + d)
    n = 50
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    qid = jnp.asarray(rng.integers(0, n, b).astype(np.int32))
    nbr = jnp.asarray(rng.integers(-1, n + 2, (b, k)).astype(np.int32))
    coef = jnp.asarray(rng.random((b, k)).astype(np.float32))
    got = ne_forces_gather_pallas(x, qid, nbr, coef, alpha,
                                  segments=segments, block_b=bb,
                                  interpret=True)
    want = ne_forces_gather_ref(x, qid, nbr, coef, alpha, segments=segments)
    for gs, ws, name in zip(got, want, ("agg", "edge", "wsum")):
        for s, (g, w) in enumerate(zip(gs, ws)):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                       rtol=2e-5, atol=2e-5,
                                       err_msg=f"{name}[{s}]")


def test_ne_forces_gather_matches_per_mode_launches():
    """One segmented launch == three independent pre-gather launches."""
    rng = np.random.default_rng(9)
    n, b, d = 48, 30, 4
    sizes, modes = (6, 5, 3), ("attraction", "repulsion", "repulsion")
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    qid = jnp.asarray(rng.integers(0, n, b).astype(np.int32))
    nbr = jnp.asarray(rng.integers(0, n, (b, sum(sizes))).astype(np.int32))
    coef = jnp.asarray(rng.random((b, sum(sizes))).astype(np.float32))
    aggs, edges, wsums = ne_forces_gather_pallas(
        x, qid, nbr, coef, 1.3, segments=tuple(zip(modes, sizes)),
        block_b=16, interpret=True)
    k0 = 0
    for s, (mode, size) in enumerate(zip(modes, sizes)):
        sl = slice(k0, k0 + size)
        agg_s, edge_s, wsum_s = ne_forces_pallas(
            x[qid], x[nbr[:, sl]], coef[:, sl], 1.3, mode=mode,
            block_b=16, interpret=True)
        np.testing.assert_allclose(np.asarray(aggs[s]), np.asarray(agg_s),
                                   rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(np.asarray(edges[s]),
                                   np.asarray(edge_s), rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(np.asarray(wsums[s]),
                                   np.asarray(wsum_s), rtol=2e-5, atol=2e-5)
        k0 += size


def test_ne_forces_gather_emit_edges_skips_output():
    """emit_edges=False segments return None edges; everything else is
    unchanged vs the all-edges launch."""
    rng = np.random.default_rng(11)
    n, b, d = 40, 24, 3
    seg = (("attraction", 5), ("repulsion", 4))
    k = 9
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    qid = jnp.asarray(rng.integers(0, n, b).astype(np.int32))
    nbr = jnp.asarray(rng.integers(0, n, (b, k)).astype(np.int32))
    coef = jnp.asarray(rng.random((b, k)).astype(np.float32))
    full = ne_forces_gather_pallas(x, qid, nbr, coef, 0.9, segments=seg,
                                   block_b=8, interpret=True)
    part = ne_forces_gather_pallas(x, qid, nbr, coef, 0.9, segments=seg,
                                   emit_edges=(True, False), block_b=8,
                                   interpret=True)
    assert part[1][1] is None
    np.testing.assert_allclose(np.asarray(part[1][0]),
                               np.asarray(full[1][0]), rtol=1e-6)
    for which in (0, 2):    # aggs, wsums identical
        for s in range(2):
            np.testing.assert_allclose(np.asarray(part[which][s]),
                                       np.asarray(full[which][s]),
                                       rtol=1e-6)


def test_ne_forces_action_reaction():
    """Aggregated force equals the sum of edge forces (Newton pairs)."""
    rng = np.random.default_rng(3)
    y = jnp.asarray(rng.normal(size=(16, 3)).astype(np.float32))
    nbr = jnp.asarray(rng.normal(size=(16, 5, 3)).astype(np.float32))
    coef = jnp.ones((16, 5), jnp.float32)
    agg, edge, _ = ne_forces_ref(y, nbr, coef, 0.8, mode="repulsion")
    np.testing.assert_allclose(np.asarray(agg),
                               np.asarray(jnp.sum(edge, axis=1)), rtol=1e-6)


@pytest.mark.parametrize("s,d,hq,hkv", [(64, 32, 4, 2), (96, 64, 8, 8),
                                        (128, 32, 6, 1)])
@pytest.mark.parametrize("opts", [{}, {"softcap": 10.0}, {"window": 23},
                                  {"softcap": 5.0, "window": 17}])
def test_flash_attention_sweep(s, d, hq, hkv, opts):
    rng = np.random.default_rng(s + hq)
    q = jnp.asarray(rng.normal(size=(2, hq, s, d)).astype(np.float32)) * 0.4
    k = jnp.asarray(rng.normal(size=(2, hkv, s, d)).astype(np.float32)) * 0.4
    v = jnp.asarray(rng.normal(size=(2, hkv, s, d)).astype(np.float32))
    got = flash_attention_pallas(q, k, v, block_q=32, block_k=32,
                                 interpret=True, **opts)
    want = flash_attention_ref(q, k, v, **opts)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_bf16():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(1, 2, 64, 32)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 2, 64, 32)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(1, 2, 64, 32)), jnp.bfloat16)
    got = flash_attention_pallas(q, k, v, block_q=32, block_k=32,
                                 interpret=True)
    want = flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5e-2, atol=5e-2)


@settings(max_examples=25, deadline=None)
@given(b=st.integers(1, 40), c=st.integers(1, 12), m=st.integers(1, 48),
       scale=st.floats(0.1, 10.0))
def test_sqdist_properties(b, c, m, scale):
    """Non-negativity, exact zero on identical points, scale law."""
    rng = np.random.default_rng(b * 7 + c)
    q = jnp.asarray(rng.normal(size=(b, m)).astype(np.float32)) * scale
    cands = jnp.repeat(q[:, None, :], c, axis=1)
    d = pairwise_sqdist_pallas(q, cands, interpret=True)
    np.testing.assert_allclose(np.asarray(d), 0.0, atol=1e-4 * scale ** 2)
    other = jnp.asarray(rng.normal(size=(b, c, m)).astype(np.float32))
    d2 = pairwise_sqdist_pallas(q, other, interpret=True)
    assert bool(jnp.all(d2 >= 0.0))

"""Verified checkpoints: CRC/manifest integrity, fallback-chain restore,
compat fingerprints, prune protection, the state auditor, and the
offline fsck CLI.

The trust contracts pinned here are the ones ISSUE 9 promises:
  * any damage to a committed checkpoint (truncated, bit-flipped or
    deleted shard file; row-coverage gaps) is detected at restore time
    as a structured CheckpointCorrupt -- never materialised;
  * restore_verified walks newest -> oldest to the last intact boundary
    and reports every boundary it skipped;
  * pruning never evicts the last VERIFIED boundary;
  * a cfg-mismatched resume raises CheckpointIncompatible instead of
    silently loading garbage; a matching-cfg resume stays bit-identical;
  * audit_state counts exactly the invariant violations it claims to,
    and zero on healthy states.
"""
import io
import json
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointCorrupt, CheckpointError,
                              CheckpointIncompatible, CheckpointNotFound,
                              Checkpointer, cfg_compat, row_shard_filter)
from repro.checkpoint.verify import verify_dir
from repro.core import funcsne
from repro.core.funcsne import FuncSNEConfig
from repro.core.knn import SENTINEL
from repro.core.resilience import ResiliencePolicy
from repro.runtime.faults import (CorruptShard, FaultScript, Preempted,
                                  Preemption, active)

N, DIM = 48, 5


def _data(n=N, dim=DIM, seed=0):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(2, dim)) * 5.0
    X = centers[rng.integers(0, 2, size=n)] + rng.normal(size=(n, dim))
    return jnp.asarray(X, jnp.float32)


def _cfg(n=N, dim=DIM, **kw):
    kw.setdefault("backend", "xla")
    kw.setdefault("n_negatives", 4)
    kw.setdefault("k_hd", min(32, n // 2))
    kw.setdefault("k_ld", min(16, n // 4))
    return FuncSNEConfig(n_points=n, dim_hd=dim, **kw)


def _tree(n=12, d=2, seed=0):
    rng = np.random.default_rng(seed)
    return {"Y": jnp.asarray(rng.normal(size=(n, d)), jnp.float32),
            "idx": jnp.asarray(rng.integers(0, n, size=(n, 3)), jnp.int32),
            "step": jnp.int32(7)}


def _like(n=12, d=2):
    return {"Y": np.zeros((n, d), np.float32),
            "idx": np.zeros((n, 3), np.int32), "step": np.int32(0)}


def _save_steps(ck, steps, n=12, n_hosts=1, meta=None):
    tree = _tree(n=n)
    for s in steps:
        if n_hosts == 1:
            ck.save(s, tree, metadata=dict(meta or {}), blocking=True)
        else:
            for h in range(n_hosts):
                ck.save(s, tree, metadata=dict(meta or {}),
                        host_shard_filter=row_shard_filter(h, n_hosts, n),
                        host_id=h, n_hosts=n_hosts)
            ck.wait()
    return tree


def _shard_files(ck, step):
    d = ck.dir / f"step_{step:010d}"
    return sorted(d.glob("shard*-of-*.npz")) or [d / "arrays.npz"]


# ---------------------------------------------------------------------------
# Manifest + verify


def test_save_writes_manifest_and_roundtrip_verifies(tmp_path):
    ck = Checkpointer(tmp_path, keep_last=5)
    tree = _save_steps(ck, [3])
    meta = json.loads(
        (tmp_path / "step_0000000003" / "meta.json").read_text())
    man = meta["manifest"]
    assert man["n_hosts"] == 1 and set(man["files"]) == {"arrays.npz"}
    fman = man["files"]["arrays.npz"]
    assert isinstance(fman["crc32"], int)
    y_meta = next(v for k, v in fman["arrays"].items() if "'Y'" in k)
    assert y_meta["dtype"] == "float32"
    assert y_meta["shape"] == [12, 2]
    got, m = ck.restore(_like())
    assert m["step"] == 3
    np.testing.assert_array_equal(np.asarray(got["Y"]),
                                  np.asarray(tree["Y"]))


def test_multihost_manifest_records_row_ranges(tmp_path):
    ck = Checkpointer(tmp_path, keep_last=5)
    tree = _save_steps(ck, [1], n_hosts=3)
    meta = ck.verify_step(1)
    man = meta["manifest"]
    assert man["n_hosts"] == 3 and len(man["files"]) == 3
    spans = []
    for fman in man["files"].values():
        for key, am in fman["arrays"].items():
            if "rows" in am and "'Y'" in key:
                assert am["full_rows"] == 12
                spans.append(tuple(am["rows"]))
    assert sorted(spans) == [(0, 4), (4, 8), (8, 12)]
    # no sidecar manifests survive the commit
    assert not list((tmp_path / "step_0000000001").glob("*.manifest.json"))
    got, _ = ck.restore(_like())
    np.testing.assert_array_equal(np.asarray(got["Y"]),
                                  np.asarray(tree["Y"]))


@pytest.mark.parametrize("mode", ["truncate", "bitflip", "delete"])
@pytest.mark.parametrize("n_hosts", [1, 2])
def test_damage_detected_at_restore(tmp_path, mode, n_hosts):
    ck = Checkpointer(tmp_path, keep_last=5)
    _save_steps(ck, [4], n_hosts=n_hosts)
    target = _shard_files(ck, 4)[-1]
    if mode == "delete":
        target.unlink()
    elif mode == "truncate":
        target.write_bytes(target.read_bytes()[:40])
    else:
        blob = bytearray(target.read_bytes())
        blob[len(blob) // 2] ^= 0x04
        target.write_bytes(bytes(blob))
    with pytest.raises(CheckpointCorrupt) as ei:
        ck.restore(_like())
    assert ei.value.step == 4
    assert isinstance(ei.value, CheckpointError)


def test_row_coverage_gap_detected(tmp_path):
    # surgically rewrite the manifest so the file set is self-consistent
    # but rows [6, 12) of every sliced leaf are missing: only the
    # coverage check can catch this
    ck = Checkpointer(tmp_path, keep_last=5)
    _save_steps(ck, [2], n_hosts=2)
    d = tmp_path / "step_0000000002"
    meta = json.loads((d / "meta.json").read_text())
    gone = "shard001-of-002.npz"
    del meta["manifest"]["files"][gone]
    meta["manifest"]["n_hosts"] = 1
    (d / gone).unlink()
    (d / "meta.json").write_text(json.dumps(meta))
    with pytest.raises(CheckpointCorrupt) as ei:
        ck.verify_step(2)
    assert "uncovered" in ei.value.reason


def test_stray_file_and_missing_manifest_detected(tmp_path):
    ck = Checkpointer(tmp_path, keep_last=5)
    _save_steps(ck, [1])
    d = tmp_path / "step_0000000001"
    (d / "extra.npz").write_bytes(b"junk")
    with pytest.raises(CheckpointCorrupt, match="not in manifest"):
        ck.verify_step(1)
    (d / "extra.npz").unlink()
    meta = json.loads((d / "meta.json").read_text())
    del meta["manifest"]
    (d / "meta.json").write_text(json.dumps(meta))
    with pytest.raises(CheckpointCorrupt, match="manifest"):
        ck.verify_step(1)


# ---------------------------------------------------------------------------
# Structured not-found + fallback chain


def test_restore_missing_step_names_available(tmp_path):
    ck = Checkpointer(tmp_path, keep_last=5)
    with pytest.raises(CheckpointNotFound) as ei:
        ck.restore(_like())
    assert ei.value.available == []
    assert isinstance(ei.value, FileNotFoundError)   # back-compat catch
    _save_steps(ck, [2, 5])
    with pytest.raises(CheckpointNotFound) as ei:
        ck.restore(_like(), step=3)
    assert ei.value.available == [2, 5] and ei.value.step == 3
    with pytest.raises(CheckpointNotFound):
        ck.restore_verified(_like(), step=1)   # nothing committed <= 1


def test_restore_verified_walks_to_last_intact_boundary(tmp_path):
    ck = Checkpointer(tmp_path, keep_last=5)
    tree = _save_steps(ck, [1, 2, 3])
    for s in (2, 3):    # damage the two newest
        f = _shard_files(ck, s)[0]
        f.write_bytes(f.read_bytes()[:30])
    got, meta, fbs = ck.restore_verified(_like())
    assert meta["step"] == 1
    assert [f["step"] for f in fbs] == [3, 2]
    assert all("CRC32" in f["reason"] or "truncat" in f["reason"].lower()
               or f["reason"] for f in fbs)
    np.testing.assert_array_equal(np.asarray(got["Y"]),
                                  np.asarray(tree["Y"]))
    # every boundary damaged -> structured aggregate, not a fall-through
    f = _shard_files(ck, 1)[0]
    f.unlink()
    with pytest.raises(CheckpointCorrupt, match="every committed step"):
        ck.restore_verified(_like())


def test_prune_never_evicts_last_verified_boundary(tmp_path):
    ck = Checkpointer(tmp_path, keep_last=1)
    _save_steps(ck, [1, 2, 3])
    assert ck.all_steps() == [3]        # keep_last=1 pruned 1 and 2
    got, meta, fbs = ck.restore_verified(_like())
    assert meta["step"] == 3 and fbs == []
    # newer saves arrive; they have NOT been verified, so pruning must
    # keep the boundary the restore chain last landed on
    _save_steps(ck, [4, 5])
    assert 3 in ck.all_steps(), \
        "pruning evicted the last verified boundary"
    assert 5 in ck.all_steps()
    # verifying a newer step moves the protection forward: 3 is now
    # prunable again
    ck.restore_verified(_like())        # lands on 5
    _save_steps(ck, [6])
    assert ck.all_steps() == [5, 6]


# ---------------------------------------------------------------------------
# Compat fingerprints


def test_cfg_compat_mismatch_raises_structured(tmp_path):
    cfg = _cfg()
    ck = Checkpointer(tmp_path, keep_last=5)
    _save_steps(ck, [2], meta={"compat": cfg_compat(cfg)})
    # matching cfg restores fine
    ck.restore(_like(), expect_compat=cfg_compat(cfg))
    for other in (_cfg(n=N + 16),                      # n differs
                  _cfg(dim=DIM + 1),                   # d differs
                  _cfg(cand_fused=not cfg.cand_fused)):  # flag matrix
        with pytest.raises(CheckpointIncompatible) as ei:
            ck.restore(_like(), expect_compat=cfg_compat(other))
        assert ei.value.mismatches, ei.value
    # incompat must NOT fall back to older boundaries (same-run cfg is
    # constant; falling back would mask a user error)
    _save_steps(ck, [3], meta={"compat": cfg_compat(cfg)})
    with pytest.raises(CheckpointIncompatible):
        ck.restore_verified(_like(),
                            expect_compat=cfg_compat(_cfg(n=N + 16)))


def test_fit_resume_mismatched_cfg_raises(tmp_path):
    X, cfg = _data(), _cfg()
    policy = ResiliencePolicy(checkpoint_dir=str(tmp_path),
                              checkpoint_every=1)
    funcsne.fit(X, cfg=cfg, n_iter=8, chunk_size=4, resilience=policy)
    bad_cfg = _cfg(cand_fused=not cfg.cand_fused)
    with pytest.raises(CheckpointIncompatible):
        funcsne.fit(X, cfg=bad_cfg, n_iter=8, chunk_size=4,
                    resilience=ResiliencePolicy(),
                    resume_from=str(tmp_path))


def test_fit_corrupt_fallback_resume_bit_identical(tmp_path):
    """The PR-6 resume guarantee survives a damaged newest boundary:
    resume falls back one chunk and replays it bit-identically."""
    X, cfg = _data(), _cfg()
    kw = dict(cfg=cfg, n_iter=16, chunk_size=4)
    st_ref, _ = funcsne.fit(X, resilience=ResiliencePolicy(), **kw)

    fault = CorruptShard(at_step=8, mode="truncate")
    with pytest.raises(Preempted):
        with active(FaultScript(fault, Preemption(at_step=8))):
            funcsne.fit(X, resilience=ResiliencePolicy(
                checkpoint_dir=str(tmp_path), checkpoint_every=1), **kw)
    assert fault.damaged is not None
    policy = ResiliencePolicy(checkpoint_dir=str(tmp_path),
                              checkpoint_every=1)
    st_res, _ = funcsne.fit(X, resilience=policy,
                            resume_from=str(tmp_path), **kw)
    fbs = [e for e in policy.events if e["kind"] == "checkpoint_fallback"]
    assert fbs and fbs[0]["step"] == 8, policy.events
    np.testing.assert_array_equal(np.asarray(st_res.Y),
                                  np.asarray(st_ref.Y))
    assert int(st_res.step) == 16


# ---------------------------------------------------------------------------
# State auditor units


def _state(cfg=None, n=N):
    cfg = cfg or _cfg(n=n)
    X = _data(n=n)
    return X, cfg, funcsne.init_state(jax.random.PRNGKey(0), X, cfg)


def test_audit_clean_state_all_zero():
    X, cfg, st = _state()
    res = jax.device_get(funcsne.audit_state(st, cfg, X))
    assert all(int(v) == 0 for v in res), res._asdict()
    policy = ResiliencePolicy()
    assert policy.audit_check(res) is None


def test_audit_counts_oob_dup_sentinel_nonfinite():
    X, cfg, st = _state()
    policy = ResiliencePolicy()

    bad = st._replace(hd_idx=st.hd_idx.at[0, 0].set(N + 5))
    res = jax.device_get(funcsne.audit_state(bad, cfg))
    assert int(res.hd_oob) == 1 and int(res.ld_oob) == 0
    assert "hd_oob=1" in policy.audit_check(res)

    # rev_idx is (N, 0) when reverse edges are off: vacuously clean
    res = jax.device_get(funcsne.audit_state(st, cfg))
    assert int(res.rev_oob) == 0
    Xr, cfg_r, st_r = _state(cfg=_cfg(c_hd_rev=4))
    bad = st_r._replace(rev_idx=st_r.rev_idx.at[0, 0].set(-3))
    res = jax.device_get(funcsne.audit_state(bad, cfg_r))
    assert int(res.rev_oob) == 1

    dup = st._replace(
        hd_idx=st.hd_idx.at[0, 0].set(int(st.hd_idx[0, 1])))
    res = jax.device_get(funcsne.audit_state(dup, cfg))
    assert int(res.hd_dup) >= 1

    # SENTINEL idx slot with a finite distance: phantom neighbour
    sent = st._replace(hd_idx=st.hd_idx.at[0, 0].set(SENTINEL),
                       hd_d=st.hd_d.at[0, 0].set(1.0))
    res = jax.device_get(funcsne.audit_state(sent, cfg))
    assert int(res.hd_sentinel) == 1
    # SENTINEL with +inf distance is the healthy encoding
    ok = st._replace(hd_idx=st.hd_idx.at[0, 0].set(SENTINEL),
                     hd_d=st.hd_d.at[0, 0].set(jnp.inf))
    res = jax.device_get(funcsne.audit_state(ok, cfg))
    assert int(res.hd_sentinel) == 0 and int(res.hd_oob) == 0

    nan = st._replace(Y=st.Y.at[0, 0].set(jnp.nan))
    res = jax.device_get(funcsne.audit_state(nan, cfg))
    assert int(res.y_nonfinite) == 1
    # the same NaN on an INACTIVE row is not a violation
    nan_off = nan._replace(active=nan.active.at[0].set(False))
    res = jax.device_get(funcsne.audit_state(nan_off, cfg))
    assert int(res.y_nonfinite) == 0

    Xbad = X.at[1, 0].set(jnp.nan)
    res = jax.device_get(funcsne.audit_state(st, cfg, Xbad))
    assert int(res.x_nonfinite) == 1
    res = jax.device_get(funcsne.audit_state(st, cfg))   # no X given
    assert int(res.x_nonfinite) == 0


# ---------------------------------------------------------------------------
# Offline fsck CLI


def test_verify_cli_reports_damage_and_exit_code(tmp_path):
    from repro.checkpoint import verify as vmod

    ck = Checkpointer(tmp_path, keep_last=5)
    _save_steps(ck, [1, 2])
    f = _shard_files(ck, 2)[0]
    blob = bytearray(f.read_bytes())
    blob[len(blob) // 2] ^= 0x01
    f.write_bytes(bytes(blob))

    out = io.StringIO()
    assert verify_dir(tmp_path, out=out) == 1
    text = out.getvalue()
    assert "step 1: OK" in text and "step 2: CORRUPT" in text
    assert "CRC32" in text
    assert vmod.main([str(tmp_path)]) == 1
    assert vmod.main([str(tmp_path), "--step", "1"]) == 0
    assert vmod.main([str(tmp_path), "--step", "9"]) == 1
    shutil.rmtree(tmp_path / "step_0000000002")
    assert vmod.main([str(tmp_path)]) == 0

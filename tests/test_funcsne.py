"""FUnc-SNE behaviour: force correctness vs the exact gradient, joint KNN
convergence, dynamic datasets, interactive hyperparameters."""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import affinities, baselines, funcsne
from repro.core.knn import exact_knn
from repro.core.quality import embedding_quality, knn_set_quality
from repro.data.synthetic import blobs


def _full_state(X, alpha=1.0, k=None, seed=0):
    n, m = X.shape
    k = k or n - 1
    cfg = funcsne.FuncSNEConfig(n_points=n, dim_hd=m, dim_ld=2, k_hd=k,
                                k_ld=k, n_negatives=4)
    st = funcsne.init_state(jax.random.PRNGKey(seed), X, cfg, init="random")
    hd_idx, hd_d = exact_knn(X, k)
    st = st._replace(hd_idx=hd_idx, hd_d=hd_d,
                     beta=affinities.solve_beta(hd_d, 30.0),
                     new_flag=jnp.zeros((n,), bool))
    ld_idx, ld_d = exact_knn(st.Y, k)
    return cfg, st._replace(ld_idx=ld_idx, ld_d=ld_d)


def test_forces_match_exact_gradient_direction():
    """With full neighbour sets, one FUnc-SNE force step must align with
    the exact Eq. 5 gradient (validates the three-term decomposition)."""
    X = jnp.asarray(np.random.default_rng(0).normal(size=(48, 6))
                    .astype(np.float32)) * 2.0
    cfg, st = _full_state(X)
    hp = funcsne.default_hparams(48, lr=1.0, momentum=0.0)
    st2 = funcsne._forces_update(cfg, st, hp, jax.random.PRNGKey(1),
                                 funcsne.AxisCtx())
    dY = np.asarray(st2.Y - st.Y).ravel()
    P = baselines.exact_p_matrix(X, 30.0)
    g = np.asarray(baselines.exact_tsne_grad(st.Y, P, 1.0)).ravel()
    cos = dY @ (-g) / (np.linalg.norm(dY) * np.linalg.norm(g))
    assert cos > 0.9, cos


@pytest.mark.parametrize("alpha", [0.5, 1.0, 2.0])
def test_z_estimator_unbiased(alpha):
    from repro.core.ld_kernels import pairwise_sqdists_full, w_tail
    X = jnp.asarray(np.random.default_rng(1).normal(size=(64, 6))
                    .astype(np.float32))
    cfg, st = _full_state(X)
    st = st._replace(Y=jax.random.normal(jax.random.PRNGKey(2), (64, 2)))
    ld_idx, ld_d = exact_knn(st.Y, 63)
    st = st._replace(ld_idx=ld_idx, ld_d=ld_d)
    hp = funcsne.default_hparams(64)._replace(alpha=jnp.float32(alpha))
    st2 = funcsne._forces_update(cfg, st, hp, jax.random.PRNGKey(3),
                                 funcsne.AxisCtx())
    d2 = pairwise_sqdists_full(st.Y)
    z_true = float(jnp.sum(w_tail(d2, alpha) * (1 - jnp.eye(64))))
    assert abs(float(st2.zhat) - z_true) / z_true < 0.25


def test_fit_blobs_quality_and_knn():
    X, labels = blobs(n=600, dim=16, n_centers=5, center_std=6.0, seed=0)
    hp = funcsne.default_hparams(600, perplexity=10.0)
    st, _ = funcsne.fit(X, n_iter=350, hparams=hp)
    assert float(knn_set_quality(st.hd_idx, jnp.asarray(X))) > 0.9
    assert float(embedding_quality(jnp.asarray(X), st.Y)) > 0.15
    assert bool(jnp.isfinite(st.Y).all())


def test_feedback_loop_beats_frozen_embedding():
    """Paper Fig. 4: co-optimised embedding accelerates HD KNN discovery."""
    X, _ = blobs(n=500, dim=24, n_centers=8, center_std=6.0, seed=1)
    Xj = jnp.asarray(X)
    cfg = funcsne.FuncSNEConfig(n_points=500, dim_hd=24, c_hd_rand=1,
                                c_hd_non=2)
    hp = funcsne.default_hparams(500, perplexity=10.0)

    def run(frozen):
        st = funcsne.init_state(jax.random.PRNGKey(2), Xj, cfg)
        step = funcsne.make_step(cfg)
        y0 = jnp.array(st.Y, copy=True)    # step donates the state
        for it in range(120):
            st = step(st, Xj, hp)
            if frozen:
                st = st._replace(Y=jnp.array(y0, copy=True),
                                 vel=jnp.zeros_like(st.vel))
        return float(knn_set_quality(st.hd_idx, Xj))

    q_live = run(frozen=False)
    q_frozen = run(frozen=True)
    assert q_live >= q_frozen - 0.02, (q_live, q_frozen)


def test_dynamic_add_points():
    X, _ = blobs(n=300, dim=8, n_centers=3, center_std=5.0, seed=2)
    Xj = jnp.asarray(X)
    cfg = funcsne.FuncSNEConfig(n_points=300, dim_hd=8)
    active0 = jnp.arange(300) < 200
    st = funcsne.init_state(jax.random.PRNGKey(0), Xj, cfg, active=active0)
    step = funcsne.make_step(cfg)
    hp = funcsne.default_hparams(300)
    for it in range(60):
        st = step(st, Xj, hp)
    # activate the held-out 100 points mid-run: no recompile, no stall
    st = funcsne.add_points(st, jnp.arange(200, 300), jax.random.PRNGKey(5))
    for it in range(120):
        st = step(st, Xj, hp)
    # new points must have found real HD neighbours
    assert bool(jnp.isfinite(st.Y).all())
    assert float(st.hd_d[200:][jnp.isfinite(st.hd_d[200:])].mean()) > 0
    new_deg = np.asarray(jnp.isfinite(st.hd_d[200:]).sum(1))
    assert (new_deg >= cfg.k_hd // 2).all()


def test_remove_points_stops_their_influence():
    X, _ = blobs(n=200, dim=8, seed=3)
    cfg = funcsne.FuncSNEConfig(n_points=200, dim_hd=8)
    st = funcsne.init_state(jax.random.PRNGKey(0), jnp.asarray(X), cfg)
    st = funcsne.remove_points(st, jnp.arange(100, 200))
    step = funcsne.make_step(cfg)
    hp = funcsne.default_hparams(200)
    y_before = st.Y[100:]
    for it in range(30):
        st = step(st, jnp.asarray(X), hp)
    np.testing.assert_array_equal(np.asarray(st.Y[100:]),
                                  np.asarray(y_before))


def test_interactive_hparams_no_recompile():
    """alpha/perplexity/ratios are traced: changing them reuses the same
    compiled step (the paper's instant-feedback property)."""
    X, _ = blobs(n=256, dim=8, seed=4)
    cfg = funcsne.FuncSNEConfig(n_points=256, dim_hd=8)
    st = funcsne.init_state(jax.random.PRNGKey(0), jnp.asarray(X), cfg)
    step = funcsne.make_step(cfg)
    hp = funcsne.default_hparams(256)
    st = step(st, jnp.asarray(X), hp)          # compile once
    with jax.log_compiles():
        import logging
        records = []
        handler = logging.Handler()
        handler.emit = lambda r: records.append(r)
        logging.getLogger("jax._src.dispatch").addHandler(handler)
        for alpha in (0.4, 0.7, 1.5, 3.0):
            st = step(st, jnp.asarray(X),
                      hp._replace(alpha=jnp.float32(alpha),
                                  perplexity=jnp.float32(5 + alpha)))
        logging.getLogger("jax._src.dispatch").removeHandler(handler)
    assert not any("Compiling" in str(r.getMessage()) for r in records)
    assert bool(jnp.isfinite(st.Y).all())


@pytest.mark.slow
def test_gather_fused_step_bit_equivalent_to_pregather():
    """The gather-fused call-site rewiring is a pure data-path change: on
    the XLA backend, 50 steps from the same seed must produce *identical*
    state vs the legacy pre-gather wiring."""
    from repro.data.synthetic import blobs as _blobs
    X, _ = _blobs(n=257, dim=13, n_centers=4, center_std=5.0, seed=0)
    Xj = jnp.asarray(X)
    # scatter_fused=False on both sides: the scatter-fused epilogue is a
    # reassociation-level change (covered by test_scatter_fused.py); this
    # test pins the *gather* rewiring, which is bit-exact.
    cfg_fused = funcsne.FuncSNEConfig(n_points=257, dim_hd=13,
                                      backend="xla", gather_fused=True,
                                      scatter_fused=False)
    cfg_legacy = dataclasses.replace(cfg_fused, gather_fused=False)
    st0 = funcsne.init_state(jax.random.PRNGKey(0), Xj, cfg_fused)
    hp = funcsne.default_hparams(257)

    def run(cfg, st):
        step = jax.jit(lambda s, x, h: funcsne.funcsne_step(cfg, s, x, h))
        for _ in range(50):
            st = step(st, Xj, hp)
        return st

    st_fused = run(cfg_fused, st0)
    st_legacy = run(cfg_legacy, st0)
    for name in ("Y", "vel", "gains", "hd_idx", "hd_d", "ld_idx", "ld_d",
                 "beta", "zhat", "ema_new_frac"):
        np.testing.assert_array_equal(
            np.asarray(getattr(st_fused, name)),
            np.asarray(getattr(st_legacy, name)), err_msg=name)


@pytest.mark.slow
def test_scatter_fused_step_trajectory_equivalent():
    """50 steps with the scatter-fused epilogue vs the legacy edge +
    ``.at[].add`` epilogue, same seed.  Positions cannot stay bit-equal
    (the epilogue reassociates fp32 sums, and the LD-KNN merge / gains
    sign logic amplify any ulp difference into discrete divergence), so
    this pins what must survive 50 steps: a statistically equivalent
    trajectory -- same Z estimator, same embedding scale, same quality.
    Per-step displacement parity to fp32 tolerance is asserted separately
    in test_scatter_fused.py."""
    from repro.data.synthetic import blobs as _blobs
    X, _ = _blobs(n=257, dim=13, n_centers=4, center_std=5.0, seed=0)
    Xj = jnp.asarray(X)
    cfg_s = funcsne.FuncSNEConfig(n_points=257, dim_hd=13, backend="xla",
                                  gather_fused=True, scatter_fused=True)
    cfg_l = dataclasses.replace(cfg_s, scatter_fused=False)
    st0 = funcsne.init_state(jax.random.PRNGKey(0), Xj, cfg_s)
    hp = funcsne.default_hparams(257)

    def run(cfg, st):
        step = jax.jit(lambda s, x, h: funcsne.funcsne_step(cfg, s, x, h))
        for _ in range(50):
            st = step(st, Xj, hp)
        return st

    st_s = run(cfg_s, st0)
    st_l = run(cfg_l, st0)
    assert bool(jnp.isfinite(st_s.Y).all())
    np.testing.assert_allclose(float(st_s.zhat), float(st_l.zhat),
                               rtol=0.02)
    np.testing.assert_allclose(float(jnp.std(st_s.Y)),
                               float(jnp.std(st_l.Y)), rtol=0.1)
    q_s = float(embedding_quality(Xj, st_s.Y))
    q_l = float(embedding_quality(Xj, st_l.Y))
    assert abs(q_s - q_l) < 0.05, (q_s, q_l)


def test_dynamic_dataset_fused_parity():
    """add_points / remove_points under the fused kernels: activating and
    deactivating rows mid-run must follow the exact same trajectory as the
    legacy pre-gather wiring (the fused kernels' index clipping + coef
    masking, not dense gathers, now carry the inactive-row semantics)."""
    X, _ = blobs(n=240, dim=8, n_centers=3, center_std=5.0, seed=9)
    Xj = jnp.asarray(X)
    kw = dict(n_points=240, dim_hd=8, backend="xla", scatter_fused=False)
    cfg_fused = funcsne.FuncSNEConfig(gather_fused=True, **kw)
    cfg_legacy = funcsne.FuncSNEConfig(gather_fused=False, **kw)
    active0 = jnp.arange(240) < 160

    def run(cfg):
        st = funcsne.init_state(jax.random.PRNGKey(0), Xj, cfg,
                                active=active0)
        step = jax.jit(lambda s, x, h: funcsne.funcsne_step(cfg, s, x, h))
        hp = funcsne.default_hparams(240)
        for _ in range(15):
            st = step(st, Xj, hp)
        st = funcsne.add_points(st, jnp.arange(160, 240),
                                jax.random.PRNGKey(5))
        for _ in range(15):
            st = step(st, Xj, hp)
        st = funcsne.remove_points(st, jnp.arange(0, 40))
        for _ in range(15):
            st = step(st, Xj, hp)
        return st

    st_f, st_l = run(cfg_fused), run(cfg_legacy)
    for name in ("Y", "vel", "gains", "hd_idx", "hd_d", "ld_idx", "ld_d",
                 "beta", "active", "new_flag", "zhat", "ema_new_frac"):
        np.testing.assert_array_equal(np.asarray(getattr(st_f, name)),
                                      np.asarray(getattr(st_l, name)),
                                      err_msg=name)
    # removed rows must have frozen in place on both paths
    assert not bool(st_f.active[:40].any())


def test_dynamic_dataset_scatter_fused_respects_membership():
    """Same add/remove sequence under the scatter-fused epilogue (fp32
    reassociation-level path, so no bit contract): inactive rows stay
    frozen, re-activated rows move, everything stays finite."""
    X, _ = blobs(n=200, dim=8, n_centers=3, center_std=5.0, seed=10)
    Xj = jnp.asarray(X)
    cfg = funcsne.FuncSNEConfig(n_points=200, dim_hd=8, backend="xla",
                                gather_fused=True, scatter_fused=True)
    st = funcsne.init_state(jax.random.PRNGKey(0), Xj, cfg,
                            active=jnp.arange(200) < 140)
    step = jax.jit(lambda s, x, h: funcsne.funcsne_step(cfg, s, x, h))
    hp = funcsne.default_hparams(200)
    for _ in range(20):
        st = step(st, Xj, hp)
    frozen_before = np.asarray(st.Y[140:])
    st = funcsne.add_points(st, jnp.arange(140, 200), jax.random.PRNGKey(3))
    y_at_activation = np.asarray(st.Y[140:])
    for _ in range(30):
        st = step(st, Xj, hp)
    np.testing.assert_array_equal(frozen_before, y_at_activation)
    assert float(np.abs(np.asarray(st.Y[140:]) - y_at_activation).max()) > 0
    st = funcsne.remove_points(st, jnp.arange(0, 50))
    y_removed = np.asarray(st.Y[:50])
    for _ in range(20):
        st = step(st, Xj, hp)
    np.testing.assert_array_equal(np.asarray(st.Y[:50]), y_removed)
    assert bool(jnp.isfinite(st.Y).all())


def test_gather_fused_init_state_bit_equivalent():
    """init_state through the index-taking kernels == legacy gathers."""
    from repro.data.synthetic import blobs as _blobs
    X, _ = _blobs(n=120, dim=9, n_centers=3, center_std=5.0, seed=6)
    Xj = jnp.asarray(X)
    cfg_fused = funcsne.FuncSNEConfig(n_points=120, dim_hd=9,
                                      backend="xla", gather_fused=True)
    cfg_legacy = dataclasses.replace(cfg_fused, gather_fused=False)
    a = funcsne.init_state(jax.random.PRNGKey(4), Xj, cfg_fused,
                           perplexity=17.0)
    b = funcsne.init_state(jax.random.PRNGKey(4), Xj, cfg_legacy,
                           perplexity=17.0)
    for name in ("Y", "hd_idx", "hd_d", "ld_idx", "ld_d", "beta"):
        np.testing.assert_array_equal(np.asarray(getattr(a, name)),
                                      np.asarray(getattr(b, name)),
                                      err_msg=name)


def test_init_state_honors_perplexity():
    """The initial sigma solve must target the requested perplexity, not a
    hardcoded 30.0 (paper: perplexity is a live hyperparameter)."""
    from repro.data.synthetic import blobs as _blobs
    X, _ = _blobs(n=150, dim=12, n_centers=3, center_std=6.0, seed=7)
    Xj = jnp.asarray(X)
    # perplexity must stay well below k_hd: row entropy over k neighbours
    # is capped at log(k)
    cfg = funcsne.FuncSNEConfig(n_points=150, dim_hd=12, backend="xla")
    for perp in (5.0, 20.0):
        st = funcsne.init_state(jax.random.PRNGKey(1), Xj, cfg,
                                perplexity=perp)
        valid = jnp.isfinite(st.hd_d)
        h = affinities.entropy_of_beta(st.hd_d, st.beta, valid)
        np.testing.assert_allclose(np.asarray(h).mean(), np.log(perp),
                                   atol=0.2)


def test_fused_step_interpret_backend_matches_xla():
    """The Pallas gather kernels (interpret mode) drive a full step to the
    same embedding as the pure-jnp fallback."""
    from repro.data.synthetic import blobs as _blobs
    X, _ = _blobs(n=96, dim=10, n_centers=3, center_std=5.0, seed=1)
    Xj = jnp.asarray(X)
    kw = dict(n_points=96, dim_hd=10, k_hd=8, k_ld=6, n_negatives=5)
    cfg_i = funcsne.FuncSNEConfig(backend="interpret", **kw)
    cfg_x = funcsne.FuncSNEConfig(backend="xla", **kw)
    st_i = funcsne.init_state(jax.random.PRNGKey(3), Xj, cfg_i)
    st_x = funcsne.init_state(jax.random.PRNGKey(3), Xj, cfg_x)
    hp = funcsne.default_hparams(96)
    for _ in range(3):
        st_i = funcsne.funcsne_step(cfg_i, st_i, Xj, hp)
        st_x = funcsne.funcsne_step(cfg_x, st_x, Xj, hp)
    np.testing.assert_allclose(np.asarray(st_i.Y), np.asarray(st_x.Y),
                               rtol=1e-4, atol=1e-5)


def test_rescale_embedding():
    X, _ = blobs(n=128, dim=8, seed=5)
    cfg = funcsne.FuncSNEConfig(n_points=128, dim_hd=8)
    st = funcsne.init_state(jax.random.PRNGKey(0), jnp.asarray(X), cfg)
    st = st._replace(Y=st.Y * 1e4)
    st2 = funcsne.rescale_embedding(st, 1e-2)
    np.testing.assert_allclose(np.asarray(st2.Y), np.asarray(st.Y) * 1e-2)
    assert float(jnp.abs(st2.vel).max()) == 0.0

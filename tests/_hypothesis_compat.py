"""Optional-hypothesis shim.

``hypothesis`` is a dev-only dependency (requirements-dev.txt).  When it is
missing the property tests must skip -- not abort the whole suite at
collection time -- so import ``given``/``settings``/``st`` from here instead
of from ``hypothesis`` directly.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    import pytest

    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stands in for ``strategies``: every attribute/call returns self."""

        def __getattr__(self, name):
            return self

        def __call__(self, *args, **kwargs):
            return self

    st = _AnyStrategy()

    def settings(*args, **kwargs):
        return lambda fn: fn

    def given(*args, **kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")

"""Multi-device integration tests (subprocess: 8 fake CPU devices).

XLA locks the device count at first jax init, so these run in fresh
subprocesses with XLA_FLAGS set; the parent pytest process keeps 1 device.
"""
import os
import subprocess
import sys
import textwrap

import pytest

# distributed-parity suite: every test pays a subprocess + 8-device
# compile; excluded from the tier-1 PR gate, run on the schedule
pytestmark = pytest.mark.slow

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, timeout=560):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = _SRC
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_funcsne_distributed_step_improves_knn():
    out = _run("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro import compat
        from repro.data.synthetic import blobs
        from repro.core import funcsne
        from repro.core.quality import knn_set_quality

        X, _ = blobs(n=512, dim=16, n_centers=5, center_std=6.0)
        Xj = jnp.asarray(X)
        mesh = compat.make_mesh((4, 2), ("data", "model"))
        cfg = funcsne.FuncSNEConfig(n_points=512, dim_hd=16)
        st = funcsne.init_state(jax.random.PRNGKey(0), Xj, cfg)
        q0 = float(knn_set_quality(st.hd_idx, Xj))
        step, _ = funcsne.make_distributed_step(cfg, mesh)
        Xs = jax.device_put(Xj, NamedSharding(mesh, P(None, "model")))
        st = jax.device_put(st, NamedSharding(mesh, P()))
        hp = funcsne.default_hparams(512)
        for _ in range(150):
            st = step(st, Xs, hp)
        q1 = float(knn_set_quality(st.hd_idx, Xj))
        assert q1 > max(q0 + 0.2, 0.8), (q0, q1)
        assert bool(jnp.isfinite(st.Y).all())
        print("OK", q0, "->", q1)
    """)
    assert "OK" in out


def test_funcsne_distributed_scatter_fused_matches_legacy_epilogue():
    """The force psum consuming scatter-fused kernel partials must produce
    the same displacement field as the legacy edge-scatter epilogue on a
    (data, model) mesh.  Both paths quantise the psum to bf16 (Perf
    H10a), so a few steps with a loose tolerance is the honest bound --
    per-step fp32 parity is pinned single-device in test_scatter_fused.py.
    """
    out = _run("""
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro import compat
        from repro.data.synthetic import blobs
        from repro.core import funcsne

        X, _ = blobs(n=512, dim=16, n_centers=5, center_std=6.0)
        Xj = jnp.asarray(X)
        mesh = compat.make_mesh((4, 2), ("data", "model"))
        cfg_s = funcsne.FuncSNEConfig(n_points=512, dim_hd=16,
                                      backend="xla", scatter_fused=True)
        cfg_l = dataclasses.replace(cfg_s, scatter_fused=False)
        st0 = funcsne.init_state(jax.random.PRNGKey(0), Xj, cfg_s)
        hp = funcsne.default_hparams(512)
        Xs = jax.device_put(Xj, NamedSharding(mesh, P(None, "model")))

        def run(cfg):
            step, _ = funcsne.make_distributed_step(cfg, mesh)
            # the step donates its state: hand each run its own copy
            st = jax.device_put(jax.tree.map(lambda a: jnp.array(a,
                                                                 copy=True),
                                             st0),
                                NamedSharding(mesh, P()))
            for _ in range(8):
                st = step(st, Xs, hp)
            return st

        st_s, st_l = run(cfg_s), run(cfg_l)
        assert bool(jnp.isfinite(st_s.Y).all())
        np.testing.assert_allclose(np.asarray(st_s.Y), np.asarray(st_l.Y),
                                   rtol=5e-2, atol=5e-3)
        np.testing.assert_allclose(float(st_s.zhat), float(st_l.zhat),
                                   rtol=2e-2)
        print("OK scatter-fused == legacy on mesh")
    """)
    assert "OK" in out


def test_funcsne_distributed_chunked_step_matches_sequential():
    """make_distributed_step(chunk=T) on a (data, model) mesh == T
    sequential distributed dispatches: discrete state bit-equal, float
    state to fp32 tolerance (the while-body codegen context costs ulps,
    same as single-device -- see tests/test_chunked_driver.py), and the
    snapshot ring + metrics come back replicated."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro import compat
        from repro.data.synthetic import blobs
        from repro.core import funcsne

        X, _ = blobs(n=256, dim=16, n_centers=5, center_std=6.0)
        Xj = jnp.asarray(X)
        mesh = compat.make_mesh((4, 2), ("data", "model"))
        cfg = funcsne.FuncSNEConfig(n_points=256, dim_hd=16, backend="xla")
        st0 = funcsne.init_state(jax.random.PRNGKey(0), Xj, cfg)
        hp = funcsne.default_hparams(256)
        Xs = jax.device_put(Xj, NamedSharding(mesh, P(None, "model")))
        cp = lambda s: jax.device_put(
            jax.tree.map(lambda a: jnp.array(a, copy=True), s),
            NamedSharding(mesh, P()))

        T = 6
        step, _ = funcsne.make_distributed_step(cfg, mesh)
        st_seq = cp(st0)
        for _ in range(T):
            st_seq = step(st_seq, Xs, hp)

        chunk, _ = funcsne.make_distributed_step(cfg, mesh, chunk=T,
                                                 snapshot_every=3)
        st_c, snaps, metrics = chunk(cp(st0), Xs, hp)
        assert int(metrics.step) == T and int(metrics.n_snapshots) == 2
        assert snaps.shape[1:] == (256, 2), snaps.shape
        for name in funcsne.FuncSNEState._fields:
            a = np.asarray(getattr(st_c, name))
            b = np.asarray(getattr(st_seq, name))
            if a.dtype.kind != 'f':
                np.testing.assert_array_equal(a, b, err_msg=name)
            else:
                finite = np.isfinite(b)
                scale = float(np.max(np.abs(b[finite]))) + 1e-9
                np.testing.assert_allclose(a[finite], b[finite], rtol=1e-4,
                                           atol=1e-5 * scale, err_msg=name)
        print("OK distributed chunk == sequential")
    """)
    assert "OK" in out


def test_lm_train_step_compiles_and_runs_on_mesh():
    out = _run("""
        import dataclasses, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro import compat
        from repro.configs.base import get_arch, smoke_variant
        from repro.launch.mesh import sanitize_spec, tree_shardings
        from repro.launch.steps import (batch_struct, make_model,
                                        make_optimizer, make_train_step)
        mesh = compat.make_mesh((4, 2), ("data", "model"))
        cfg = dataclasses.replace(smoke_variant(get_arch("olmoe-1b-7b")),
                                  attn_chunk_k=64)
        model = make_model(cfg, mesh)
        opt = make_optimizer(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        opt_state = opt.init(params)
        p_sh = tree_shardings(mesh, model.param_specs(),
                              jax.eval_shape(lambda: params))
        params = jax.device_put(params, p_sh)
        step = jax.jit(make_train_step(model, opt), donate_argnums=(0, 1))
        x = jax.random.randint(jax.random.PRNGKey(1), (8, 64), 0,
                               cfg.vocab_size)
        batch = {"inputs": x, "labels": x}
        p2, o2, metrics = step(params, opt_state, batch)
        loss0 = float(metrics["loss"])
        for i in range(3):
            p2, o2, metrics = step(p2, o2, batch)
        assert float(metrics["loss"]) < loss0
        print("OK", loss0, "->", float(metrics["loss"]))
    """)
    assert "OK" in out


def test_checkpoint_elastic_reshard():
    out = _run("""
        import tempfile, jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro import compat
        from repro.checkpoint import Checkpointer

        mesh8 = compat.make_mesh((4, 2), ("data", "model"))
        mesh4 = compat.make_mesh((2, 2), ("data", "model"),
                                 devices=jax.devices()[:4])
        t = {"w": jax.device_put(jnp.arange(64, dtype=jnp.float32)
                                 .reshape(8, 8),
                                 NamedSharding(mesh8, P("data", "model")))}
        d = tempfile.mkdtemp()
        ck = Checkpointer(d)
        ck.save(1, t, blocking=True)
        got, _ = ck.restore(jax.tree.map(jnp.zeros_like, t),
                            shardings={"w": NamedSharding(
                                mesh4, P("data", "model"))})
        np.testing.assert_array_equal(np.asarray(got["w"]),
                                      np.asarray(t["w"]))
        assert got["w"].sharding.mesh.devices.size == 4
        print("OK elastic reshard 8 -> 4 devices")
    """)
    assert "OK" in out


def test_multipod_gradient_compression_psum():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro import compat
        from repro.optim.compression import (compress_with_error_feedback,
                                             init_ef)
        mesh = compat.make_mesh((2, 4), ("pod", "data"))

        def allreduce_compressed(g, ef):
            sparse, ef, dens = compress_with_error_feedback(
                {"g": g}, ef, k_frac=0.25)
            summed = jax.lax.psum(sparse["g"], "pod")
            return summed, ef

        f = compat.shard_map(
            lambda g, r: (jax.lax.psum(g, "pod"), r),
            mesh=mesh, in_specs=(jax.sharding.PartitionSpec("pod"),
                                 jax.sharding.PartitionSpec()),
            out_specs=(jax.sharding.PartitionSpec(),
                       jax.sharding.PartitionSpec()), check_vma=False)
        g = jnp.arange(16, dtype=jnp.float32).reshape(2, 8)
        s, _ = f(g, jnp.zeros((8,)))
        np.testing.assert_allclose(np.asarray(s).reshape(-1),
                                   np.asarray(g.sum(0)))
        print("OK pod-axis psum")
    """)
    assert "OK" in out

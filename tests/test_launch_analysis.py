"""Dry-run machinery units: HLO analyzer trip counting, spec sanitisation,
roofline math, collective parsing (fixed HLO snippets)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch import roofline as rl
from repro.launch.hlo_analysis import analyze, parse_module
from repro.launch.mesh import sanitize_spec


def test_hlo_analyzer_counts_scan_trips():
    x = jnp.zeros((256,), jnp.float32)
    Ws = jnp.zeros((6, 256, 256))

    def f(x, Ws):
        def body(c, W):
            return jnp.tanh(W @ c), None
        return jax.lax.scan(body, x, Ws)[0]

    txt = jax.jit(f).lower(x, Ws).compile().as_text()
    mc = analyze(txt)
    assert mc.dot_flops == pytest.approx(2 * 256 * 256 * 6, rel=0.01)
    assert any(l["trip"] == 6 for l in mc.loops)


def test_hlo_analyzer_nested_scans():
    x = jnp.zeros((128,), jnp.float32)
    Ws = jnp.zeros((3, 4, 128, 128))

    def f(x, Ws):
        def outer(c, Wrow):
            def inner(ci, W):
                return W @ ci, None
            return jax.lax.scan(inner, c, Wrow)[0], None
        return jax.lax.scan(outer, x, Ws)[0]

    txt = jax.jit(f).lower(x, Ws).compile().as_text()
    mc = analyze(txt)
    assert mc.dot_flops == pytest.approx(2 * 128 * 128 * 12, rel=0.01)


_FAKE_HLO = """
HloModule test

%cond (p: (s32[])) -> pred[] {
  %c = s32[] constant(24)
  ROOT %lt = pred[] compare(%gte, %c), direction=LT
}

%body (p: (s32[], f32[64,128])) -> (s32[], f32[64,128]) {
  %ag = f32[64,128]{1,0} all-gather(%gte2), replica_groups=[2,8]<=[16], dimensions={0}
  %ar = f32[64,128]{1,0} all-reduce(%ag), replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%add
}

ENTRY %main (a: f32[64,128]) -> f32[64,128] {
  %w = (s32[], f32[64,128]) while(%t), condition=%cond, body=%body
  %rs = f32[8,128]{1,0} reduce-scatter(%a2), replica_groups=[2,8]<=[16], dimensions={0}
}
"""


def test_collective_parse_and_trip_multiplication():
    mc = analyze(_FAKE_HLO, entry="main")
    b = 64 * 128 * 4
    # all-gather: G=8 (iota [2,8]) inside a 24-trip loop
    assert mc.coll_counts["all-gather"] == 24
    assert mc.coll_counts["all-reduce"] == 24
    assert mc.coll_counts["reduce-scatter"] == 1
    want_wire = (24 * (b * 7 / 8)            # AG
                 + 24 * (2 * b * 3 / 4)      # AR, G=4 curly groups
                 + (8 * 128 * 4) * 7)        # RS: out*(G-1)
    assert mc.coll_wire == pytest.approx(want_wire)


def test_sanitize_spec_drops_nondivisible():
    import os
    mesh = None

    class FakeMesh:
        shape = {"data": 16, "model": 16}
    m = FakeMesh()
    assert sanitize_spec(m, P("data", "model"), (32, 32)) == P("data",
                                                               "model")
    assert sanitize_spec(m, P("model"), (24,)) == P(None)
    assert sanitize_spec(m, P(("data", "model")), (512,)) == \
        P(("data", "model"))
    assert sanitize_spec(m, P(("data", "model")), (128,)) == P(None)
    # specs are padded to the full rank; non-divisible dims drop to None
    assert sanitize_spec(m, P("data"), (8, 4)) == P(None, None)
    assert sanitize_spec(m, P("data"), (32, 4)) == P("data", None)


def test_roofline_terms_and_bottleneck():
    t = rl.roofline_terms(197e12, 819e9 * 2, 50e9 * 3, chips=256)
    assert t["compute_s"] == pytest.approx(1.0)
    assert t["memory_s"] == pytest.approx(2.0)
    assert t["collective_s"] == pytest.approx(3.0)
    assert t["bottleneck"] == "collective"


def test_model_flops_formulas():
    from repro.configs.base import get_arch
    cfg = get_arch("qwen2-7b")
    f_train = rl.model_flops(cfg, int(7.6e9), int(7.6e9), 4096, 256, "train")
    assert f_train == pytest.approx(6 * 7.6e9 * 4096 * 256)
    f_dec = rl.model_flops(cfg, int(7.6e9), int(7.6e9), 32768, 128, "decode")
    assert f_dec == pytest.approx(2 * 7.6e9 * 128)


def test_active_params_moe():
    from repro.configs.base import get_arch
    from repro.models.transformer import LMModel
    cfg = get_arch("olmoe-1b-7b")
    shapes = jax.eval_shape(
        lambda: LMModel(cfg).init_params(jax.random.PRNGKey(0)))
    total = rl.count_params(shapes)
    active = rl.active_params(cfg, total)
    # OLMoE: ~6.9B total / ~1.3B active
    assert active < 0.35 * total
    assert active > 0.1 * total

"""Multi-process elastic runtime: observer-stamped heartbeat liveness,
generation-tagged checkpoint shards, and the supervisor/worker control
plane (``repro.runtime.control``).

The fast tests exercise the pure contracts (no subprocesses, no
collectives).  The ``slow``-marked integration test runs the real
thing: a supervisor, two worker processes joined under gloo CPU
collectives, a SIGKILL mid-run, and a verified resume -- via the same
``process_kill`` smoke scenario the CI gate runs, in a subprocess so
its ``jax.distributed`` state never leaks into this interpreter.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.checkpoint.checkpointer import (CheckpointCorrupt, Checkpointer,
                                           row_shard_filter)
from repro.runtime.elastic import Beat, HeartbeatObserver, surviving_pods


# --------------------------------------------------------------------------
# Heartbeat freshness: counters + observer clock, never pod wall clocks


def test_surviving_pods_ignores_pod_clocks():
    # pod 1's counter (3) could be a wildly skewed timestamp for all the
    # observer cares -- freshness comes only from the observer's stamp
    beats = {0: (7, 100.0), 1: (3, 50.0)}
    assert surviving_pods(beats, timeout_s=30.0, now=110.0) == [0]
    # both fresh when the observer saw both recently
    assert surviving_pods(beats, timeout_s=70.0, now=110.0) == [0, 1]


def test_boundary_equal_gap_counts_fresh():
    # now - stamped == timeout is the FIRST instant a pod may be
    # declared dead, not the last instant it may be declared alive
    beats = {0: Beat(counter=5, stamped=100.0)}
    assert surviving_pods(beats, timeout_s=10.0, now=110.0) == [0]
    assert surviving_pods(beats, timeout_s=10.0, now=110.0001) == []


def test_observer_stamps_changes_only():
    obs = HeartbeatObserver()
    assert obs.observe("a", 1, now=0.0)          # first sighting stamps
    # re-reading the same stale file never refreshes: the pod goes
    # stale on schedule even though the observer keeps polling it
    for t in (1.0, 5.0, 9.0):
        assert not obs.observe("a", 1, now=t)
    assert obs.survivors(timeout_s=8.0, now=9.0) == []
    # a counter change re-stamps with the OBSERVER's time of sighting
    assert obs.observe("a", 2, now=9.0)
    assert obs.survivors(timeout_s=8.0, now=9.0) == ["a"]


def test_observer_startup_grace_signal_and_forget():
    obs = HeartbeatObserver()
    obs.observe("a", 1, now=0.0)
    # changes == 0: published but never seen to progress.  (The
    # supervisor's startup-grace cutover is gated on beat CONTENT --
    # a step past the resume boundary -- because counter changes alone
    # also happen before the slow first-chunk compile.)
    assert obs.beats["a"].changes == 0
    obs.observe("a", 2, now=3.0)
    assert obs.beats["a"].changes == 1
    obs.forget("a")
    assert obs.survivors(timeout_s=100.0, now=3.0) == []


def test_tuple_counters_cross_generations():
    # the control plane publishes (generation, k): a relaunched worker
    # restarting its local counter at 1 still reads as progress because
    # the tuple differs -- equality is the only operation on counters
    obs = HeartbeatObserver()
    obs.observe(0, (0, 9), now=0.0)
    assert not obs.observe(0, (0, 9), now=50.0)
    assert obs.observe(0, (1, 1), now=50.0)
    assert obs.survivors(timeout_s=10.0, now=55.0) == [0]


# --------------------------------------------------------------------------
# Generation-tagged checkpoint shards


def _tree(seed, n=12, d=3):
    rng = np.random.default_rng(seed)
    return {"Y": rng.normal(size=(n, d)).astype(np.float32),
            "step": np.int32(seed)}


def _save_shard(ck, step, tree, host_id, n_hosts, generation, n=12):
    ck.save(step, tree, blocking=True, host_id=host_id, n_hosts=n_hosts,
            generation=generation,
            host_shard_filter=row_shard_filter(host_id, n_hosts, n))


def test_generation_tagged_shard_roundtrip(tmp_path):
    tree = _tree(7)
    # two writers (as two Checkpointer handles on the shared dir, like
    # two processes); the completing one commits the merged boundary
    _save_shard(Checkpointer(tmp_path), 4, tree, 0, 2, generation=3)
    assert not (tmp_path / "step_0000000004").exists()   # half-staged
    _save_shard(Checkpointer(tmp_path), 4, tree, 1, 2, generation=3)
    d = tmp_path / "step_0000000004"
    names = sorted(p.name for p in d.glob("*.npz"))
    assert names == ["shard000-of-002-g000003.npz",
                     "shard001-of-002-g000003.npz"]
    got, meta = Checkpointer(tmp_path).restore(_tree(0))
    assert meta["generation"] == 3
    np.testing.assert_array_equal(got["Y"], tree["Y"])


def test_stale_generation_shard_evicted_on_commit(tmp_path):
    # generation 0 died after staging only host 0's shard of step 8;
    # generation 1 (remeshed to one host) checkpoints the same step
    _save_shard(Checkpointer(tmp_path), 8, _tree(0), 0, 2, generation=0)
    _save_shard(Checkpointer(tmp_path), 8, _tree(1), 0, 1, generation=1)
    d = tmp_path / "step_0000000008"
    names = sorted(p.name for p in d.iterdir())
    assert names == ["meta.json", "shard000-of-001-g000001.npz"]
    meta = json.loads((d / "meta.json").read_text())
    assert meta["generation"] == 1
    # the completing writer recorded exactly what it swept out
    assert any("g000000" in f for f in meta["evicted_stale"])
    got, _ = Checkpointer(tmp_path).restore(_tree(9))
    np.testing.assert_array_equal(got["Y"], _tree(1)["Y"])


def test_commit_claim_gates_completing_writer(tmp_path):
    # two real SPMD writers can BOTH glob a complete shard set at a
    # near-simultaneous boundary; the O_EXCL claim lets exactly one
    # commit.  A completing save that finds the claim held must back
    # off -- neither committing nor erroring.
    ck = Checkpointer(tmp_path)
    _save_shard(ck, 8, _tree(0), 0, 2, generation=2)
    claim = tmp_path / ".tmp-8.claim-g000002"
    claim.touch()
    _save_shard(ck, 8, _tree(0), 1, 2, generation=2)  # full set, claimed
    assert not (tmp_path / "step_0000000008").exists()
    # claim released: the next completing write claims, commits, and
    # cleans the claim up
    claim.unlink()
    _save_shard(ck, 8, _tree(0), 1, 2, generation=2)
    assert (tmp_path / "step_0000000008" / "meta.json").exists()
    assert not claim.exists()


def test_commit_race_loser_never_destroys_committed_boundary(tmp_path):
    # the race's winner committed the boundary ...
    _save_shard(Checkpointer(tmp_path), 4, _tree(1), 0, 1, generation=1)
    d = tmp_path / "step_0000000004"
    winner_meta = (d / "meta.json").read_text()
    # ... and a straggling writer completes its own staged set for the
    # SAME step afterwards.  Its commit must fail soft: no rmtree of
    # the committed step dir, no spurious worker error -- the boundary
    # elastic resume depends on stays exactly as the winner wrote it.
    ck = Checkpointer(tmp_path)
    _save_shard(ck, 4, _tree(2), 0, 2, generation=1)
    _save_shard(ck, 4, _tree(2), 1, 2, generation=1)   # completing write
    assert (d / "meta.json").read_text() == winner_meta
    got, meta = Checkpointer(tmp_path).restore(_tree(0))
    assert meta["generation"] == 1
    np.testing.assert_array_equal(got["Y"], _tree(1)["Y"])


def test_manifest_filters_planted_stray_shard(tmp_path):
    _save_shard(Checkpointer(tmp_path), 8, _tree(1), 0, 1, generation=1)
    d = tmp_path / "step_0000000008"
    # a stale-generation shard that somehow survived into the committed
    # dir: the manifest-driven reader must not merge it ...
    np.savez(d / "shard000-of-001-g000000.npz",
             **{"Y||@rows0": _tree(0)["Y"]})
    got, _ = Checkpointer(tmp_path).restore(_tree(9), verify=False)
    np.testing.assert_array_equal(got["Y"], _tree(1)["Y"])
    # ... and the verifying reader flags it as a stray
    with pytest.raises(CheckpointCorrupt, match="not in manifest"):
        Checkpointer(tmp_path).verify_step(8)


# --------------------------------------------------------------------------
# Supervisor-side helpers (JAX-free)


def test_committed_steps_listing(tmp_path):
    from repro.runtime import control
    for s, committed in [(4, True), (8, True), (12, False)]:
        d = tmp_path / f"step_{s:010d}"
        d.mkdir()
        if committed:
            (d / "meta.json").write_text("{}")
    assert control.committed_steps(tmp_path) == [4, 8]
    assert control.committed_steps(tmp_path / "missing") == []


def test_beat_writer_feeds_observer(tmp_path):
    from repro.runtime import control
    beat = control._beat_writer(tmp_path, pod=1, generation=2)
    obs = HeartbeatObserver()
    for it, t in [(0, 0.0), (4, 1.0)]:
        beat(it)
        rec = json.loads((tmp_path / "pod1.beat").read_text())
        assert rec["generation"] == 2 and rec["step"] == it
        assert obs.observe(1, (rec["generation"], rec["counter"]), now=t)
    assert obs.beats[1].changes == 1


def test_read_beat_returns_counter_and_step(tmp_path):
    from repro.runtime import control
    sup = control.Supervisor(tmp_path, n_pods=1)
    assert sup._read_beat(0) is None        # absent file: no reading
    (sup.hb_dir / "pod0.beat").write_text(json.dumps(
        {"pod": 0, "generation": 3, "counter": 5, "step": 12}))
    assert sup._read_beat(0) == ((3, 5), 12)
    (sup.hb_dir / "pod0.beat").write_text("{torn")
    assert sup._read_beat(0) is None        # torn file: no reading


def test_spawn_sweeps_stale_beat_files(tmp_path):
    # a relaunched generation must not inherit the dead generation's
    # beat files: the new worker's first write would read as progress,
    # cutting startup grace down to the steady-state timeout while the
    # worker is still compiling
    from repro.runtime import control
    sup = control.Supervisor(tmp_path, n_pods=2)
    (sup.hb_dir / "pod0.beat").write_text(json.dumps(
        {"pod": 0, "generation": 0, "counter": 7, "step": 8}))
    (sup.hb_dir / "pod1.beat.tmp").write_text("torn atomic-write stray")
    sup._clear_beats()
    assert list(sup.hb_dir.iterdir()) == []


# --------------------------------------------------------------------------
# The real thing: 2 processes, gloo, SIGKILL, supervised resume


@pytest.mark.slow
def test_process_kill_smoke_two_real_processes(tmp_path):
    if os.environ.get("FUNCSNE_NO_MULTIPROCESS") == "1":
        pytest.skip("FUNCSNE_NO_MULTIPROCESS=1")
    from repro.runtime import control
    if not control.gloo_available():
        pytest.skip("no gloo CPU collectives in this jaxlib")
    import repro
    src = os.path.dirname(list(repro.__path__)[0])
    env = dict(os.environ, PYTHONPATH=src, XLA_FLAGS="")
    # subprocess isolation: the scenario spawns its own supervisor and
    # worker pods; nothing distributed touches this interpreter
    proc = subprocess.run(
        [sys.executable, "-m", "repro.runtime.faults", "--smoke",
         "--only", "process_kill"],
        env=env, cwd=tmp_path, capture_output=True, text=True, timeout=420)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "process_kill: OK" in proc.stdout, proc.stdout

"""Shard-blind probe fix + multi-host elastic resume (subprocess: 8 fake
CPU devices, same convention as tests/test_distributed.py).

The regression under test: health telemetry used to be computed per
replica and read from shard 0 only (``out_specs=P()`` under
``check_vma=False`` hands the host the first addressable shard's value),
so a NaN confined to another device's replica never tripped the global
rollback.  The fix probes each shard's own row slice and pmin/pmax-es
the scalars across the mesh inside the chunk program; the legacy path is
kept behind ``health_reduce=False`` as the positive-control anchor.
"""
import os
import subprocess
import sys
import textwrap

import pytest

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, timeout=560):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = _SRC
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


# shares the test bodies' 8-space indent so the concatenation dedents
_SETUP = """
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro import compat
        from repro.data.synthetic import blobs
        from repro.core import funcsne
        from repro.runtime import faults

        X, _ = blobs(n=256, dim=16, n_centers=4, center_std=6.0)
        Xj = jnp.asarray(X)
        mesh = compat.make_mesh((8, 1), ("data", "model"))
        cfg = funcsne.FuncSNEConfig(n_points=256, dim_hd=16,
                                    backend="xla")
        st0 = funcsne.init_state(jax.random.PRNGKey(0), Xj, cfg)
        hp = funcsne.default_hparams(256)
        Xs = jax.device_put(Xj, NamedSharding(mesh, P(None, "model")))
        cp = lambda s: jax.device_put(
            jax.tree.map(lambda a: jnp.array(a, copy=True), s),
            NamedSharding(mesh, P()))
"""


def test_shard_confined_nan_trips_reduced_probe_only():
    """Positive control for the shard-blind bug: NaN rows in ONE
    device's replica of ``vel`` (purely local momentum update -> the NaN
    reaches only that device's Y within the step).  The legacy
    ``health_reduce=False`` probe reports a perfectly healthy chunk; the
    mesh-reduced probe trips with the correct shard-local finite
    fraction and first-bad-step."""
    out = _run(_SETUP + """
        def probe(reduce):
            chunk, _ = funcsne.make_distributed_step(
                cfg, mesh, chunk=1, health_reduce=reduce)
            st = cp(st0)
            st = faults.NaNChunk(at_step=0, shard=3, field="vel",
                                 rows=4).apply(st, 0)
            _, _, m = chunk(st, Xs, hp)
            return float(m.finite_frac), int(m.bad_step)

        ff_blind, bad_blind = probe(False)
        ff_mesh, bad_mesh = probe(True)
        # the old probe commits the corruption silently...
        assert ff_blind == 1.0 and bad_blind == -1, (ff_blind, bad_blind)
        # ...the reduced probe reports shard 3's slice: 4 of its 32 rows
        # went NaN at step 0
        np.testing.assert_allclose(ff_mesh, 28.0 / 32.0, rtol=1e-6)
        assert bad_mesh == 0, bad_mesh
        print("OK shard-blind positive control")
    """)
    assert "OK" in out


def test_shard_confined_nan_rolls_back_deterministically():
    """End-to-end on the coordinator: the shard-confined fault trips the
    global probe, the rollback-retry completes the run finite, and the
    whole faulted run is bit-deterministic (two identical runs agree
    exactly -- retry replays the same chunk program from the same
    replicated anchor)."""
    out = _run(_SETUP + """
        from repro.core.resilience import ResiliencePolicy
        from repro.runtime.coordinator import fit_elastic

        def run():
            policy = ResiliencePolicy(max_retries=2)
            with faults.active(faults.FaultScript(faults.NaNChunk(
                    at_step=8, shard=5, field="vel", rows=4))):
                st = fit_elastic(Xj, cfg=cfg, n_iter=16, chunk_size=4,
                                 resilience=policy)
            return st, policy

        st_a, pol_a = run()
        st_b, _ = run()
        kinds = [e["kind"] for e in pol_a.events]
        assert "rollback" in kinds, kinds
        assert int(st_a.step) == 16
        assert bool(jnp.isfinite(st_a.Y).all())
        np.testing.assert_array_equal(np.asarray(st_a.Y),
                                      np.asarray(st_b.Y))
        print("OK rollback", kinds.count("rollback"))
    """)
    assert "OK" in out


def test_per_host_shard_checkpoint_merges_on_restore():
    """Each simulated host writes only its row slice (+ host 0 the
    replicated leaves); the committed step dir restores to the full
    state, including onto a smaller mesh."""
    out = _run("""
        import tempfile, jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro import compat
        from repro.checkpoint import Checkpointer, row_shard_filter

        n = 64
        tree = {"Y": jnp.arange(n * 2, dtype=jnp.float32).reshape(n, 2),
                "idx": jnp.arange(n * 3, dtype=jnp.int32).reshape(n, 3),
                "zhat": jnp.float32(3.5),
                "key": jnp.arange(2, dtype=jnp.uint32)}
        d = tempfile.mkdtemp()
        ck = Checkpointer(d)
        H = 4
        for h in range(H):
            ck.save(7, tree, blocking=True,
                    host_shard_filter=row_shard_filter(h, H, n),
                    host_id=h, n_hosts=H)
        # the step dir only commits once every host's part landed
        assert ck.latest_step() == 7
        files = sorted(p.name for p in (ck.dir / "step_0000000007")
                       .glob("shard*.npz"))
        assert len(files) == H, files

        mesh = compat.make_mesh((2,), ("data",),
                                devices=jax.devices()[:2])
        got, meta = ck.restore(
            jax.tree.map(jnp.zeros_like, tree),
            shardings=jax.tree.map(
                lambda _: NamedSharding(mesh, P()), tree))
        assert meta["n_hosts"] == H
        for k in tree:
            np.testing.assert_array_equal(np.asarray(got[k]),
                                          np.asarray(tree[k]), err_msg=k)
        assert got["Y"].sharding.mesh.devices.size == 2
        print("OK per-host shards merge", files)
    """)
    assert "OK" in out


def test_partial_shard_set_does_not_commit():
    """A step dir with a missing host part must stay invisible: restore
    keeps serving the previous committed step."""
    out = _run("""
        import tempfile, jax.numpy as jnp, numpy as np
        from repro.checkpoint import Checkpointer, row_shard_filter

        n = 16
        tree = {"Y": jnp.ones((n, 2), jnp.float32)}
        d = tempfile.mkdtemp()
        ck = Checkpointer(d)
        ck.save(1, tree, blocking=True)             # committed baseline
        ck.save(2, {"Y": tree["Y"] * 2}, blocking=True,
                host_shard_filter=row_shard_filter(0, 2, n),
                host_id=0, n_hosts=2)               # host 1 never writes
        assert ck.latest_step() == 1, ck.all_steps()
        got, meta = ck.restore({"Y": jnp.zeros((n, 2))})
        assert meta["step"] == 1
        np.testing.assert_array_equal(np.asarray(got["Y"]),
                                      np.asarray(tree["Y"]))
        print("OK partial set stays uncommitted")
    """)
    assert "OK" in out


def test_remesh_uses_every_device_or_reports_drops():
    """6 devices at a requested model width of 4: the old remesh built
    (1, 4) and silently discarded two devices.  Now it picks the largest
    feasible width <= request ((2, 3) -- all six devices used), honours
    extra divisibility constraints, and when forced (exact_model) emits
    a structured devices_dropped event instead of staying silent."""
    out = _run("""
        import jax
        from repro.runtime import elastic

        elastic.reset_events()
        devs = jax.devices()[:6]

        mesh = elastic.remesh(model=4, devices=devs)
        assert dict(mesh.shape) == {"data": 2, "model": 3}, mesh.shape
        assert mesh.devices.size == 6          # nobody on the floor
        assert elastic.n_events() == 0

        # model axis shards a feature dim of 8 -> width must divide both
        mesh = elastic.remesh(model=4, devices=devs, divides=(8,))
        assert dict(mesh.shape) == {"data": 3, "model": 2}, mesh.shape

        seen = []
        mesh = elastic.remesh(model=4, devices=devs, exact_model=True,
                              on_event=seen.append)
        assert dict(mesh.shape) == {"data": 1, "model": 4}, mesh.shape
        (ev,) = seen
        assert ev["kind"] == "devices_dropped" and ev["n_dropped"] == 2
        assert elastic.events()[-1] == ev       # module log too
        print("OK remesh", dict(mesh.shape))
    """)
    assert "OK" in out


@pytest.mark.slow
def test_host_loss_elastic_resume_matches_uninterrupted_quality():
    """Kill one simulated host mid-run: the coordinator resumes on the
    shrunken mesh from the last committed boundary and finishes every
    iteration.  Bitwise parity with the uninterrupted run is not
    expected (the smaller mesh regroups the force psum), so the
    acceptance bound is embedding quality: R_NX AUC within tolerance of
    the uninterrupted run on the same data."""
    out = _run("""
        import tempfile, jax, jax.numpy as jnp, numpy as np
        from repro.data.synthetic import blobs
        from repro.core import funcsne
        from repro.core.quality import embedding_quality
        from repro.core.resilience import ResiliencePolicy
        from repro.runtime import faults
        from repro.runtime.coordinator import fit_elastic

        X, _ = blobs(n=256, dim=16, n_centers=4, center_std=6.0)
        Xj = jnp.asarray(X)
        cfg = funcsne.FuncSNEConfig(n_points=256, dim_hd=16,
                                    backend="xla")
        kw = dict(cfg=cfg, n_iter=96, chunk_size=8, n_hosts=2)

        st_ref = fit_elastic(Xj, resilience=ResiliencePolicy(), **kw)

        d = tempfile.mkdtemp()
        policy = ResiliencePolicy(checkpoint_dir=d, checkpoint_every=1)
        with faults.active(faults.FaultScript(
                faults.HostLoss(at_step=40, host=1))):
            st = fit_elastic(Xj, resilience=policy, **kw)

        assert int(st.step) == 96, int(st.step)
        assert bool(jnp.isfinite(st.Y).all())
        kinds = [e["kind"] for e in policy.events]
        assert "host_lost" in kinds and "remesh" in kinds, kinds
        rm = next(e for e in policy.events if e["kind"] == "remesh")
        assert rm["step"] == 40 and rm["n_devices"] == 4, rm

        q_ref = float(embedding_quality(Xj, jnp.asarray(st_ref.Y)))
        q_got = float(embedding_quality(Xj, jnp.asarray(st.Y)))
        assert q_got > q_ref - 0.05, (q_ref, q_got)
        print("OK elastic resume", q_ref, "->", q_got)
    """)
    assert "OK" in out

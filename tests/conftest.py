import os
import sys

# Tests and benches must see exactly 1 device (the dry-run sets its own
# XLA_FLAGS); keep any user flags but never force a device count here.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)

"""Compatibility shims over moving JAX APIs (supports jax >= 0.4.37).

The distribution layer targets the modern surface (``jax.shard_map`` with
``check_vma``, ``jax.make_mesh(..., axis_types=...)``, ``AxisType``); on
older installs we fall back to ``jax.experimental.shard_map`` /
``check_rep`` and positional ``make_mesh``.  Import from here, never from
``jax.sharding`` directly, for any of these three names.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5-era explicit-sharding API
    from jax.sharding import AxisType  # type: ignore[attr-defined]
    _HAVE_AXIS_TYPE = True
except ImportError:  # pragma: no cover - depends on installed jax
    _HAVE_AXIS_TYPE = False

    class AxisType:  # minimal stand-in: old meshes behave as Auto
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"


def make_mesh(axis_shapes, axis_names, *, axis_types=None, devices=None):
    """``jax.make_mesh`` that tolerates the absence of ``axis_types``."""
    kwargs = {"devices": devices} if devices is not None else {}
    if _HAVE_AXIS_TYPE:
        if axis_types is None:
            axis_types = (AxisType.Auto,) * len(tuple(axis_names))
        try:
            return jax.make_mesh(axis_shapes, axis_names,
                                 axis_types=axis_types, **kwargs)
        except TypeError:  # pragma: no cover - transitional versions
            pass
    return jax.make_mesh(axis_shapes, axis_names, **kwargs)


def tpu_compiler_params(*, dimension_semantics=None, **kwargs):
    """Mosaic compiler params across the ``TPUCompilerParams`` ->
    ``CompilerParams`` rename (jax 0.4.x vs newer).  Used to annotate
    pallas grids with ``dimension_semantics`` ('parallel' axes may be
    split across TensorCores; 'arbitrary' axes are sequential revisits,
    e.g. accumulation over feature chunks)."""
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None) \
        or getattr(pltpu, "TPUCompilerParams")
    if dimension_semantics is not None:
        kwargs["dimension_semantics"] = tuple(dimension_semantics)
    return cls(**kwargs)


if hasattr(jax, "shard_map"):
    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
else:  # pragma: no cover - depends on installed jax
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True):
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check_vma)

"""Synthetic HD datasets matching the paper's evaluation suite.

The paper evaluates on Gaussian blobs (overlapping / disjoint), COIL-20
(ring manifolds), an S-curve, MNIST and single-cell data.  Offline we
generate structured stand-ins with the same geometry: blobs with
controllable separation, ring manifolds ('coil'), an S-curve with optional
unbalanced sampling (paper Fig. 1), and a hierarchical mixture ('cells')
mimicking the cluster-of-clusters structure of transcriptomics data.
"""
from __future__ import annotations

import numpy as np


def blobs(n: int = 2000, dim: int = 32, n_centers: int = 5,
          center_std: float = 1.0, blob_std: float = 1.0, seed: int = 0):
    """Gaussian blobs; 'overlapping' = large blob_std, small center_std."""
    rng = np.random.default_rng(seed)
    centers = rng.normal(0.0, center_std, (n_centers, dim))
    labels = rng.integers(0, n_centers, n)
    X = centers[labels] + rng.normal(0.0, blob_std, (n, dim))
    return X.astype(np.float32), labels.astype(np.int32)


def disjoint_blobs(n: int = 30000, dim: int = 32, n_centers: int = 1000,
                   seed: int = 0):
    """Paper Fig. 7 'Disjointed': many tiny well-separated clusters."""
    rng = np.random.default_rng(seed)
    centers = rng.normal(0.0, 10.0, (n_centers, dim))
    labels = np.resize(np.repeat(np.arange(n_centers),
                                 max(1, -(-n // n_centers))), n)
    X = centers[labels] + rng.normal(0.0, 0.05, (n, dim))
    return X.astype(np.float32), labels.astype(np.int32)


def s_curve(n: int = 2000, noise: float = 0.0, unbalanced: bool = False,
            seed: int = 0):
    """3-D 'S' sheet (paper Fig. 1); unbalanced halves optional."""
    rng = np.random.default_rng(seed)
    if unbalanced:
        n_top = int(n * 10 / 11)
        t = np.concatenate([rng.uniform(0.0, 0.5, n - n_top),
                            rng.uniform(0.5, 1.0, n_top)])
    else:
        t = rng.uniform(0.0, 1.0, n)
    theta = 3.0 * np.pi * (t - 0.5)
    y = rng.uniform(0.0, 2.0, n)
    X = np.stack([np.sin(theta), y, np.sign(theta) * (np.cos(theta) - 1.0)],
                 axis=1)
    X += rng.normal(0.0, noise, X.shape)
    labels = (t > 0.5).astype(np.int32)
    return X.astype(np.float32), labels


def coil_rings(n_objects: int = 20, n_per_object: int = 72, dim: int = 32,
               radius: float = 1.0, separation: float = 6.0, seed: int = 0):
    """COIL-20 stand-in: ring manifolds in random 2-D subspaces of R^dim."""
    rng = np.random.default_rng(seed)
    xs, labels = [], []
    for o in range(n_objects):
        basis = np.linalg.qr(rng.normal(size=(dim, 2)))[0]
        center = rng.normal(0.0, separation, dim)
        ang = np.linspace(0.0, 2 * np.pi, n_per_object, endpoint=False)
        ring = np.stack([np.cos(ang), np.sin(ang)], 1) * radius
        xs.append(center + ring @ basis.T)
        labels.append(np.full(n_per_object, o))
    X = np.concatenate(xs).astype(np.float32)
    return X, np.concatenate(labels).astype(np.int32)


def hierarchical_cells(n: int = 4000, dim: int = 50, n_major: int = 4,
                       minors_per_major: int = 4, seed: int = 0):
    """Transcriptomics stand-in: major types -> sub-types -> cells."""
    rng = np.random.default_rng(seed)
    Xs, major_l, minor_l = [], [], []
    per = n // (n_major * minors_per_major)
    for a in range(n_major):
        major = rng.normal(0.0, 8.0, dim)
        for b in range(minors_per_major):
            minor = major + rng.normal(0.0, 2.0, dim)
            Xs.append(minor + rng.normal(0.0, 0.5, (per, dim)))
            major_l += [a] * per
            minor_l += [a * minors_per_major + b] * per
    X = np.concatenate(Xs).astype(np.float32)
    return (X, np.array(major_l, np.int32), np.array(minor_l, np.int32))


def mnist_like(n: int = 4000, dim: int = 64, n_classes: int = 10,
               manifold_dim: int = 3, seed: int = 0):
    """MNIST stand-in: per-class smooth low-dim manifolds in R^dim."""
    rng = np.random.default_rng(seed)
    Xs, labels = [], []
    per = n // n_classes
    for c in range(n_classes):
        basis = np.linalg.qr(rng.normal(size=(dim, manifold_dim)))[0]
        center = rng.normal(0.0, 6.0, dim)
        t = rng.uniform(-1.0, 1.0, (per, manifold_dim))
        Xs.append(center + (t ** 3) @ basis.T * 3.0
                  + rng.normal(0.0, 0.2, (per, dim)))
        labels += [c] * per
    return (np.concatenate(Xs).astype(np.float32),
            np.array(labels, np.int32))

"""Synthetic token stream for LM training (offline container, no corpora).

Zipf-distributed unigrams composed with a first-order Markov structure so
the loss has learnable signal; deterministic per (seed, step) so restart
recovery can assert bit-exact data-order resumption.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenStreamConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    zipf_a: float = 1.2
    markov_states: int = 64
    seed: int = 0


class TokenStream:
    """Deterministic synthetic next-token data, shardable by host."""

    def __init__(self, cfg: TokenStreamConfig, host_id: int = 0,
                 n_hosts: int = 1):
        self.cfg = cfg
        self.host_id = host_id
        self.n_hosts = n_hosts
        assert cfg.global_batch % n_hosts == 0
        self.local_batch = cfg.global_batch // n_hosts
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        # Markov chain over latent states; each state emits a Zipf slice
        self._trans = rng.dirichlet(np.ones(cfg.markov_states) * 0.2,
                                    size=cfg.markov_states)
        ranks = np.arange(1, v + 1, dtype=np.float64)
        zipf = ranks ** (-cfg.zipf_a)
        self._emit = np.stack([
            np.roll(zipf, rng.integers(0, v)) for _ in
            range(cfg.markov_states)])
        self._emit /= self._emit.sum(axis=1, keepdims=True)

    def batch(self, step: int):
        """(local_batch, seq_len+1) int32 tokens for this host and step."""
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed, step, self.host_id, 0xC0FFEE))
        b, s = self.local_batch, cfg.seq_len + 1
        states = np.zeros((b,), np.int64)
        out = np.empty((b, s), np.int32)
        cum_t = np.cumsum(self._trans, axis=1)
        cum_e = np.cumsum(self._emit, axis=1)
        u_t = rng.random((b, s))
        u_e = rng.random((b, s))
        for t in range(s):
            states = (cum_t[states] < u_t[:, t:t + 1]).sum(axis=1)
            states = np.minimum(states, cfg.markov_states - 1)
            tok = (cum_e[states] < u_e[:, t:t + 1]).sum(axis=1)
            out[:, t] = np.minimum(tok, cfg.vocab_size - 1)
        return out

    def train_pair(self, step: int):
        """(tokens, labels) = (x[:, :-1], x[:, 1:])."""
        x = self.batch(step)
        return x[:, :-1], x[:, 1:]

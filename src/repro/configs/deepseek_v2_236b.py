"""DeepSeek-V2 236B [moe]: MLA (kv_lora=512) + 2 shared + 160 routed top-6
experts (arXiv:2405.04434).  First layer dense FFN per the paper."""
from repro.configs.base import ArchConfig, register

register(ArchConfig(
    name="deepseek-v2-236b", family="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
    d_ff=12288,                       # dense first-layer FFN width
    vocab_size=102400, head_dim=128,
    n_experts=160, moe_top_k=6, n_shared_experts=2, d_ff_expert=1536,
    moe_dense_first=True,
    kv_lora_rank=512, q_nope_dim=128, q_rope_dim=64, v_head_dim=128,
    rope_theta=10000.0,
    param_dtype="bfloat16", opt_state_dtype="int8",   # 236B on 16 GiB chips
    logits_chunks=8,
    moe_impl="a2a",            # §Perf H1: shard_map all-to-all EP
))

"""Gemma2-2B [dense/gemma2]: alternating local(4096)/global attention,
attn softcap 50, final softcap 30, sandwich norms, GeGLU
(arXiv:2408.00118)."""
from repro.configs.base import ArchConfig, register

register(ArchConfig(
    name="gemma2-2b", family="gemma2",
    n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4,
    d_ff=9216, vocab_size=256000, head_dim=256,
    alt_local_global=True, local_window=4096,
    attn_softcap=50.0, final_softcap=30.0,
    post_block_norm=True, norm_plus_one=True, mlp_act="geglu",
    scale_embeddings=True, tie_embeddings=True,
    rope_theta=10000.0,
    logits_chunks=16,
))

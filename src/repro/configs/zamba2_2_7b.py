"""Zamba2-2.7B [hybrid]: Mamba2 backbone + shared attention block applied
every 6 layers (arXiv:2411.15242).  54 mamba layers -> 9 super-blocks."""
from repro.configs.base import ArchConfig, register

register(ArchConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=10240, vocab_size=32000, head_dim=80,
    ssm_state=64, ssm_expand=2, ssm_headdim=64, ssm_chunk=256,
    shared_attn_every=6,
    rope_theta=10000.0,
    supports_long=True,
))

"""ArchConfig: one dataclass describing every assigned architecture.

The 10 assigned archs are registered by their own module in this package;
``get_arch(id)`` resolves them.  ``smoke_variant`` shrinks any config to a
CPU-runnable size for the per-arch smoke tests (same family/topology, tiny
widths), per the assignment instructions.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | gemma2 | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    scale_embeddings: bool = False      # gemma: h *= sqrt(d_model)
    input_mode: str = "tokens"          # 'tokens' | 'embeds' (modality stub)
    # gemma2
    attn_softcap: float = 0.0
    final_softcap: float = 0.0
    local_window: int = 0
    alt_local_global: bool = False
    post_block_norm: bool = False
    norm_plus_one: bool = False         # gemma: scale = (1 + w)
    mlp_act: str = "swiglu"             # 'swiglu' | 'geglu'
    # moe
    n_experts: int = 0
    moe_top_k: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    router_z_weight: float = 0.001
    moe_dense_first: bool = False       # DeepSeek-V2: first layer dense FFN
    moe_impl: str = "gspmd"             # 'gspmd' | 'a2a' (shard_map EP)
    # mla (DeepSeek-V2); kv_lora_rank > 0 enables MLA attention
    kv_lora_rank: int = 0
    q_nope_dim: int = 128
    q_rope_dim: int = 64
    v_head_dim: int = 128
    # ssm (mamba2 / zamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_headdim: int = 64
    ssm_chunk: int = 128
    ssm_ngroups: int = 1
    # hybrid (zamba2): shared attn+mlp block applied every k mamba layers
    shared_attn_every: int = 0
    # numerics / memory policy
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    opt_state_dtype: str = "float32"    # 'int8' enables quantised Adam moments
    remat: bool = True
    remat_policy: str = "nothing"       # 'nothing' | 'dots_no_batch' | 'none'
    # treat the model axis as extra data parallelism when the global batch
    # divides the full mesh (right call for sub-1B archs; §Perf H9)
    pure_dp: bool = False
    logits_chunks: int = 1
    attn_chunk_q: int = 512
    attn_chunk_k: int = 1024
    # which shapes this arch supports ('long_500k' only for sub-quadratic)
    supports_long: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_mla(self) -> bool:
        return self.kv_lora_rank > 0

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # 'train' | 'prefill' | 'decode'


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

ARCH_IDS = [
    "chameleon-34b", "olmoe-1b-7b", "deepseek-v2-236b", "zamba2-2.7b",
    "mamba2-130m", "yi-34b", "qwen2.5-14b", "gemma2-2b", "qwen2-7b",
    "musicgen-large",
]

_REGISTRY: Dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ArchConfig:
    if name not in _REGISTRY:
        importlib.import_module(
            f"repro.configs.{name.replace('-', '_').replace('.', '_')}")
    return _REGISTRY[name]


def list_archs():
    return list(ARCH_IDS)


def smoke_variant(cfg: ArchConfig) -> ArchConfig:
    """Reduced same-family config for CPU smoke tests."""
    ssm_headdim = 16 if cfg.ssm_state else cfg.ssm_headdim
    repl = dict(
        n_layers=min(cfg.n_layers, 4 if cfg.shared_attn_every else 2),
        d_model=128, n_heads=4, n_kv_heads=min(cfg.n_kv_heads, 2) or 2,
        head_dim=32, d_ff=min(cfg.d_ff, 256) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
        n_experts=min(cfg.n_experts, 8), d_ff_expert=64 if cfg.is_moe else 0,
        moe_top_k=min(cfg.moe_top_k, 2),
        n_shared_experts=min(cfg.n_shared_experts, 1),
        kv_lora_rank=32 if cfg.is_mla else 0,
        q_nope_dim=32 if cfg.is_mla else cfg.q_nope_dim,
        q_rope_dim=16 if cfg.is_mla else cfg.q_rope_dim,
        v_head_dim=32 if cfg.is_mla else cfg.v_head_dim,
        ssm_state=min(cfg.ssm_state, 16), ssm_headdim=ssm_headdim,
        ssm_chunk=32,
        shared_attn_every=2 if cfg.shared_attn_every else 0,
        local_window=64 if cfg.local_window else 0,
        logits_chunks=1, attn_chunk_q=64, attn_chunk_k=64,
        param_dtype="float32", compute_dtype="float32",
        opt_state_dtype="float32", remat=False,
        name=cfg.name + "-smoke",
    )
    return dataclasses.replace(cfg, **repl)

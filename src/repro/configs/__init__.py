"""Architecture configs: the 10 assigned archs + reduced smoke variants."""

from repro.configs.base import (  # noqa: F401
    ArchConfig, ShapeSpec, SHAPES, get_arch, list_archs, smoke_variant)

"""OLMoE-1B-7B [moe]: 64 experts, top-8, d_ff_expert=1024 (arXiv:2409.02060)."""
from repro.configs.base import ArchConfig, register

register(ArchConfig(
    name="olmoe-1b-7b", family="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1024, vocab_size=50304, head_dim=128,
    n_experts=64, moe_top_k=8, d_ff_expert=1024,
    rope_theta=10000.0,
    logits_chunks=2,
    moe_impl="a2a",            # §Perf H1: shard_map all-to-all EP
))

"""Mamba2-130M [ssm]: pure SSD, attention-free (arXiv:2405.21060)."""
from repro.configs.base import ArchConfig, register

register(ArchConfig(
    name="mamba2-130m", family="ssm",
    n_layers=24, d_model=768, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_expand=2, ssm_headdim=64, ssm_chunk=256,
    supports_long=True,
    pure_dp=True,               # §Perf H9: model axis as extra DP
))

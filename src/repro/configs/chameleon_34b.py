"""Chameleon-34B [vlm]: early-fusion mixed-modal decoder (arXiv:2405.09818).

VQ image tokens share the 65536-entry vocab, so the backbone is a dense
GQA decoder in token mode; the VQ-GAN tokenizer frontend is a stub per the
assignment (tokens arrive pre-quantised).
"""
from repro.configs.base import ArchConfig, register

register(ArchConfig(
    name="chameleon-34b", family="vlm",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22016, vocab_size=65536, head_dim=128,
    rope_theta=10000.0,
    param_dtype="bfloat16", opt_state_dtype="int8",   # 34B on 16 GiB chips
    logits_chunks=8,
))

"""MusicGen-large [audio]: decoder-only over EnCodec tokens
(arXiv:2306.05284).  The EnCodec frontend is a stub per the assignment:
input_specs feeds precomputed (B, S, d_model) frame embeddings; targets are
codebook tokens (vocab 2048)."""
from repro.configs.base import ArchConfig, register

register(ArchConfig(
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=2048, head_dim=64,
    input_mode="embeds",
    rope_theta=10000.0,
))

"""Yi-34B [dense]: llama-arch GQA kv=8 (arXiv:2403.04652)."""
from repro.configs.base import ArchConfig, register

register(ArchConfig(
    name="yi-34b", family="dense",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=20480, vocab_size=64000, head_dim=128,
    rope_theta=5000000.0,
    param_dtype="bfloat16", opt_state_dtype="int8",
    logits_chunks=4,
))

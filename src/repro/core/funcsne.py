"""FUnc-SNE: fast, unconstrained neighbour embedding (paper Sec. 3).

One ``funcsne_step`` fuses, in a single fixed-shape XLA/TPU program:

  1. stochastic HD neighbour refinement (prob 0.05 + 0.95 E[N_new/N]),
     candidates drawn from HD/LD neighbours-of-neighbours + cross-space
     + uniform probes (the joint iterative KNN),
  2. flag-driven perplexity (sigma_i) refresh with warm restart,
  3. systematic LD neighbour refinement,
  4. variable-tail forces: attraction over the HD set, repulsion over the
     LD set (the paper's novel middle term of Eq. 6) + negative-sampling
     far field with an EMA'd Z estimator,
  5. t-SNE-style gains/momentum update of the embedding.

Hyperparameters that the paper exposes interactively (alpha, perplexity,
attraction/repulsion ratio, lr, exaggeration) are *traced scalars*
(``HParams``) so changing them never recompiles -- the headless equivalent
of the paper's instant-GUI-feedback property.

Driver surface (one traced step, three dispatch granularities):

  ``make_step(cfg)``
      jitted single-device ``step(st, X, hp) -> st``; one dispatch per
      iteration (interactive GUIs that must see every frame).
  ``make_chunked_step(cfg, T, schedule=, n_iter=, snapshot_every=)``
      jitted ``chunk(st, X, hp) -> (st, snaps, ChunkMetrics)``: T
      iterations inside ONE ``lax.scan`` device program (§Perf H15) --
      the hyperparameter schedule runs on device from the carried
      ``st.step``, snapshots land in a device-side ``(n_snap, n, d)``
      ring, and per-step scalars are EMA'd into one ChunkMetrics sync
      per chunk.  ``fit`` and ``launch/embed.py`` run on this.
  ``make_distributed_step(cfg, mesh, ..., chunk=None)``
      the same two contracts under ``shard_map``: ``chunk=None`` keeps
      the classic one-step program, ``chunk=T`` the scan-chunked one.
  ``fit(..., resilience=ResiliencePolicy(...), resume_from=dir)``
      the resilient outer loop on the chunked driver: the chunk scan
      folds health telemetry into :class:`ChunkMetrics` (finite fraction
      of Y over active rows, max |Y|, first bad step -- zero extra host
      syncs), a tripped probe rolls back to the last healthy chunk
      boundary and retries with backed-off lr/exaggeration (bounded,
      then ``EmbeddingDiverged``), the full state checkpoints through
      ``repro.checkpoint`` for bit-deterministic resume, Pallas launch
      failures demote per kernel family to the XLA refs
      (``repro.kernels.fallback``), and ``repro.runtime.faults`` injects
      every one of those failures deterministically in tests/CI.

Config flag matrix (orthogonal, all combinations tested):
  ``gather_fused``   True: kernels take indices and DMA rows in-kernel
                     (§H12/H13); False: legacy pre-gather wiring
                     (bit-equivalence anchor).
  ``scatter_fused``  True: symmetrisation binned in-kernel into (N, d)
                     partials (§H14; requires gather_fused); False:
                     edge-emitting epilogue + XLA scatters.
  ``merge_fused``    True: the neighbour-selection epilogue (dedup +
                     sorted top-K merge) runs inside the gather kernel
                     (§H16; requires gather_fused; the HD phase falls
                     back under feature-axis sharding); False: XLA
                     ``dedup_candidates`` + ``merge_knn`` epilogue
                     (bit-equivalence anchor on the 'xla' backend).
  ``cand_fused``     True: every per-step random draw comes from the
                     counter-based hash RNG (§H17) -- the HD/LD
                     candidates are *generated inside* the merge kernel
                     (chained two-hop gathers through the second-table
                     channel) when ``merge_fused`` + ``gather_fused``
                     supply that kernel, and by the bit-identical
                     pure-jnp reference sampler otherwise (the 'xla'
                     backend, ``merge_fused=False``, or the HD
                     feature-sharding fallback); the refinement gate and
                     the negative samples use the same counter RNG, so
                     the step HLO carries NO threefry/random-bits ops
                     and no (n, s, K2) two-hop gather broadcast.
                     False: the legacy ``jax.random`` (threefry)
                     sampler.  NB flipping this flag changes the random
                     stream, so trajectories differ statistically (not
                     bitwise) from the legacy path; within
                     ``cand_fused=True`` all backend / fused-flag
                     combinations keep their usual parity contracts.
  ``backend``        'auto' (pallas on TPU else xla) | 'pallas' |
                     'interpret' | 'xla'.  The scatter kernel's VMEM
                     plan (ne_forces/ops.py: ~10MB budget, N-chunked
                     bins, XLA ref fallback only for degenerate plans)
                     applies on the pallas/interpret paths.

Distribution (DESIGN.md Sec. 3/5): inside ``shard_map`` the embedding state
is replicated; each device owns a contiguous row slice per phase
(KNN phases: the ``points`` axes; force phase: points x feat axes) and the
slices are reassembled with tiled all-gathers / a single force psum.  The HD
feature dimension is sharded over the ``feat`` axis and squared distances
are psum'd -- tensor parallelism for the NE.  Passing ``ctx=AxisCtx()``
(no axes) yields the single-device program, so both paths share this code.

§Perf notes (H-series; inline comments reference these ids):
  H10a  force psum crosses the wire in bf16 (f32 local accumulation);
        negative-sampling noise dominates the bf16 rounding error.
  H10b  ld_d is never all-gathered: it is re-derived from Y at the next
        refinement, so cross-chip transport is pure waste.
  H11   squared HD distances cross the wire in bf16 (merge thresholds and
        the sigma solve tolerate ~0.4% relative error).
  H12   gather-fused kernels: ``pairwise_sqdist_gather`` /
        ``ne_forces_gather`` take *indices* and DMA only the needed rows
        inside the kernel (X/Y stay in HBM), instead of XLA materialising
        (n, C, M) / (n, K, d) gathered operands in HBM per launch and the
        kernel streaming them back a second time.  Applies to HD candidate
        scoring, the LD current-distance refresh (one fused launch scores
        current + candidate LD neighbours), and the force phase.
        ``cfg.gather_fused=False`` restores the legacy pre-gather wiring
        (kept for bit-equivalence tests and A/B benches).
  H13   single force launch: HD attraction + LD repulsion + negatives run
        as static segments of ONE ``ne_forces_gather`` call over the
        concatenated neighbour axis -- one read of Y and one launch where
        there were three of each; per-segment outputs avoid any
        concat/re-slice round-trip at the call site.
  H14   scatter-fused force epilogue: the symmetrisation (each directed
        edge acting on both endpoints) is accumulated *inside* the force
        kernel into per-segment (N, d) displacement-field partials, so
        the per-edge (n, K, d) force tensors and the ``.at[tgt].add``
        scatters that consumed them vanish -- the step's last per-edge
        HBM round-trip.  ``cfg.scatter_fused=False`` restores the
        edge-emitting epilogue (kept for equivalence tests / A-B benches).
  H15   scan-chunked driver: T iterations per dispatch via ``lax.scan``
        with a donated state carry -- host->device dispatch cost, the
        per-step hyperparameter upload (schedule evaluated from the
        carried ``st.step``), per-step ``device_get`` snapshots (device
        ring buffer) and per-step metric syncs (EMA'd ChunkMetrics) all
        amortise to 1/T.  Chunk boundaries are bit-exactly neutral
        (chunk(a) then chunk(b) == chunk(a+b)); a handful of
        ``optimization_barrier``\\ s pin scalar EMA/schedule rounding so
        the traced chunk tracks the eager host loop it replaced.
  H17   candidate-fused sampling: candidate generation was the last
        per-iteration phase running as plain XLA -- ``sample_hops``
        materialised an (n, s, K2) two-hop gather broadcast in HBM, the
        threefry split/randint chain re-ran every step, and the (n, C)
        candidate tensor round-tripped HBM just to be re-read by the
        merge kernel's SMEM slabs.  With ``cand_fused=True`` the
        candidate slots are derived *inside* the kernel from state it
        already stages: a counter-based hash RNG keyed on (step salt,
        global row, slot) -- splittable and order/shard-invariant, with
        a bit-exact pure-jnp reference in ``core/knn.py`` -- plus
        chained element DMAs through the neighbour tables for the
        two-hop sources.  The refinement gate and the negatives draw
        from the same counter stream, so no threefry survives anywhere
        in the step HLO.  Cached reverse edges (``rev_refresh``) ride in
        as precomputed "extra" slots.
  H16   merge-fused neighbour selection: after the gather kernel has the
        candidate distances in VMEM, the dedup (self / current-list /
        earlier-candidate / SENTINEL) and the sorted top-K insertion run
        *in-register* and only the new (n, K) idx/d lists + a per-row
        ``improved`` flag leave the kernel -- the (n, C) distance buffer,
        the (n, C, K)/(n, C, C) dedup broadcast tensors and
        ``merge_knn``'s ``lax.top_k`` sort vanish from the step HLO.
        Applies to HD refinement (stored sorted distances ride in) and LD
        refinement (current rows re-scored in the same sweep).  With the
        scan-chunked driver the removed epilogue would otherwise run T
        times per dispatch.  ``cfg.merge_fused=False`` restores the XLA
        selection epilogue (bit-equivalence anchor / A-B benches).
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import time
import warnings
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import affinities
from repro.core import knn as knn_lib
from repro.core.knn import SENTINEL
from repro.core.resilience import EmbeddingDiverged, ResiliencePolicy
from repro.kernels import fallback
from repro.kernels.knn_merge.ops import knn_merge
from repro.kernels.ne_forces.ops import ne_forces, ne_forces_gather
from repro.kernels.pairwise_sqdist.ops import (pairwise_sqdist,
                                               pairwise_sqdist_gather)
from repro.runtime import faults


# --------------------------------------------------------------------------
# Configuration


@dataclasses.dataclass(frozen=True)
class FuncSNEConfig:
    """Static configuration (hashable -> jit static arg)."""
    n_points: int                 # capacity; dynamic datasets use `active`
    dim_hd: int
    dim_ld: int = 2
    k_hd: int = 32
    k_ld: int = 16
    # HD candidate sources per iteration (paper Sec. 3)
    c_hd_non: int = 4             # HD neighbours-of-neighbours
    c_hd_ld: int = 2              # LD neighbours proposed cross-space
    c_hd_ld_non: int = 2          # LD neighbours-of-neighbours cross-space
    c_hd_rand: int = 2            # uniform probes
    c_hd_rev: int = 0             # reverse edges (off by default; NND uses it)
    # LD candidate sources
    c_ld_non: int = 4
    c_ld_hd: int = 2              # HD neighbours as stable LD candidates
    c_ld_rand: int = 2
    n_negatives: int = 16
    sigma_refresh_every: int = 10
    min_refresh_prob: float = 0.05
    ema_decay: float = 0.9        # for E[N_new / N]
    z_ema_decay: float = 0.9
    backend: str = "auto"         # kernels backend
    # gather-fused hot path (§Perf H12/H13): kernels take indices and DMA
    # rows in-kernel; False re-materialises X[cand]/Y[idx] per launch
    # (legacy pre-gather wiring, kept for equivalence tests and A/B benches)
    gather_fused: bool = True
    # scatter-fused force epilogue (§Perf H14): symmetrisation edges are
    # accumulated in-kernel into (N, d) partials; False keeps the
    # edge-emitting kernel + XLA ``.at[].add`` scatters.  Only takes
    # effect with gather_fused (the scatter kernel is index-taking).
    scatter_fused: bool = True
    # merge-fused neighbour selection (§Perf H16): dedup + sorted top-K
    # merge happen inside the gather kernel; False keeps the XLA
    # selection epilogue (dedup_candidates -> distance kernel ->
    # merge_knn's top_k).  Only takes effect with gather_fused; the HD
    # phase falls back automatically under feature-axis sharding (the
    # merge needs the psum'd full distances).
    merge_fused: bool = True
    # candidate-fused sampling (§Perf H17): every per-step draw (HD/LD
    # candidates, refinement gate, negatives, reverse-edge fill) comes
    # from the counter-based hash RNG; candidates are generated inside
    # the merge kernel where merge_fused+gather_fused supply it, and by
    # the bit-identical jnp reference sampler otherwise.  False keeps the
    # legacy jax.random (threefry) sampler -- a different random stream,
    # so the flag is a statistical (not bitwise) A/B.
    cand_fused: bool = True
    # refresh cadence of the cached reverse-edge table (used when
    # c_hd_rev > 0): the n*K-edge argsort rebuild runs every rev_refresh
    # steps instead of at every HD refinement; 1 == the legacy
    # rebuild-per-refinement behaviour, bit-for-bit.
    rev_refresh: int = 10

    @property
    def c_hd(self) -> int:
        return (self.c_hd_non + self.c_hd_ld + self.c_hd_ld_non
                + self.c_hd_rand + self.c_hd_rev)

    @property
    def c_ld(self) -> int:
        return self.c_ld_non + self.c_ld_hd + self.c_ld_rand


class HParams(NamedTuple):
    """Traced hyperparameters -- change any of these without recompiling."""
    alpha: Any
    perplexity: Any
    lr: Any
    momentum: Any
    attraction: Any
    repulsion: Any
    exaggeration: Any


def default_hparams(n: int, *, alpha=1.0, perplexity=30.0, lr=None,
                    momentum=0.8, attraction=1.0, repulsion=1.0,
                    exaggeration=1.0) -> HParams:
    if lr is None:
        lr = max(50.0, n / 12.0)   # openTSNE-style default
    f32 = lambda v: jnp.asarray(v, jnp.float32)
    return HParams(f32(alpha), f32(perplexity), f32(lr), f32(momentum),
                   f32(attraction), f32(repulsion), f32(exaggeration))


class AxisCtx(NamedTuple):
    """Mesh axis names; all None -> single-device execution."""
    points: Optional[tuple] = None    # axes sharding KNN-phase rows
    feat: Optional[str] = None        # axis sharding the HD feature dim

    @property
    def all_rows(self) -> Optional[tuple]:
        if self.points is None:
            return None
        return self.points + ((self.feat,) if self.feat else ())


class FuncSNEState(NamedTuple):
    Y: Any          # (N, d_ld)
    vel: Any        # (N, d_ld)
    gains: Any      # (N, d_ld)
    hd_idx: Any     # (N, k_hd) int32, sorted by hd_d ascending
    hd_d: Any       # (N, k_hd) f32 squared HD distances
    ld_idx: Any     # (N, k_ld) int32
    ld_d: Any       # (N, k_ld) f32 squared LD distances
    beta: Any       # (N,) 1/(2 sigma_i^2)
    new_flag: Any   # (N,) bool -- new HD neighbour since last sigma refresh
    active: Any     # (N,) bool -- dynamic-dataset membership
    ema_new_frac: Any   # () f32
    zhat: Any       # () f32 EMA'd Z estimator
    step: Any       # () i32
    rng: Any        # PRNG key
    rev_idx: Any = ()   # (N, c_hd_rev) cached reverse edges ((N, 0) when
    #                     unused; refreshed every cfg.rev_refresh steps)
    rev_step: Any = ()  # () i32 step of the last reverse-edge refresh
    #                     (refinement runs behind a stochastic gate, so
    #                     cadence is since-last-refresh, not step % k --
    #                     a gate-skipped refresh step must not be lost)


# Counter-RNG stream tags (§Perf H17): per-step salts are
# hash3(key_salt(st.rng), st.step, TAG), one disjoint stream per phase.
_TAG_GATE, _TAG_HD, _TAG_LD, _TAG_NEG, _TAG_REV = 1, 2, 3, 4, 5


# --------------------------------------------------------------------------
# Helpers


def _phase_rows(n: int, axes):
    """(start, n_local) of this device's contiguous row slice for a phase."""
    if axes is None:
        return jnp.int32(0), n
    n_shards = jax.lax.psum(1, axes)
    idx = jax.lax.axis_index(axes)
    n_local = n // n_shards
    return (idx * n_local).astype(jnp.int32), n_local


def _gather_rows(full, axes):
    """Reassemble per-device row slices into the full array."""
    if axes is None:
        return full
    return jax.lax.all_gather(full, axes, axis=0, tiled=True)


def _take(arr, idx):
    """Gather rows with SENTINEL-safe clipping."""
    return arr[jnp.clip(idx, 0, arr.shape[0] - 1)]


def _row_sqdist(X, ids, cand, ctx: AxisCtx, cfg: "FuncSNEConfig"):
    """Squared HD distances rows->candidates, psum over the feature axis.

    Gather-fused (default): the kernel receives indices and DMAs rows of X
    in-kernel, so the (n_loc, C, M) gathered operand never hits HBM.  The
    feature-axis psum semantics are unchanged -- each shard computes partial
    squared distances over its local M slice.
    """
    if cfg.gather_fused:
        d = pairwise_sqdist_gather(X, ids, cand, backend=cfg.backend)
    else:
        d = pairwise_sqdist(X[ids], _take(X, cand), backend=cfg.backend)
    if ctx.feat is not None:
        d = jax.lax.psum(d, ctx.feat)
    return d


# --------------------------------------------------------------------------
# Phase 1: HD neighbour refinement


def _rev_update(cfg: FuncSNEConfig, st: FuncSNEState, fill):
    """Refresh the cached reverse-edge table once ``cfg.rev_refresh``
    steps have passed since the last rebuild: the argsort over all n*K
    directed edges leaves the per-iteration path.  The cadence is
    *since-last-refresh* (``st.rev_step``), not ``step % k``: refinement
    itself runs behind a stochastic gate, so an absolute-modulo schedule
    would silently drop every refresh whose step the gate skipped and
    leave staleness unbounded.  ``rev_refresh=1`` == the legacy
    per-refinement rebuild, bit-for-bit -- any later refinement
    satisfies the >= 1 condition, the same ``fill`` protocol feeds
    ``reverse_neighbors``, and the cache is overwritten before use."""
    n = cfg.n_points
    rev, rstep = jax.lax.cond(
        st.step - st.rev_step >= cfg.rev_refresh,
        lambda: (knn_lib.reverse_neighbors(st.hd_idx, n, cfg.c_hd_rev,
                                           fill=fill), st.step),
        lambda: (st.rev_idx, st.rev_step))
    return st._replace(rev_idx=rev, rev_step=rstep)


def _hd_refine(cfg: FuncSNEConfig, st: FuncSNEState, X, rng, ctx: AxisCtx):
    n = cfg.n_points
    start, n_loc = _phase_rows(n, ctx.points)
    ids = start + jnp.arange(n_loc, dtype=jnp.int32)
    hd_l = jax.lax.dynamic_slice_in_dim(st.hd_idx, start, n_loc)
    hd_d_l = jax.lax.dynamic_slice_in_dim(st.hd_d, start, n_loc)
    ld_l = jax.lax.dynamic_slice_in_dim(st.ld_idx, start, n_loc)

    # §Perf H16 (and the feature-sharding fallback): the in-kernel merge
    # is available off the feat axis only -- it needs full distances.
    use_kernel = cfg.merge_fused and cfg.gather_fused and ctx.feat is None
    cand = rev_l = None
    fused_kw = {}
    if cfg.cand_fused:
        # §Perf H17: all draws from the counter RNG, keyed on *global*
        # row ids -- no per-shard fold needed, the stream is
        # shard-invariant by construction.
        base = knn_lib.as_salt(rng)
        salt = knn_lib.hash3(base, st.step, _TAG_HD)
        if cfg.c_hd_rev:
            fill = knn_lib.counter_fill(
                knn_lib.hash3(base, st.step, _TAG_REV), n, cfg.c_hd_rev)
            st = _rev_update(cfg, st, fill)
            rev_l = jax.lax.dynamic_slice_in_dim(st.rev_idx, start, n_loc)
        sources = (("two_hop", 0, 0, cfg.c_hd_non),
                   ("one_hop", 1, cfg.c_hd_ld),
                   ("two_hop", 1, 1, cfg.c_hd_ld_non),
                   ("uniform", cfg.c_hd_rand),
                   ("extra", cfg.c_hd_rev))
        firsts, seconds = (hd_l, ld_l), (st.hd_idx, st.ld_idx)
        if use_kernel:
            fused_kw = dict(sources=sources, salt=salt,
                            first_tables=firsts, second_tables=seconds,
                            active=st.active)
        else:
            cand = knn_lib.counter_candidates(salt, ids, sources, firsts,
                                              seconds, n_total=n,
                                              extra=rev_l)
    else:
        rng0 = rng
        if ctx.points is not None:
            rng = jax.random.fold_in(rng, jax.lax.axis_index(ctx.points))
        r = jax.random.split(rng, 5)
        parts = []
        if cfg.c_hd_non:
            parts.append(knn_lib.sample_hops(r[0], hd_l, st.hd_idx, ids,
                                             cfg.c_hd_non))
        if cfg.c_hd_ld:
            parts.append(knn_lib.sample_direct(r[1], ld_l, cfg.c_hd_ld))
        if cfg.c_hd_ld_non:
            parts.append(knn_lib.sample_hops(r[2], ld_l, st.ld_idx, ids,
                                             cfg.c_hd_ld_non))
        if cfg.c_hd_rand:
            parts.append(knn_lib.sample_uniform(r[3], n_loc, n,
                                                cfg.c_hd_rand))
        if cfg.c_hd_rev:
            # the cached table is carried in *replicated* state, so its
            # fill must be identical on every shard: on a mesh derive it
            # from the pre-fold key (single-device: r[4], the legacy key)
            fill_key = r[4] if ctx.points is None \
                else jax.random.split(rng0, 5)[4]
            st = _rev_update(cfg, st,
                             knn_lib.sample_uniform(fill_key, n, n,
                                                    cfg.c_hd_rev))
            parts.append(jax.lax.dynamic_slice_in_dim(st.rev_idx, start,
                                                      n_loc))
        cand = jnp.concatenate(parts, axis=1)

    if use_kernel:
        # §Perf H16 + H17: dedup + top-K merge run inside the gather
        # kernel -- no (n, C) distance round-trip, no (n, C, K)/(n, C, C)
        # dedup broadcast tensors, no top_k in the step HLO; with
        # cand_fused the candidates themselves are generated in-kernel
        # (counter RNG + chained two-hop DMAs), so the (n, C) candidate
        # tensor and the threefry chain vanish too.
        new_idx, new_d, improved = knn_merge(
            X, ids, hd_l, hd_d_l, rev_l if cfg.cand_fused else cand,
            cand_active=None if cfg.cand_fused else _take(st.active, cand),
            backend=cfg.backend, **fused_kw)
    else:
        valid = knn_lib.dedup_candidates(ids, hd_l, cand)
        valid &= _take(st.active, cand)
        cand_d = _row_sqdist(X, ids, cand, ctx, cfg)
        new_idx, new_d, improved = knn_lib.merge_knn(hd_l, hd_d_l, cand,
                                                     cand_d, valid)

    hd_idx = _gather_rows(new_idx, ctx.points)
    if ctx.points is None:
        hd_d = new_d
    else:
        # §Perf H11: squared HD distances cross the wire in bf16 (merge
        # thresholds and the sigma solve tolerate ~0.4% relative error)
        hd_d = _gather_rows(new_d.astype(jnp.bfloat16), ctx.points)
        hd_d = hd_d.astype(jnp.float32)
    improved_f = _gather_rows(improved, ctx.points)
    new_flag = st.new_flag | improved_f
    n_act = jnp.maximum(jnp.sum(st.active.astype(jnp.float32)), 1.0)
    frac = jnp.sum((improved_f & st.active).astype(jnp.float32)) / n_act
    ema = cfg.ema_decay * st.ema_new_frac + (1.0 - cfg.ema_decay) * frac
    return st._replace(hd_idx=hd_idx, hd_d=hd_d, new_flag=new_flag,
                       ema_new_frac=ema)


# --------------------------------------------------------------------------
# Phase 2: sigma (beta) refresh for flagged rows


def _sigma_refresh(cfg: FuncSNEConfig, st: FuncSNEState, hp: HParams,
                   ctx: AxisCtx):
    start, n_loc = _phase_rows(cfg.n_points, ctx.all_rows)
    hd_d_l = jax.lax.dynamic_slice_in_dim(st.hd_d, start, n_loc)
    hd_i_l = jax.lax.dynamic_slice_in_dim(st.hd_idx, start, n_loc)
    beta_l = jax.lax.dynamic_slice_in_dim(st.beta, start, n_loc)
    flag_l = jax.lax.dynamic_slice_in_dim(st.new_flag, start, n_loc)
    valid = jnp.isfinite(hd_d_l) & (hd_i_l != SENTINEL)
    valid &= _take(st.active, hd_i_l)
    solved = affinities.solve_beta(hd_d_l, hp.perplexity, valid=valid,
                                   beta0=beta_l, n_iter=24)
    beta_l = jnp.where(flag_l, solved, beta_l)
    beta = _gather_rows(beta_l, ctx.all_rows)
    n = cfg.n_points
    cleared = jnp.zeros((n,), bool)
    return st._replace(beta=beta, new_flag=cleared)


# --------------------------------------------------------------------------
# Phase 3: LD neighbour refinement (every iteration)


def _ld_refine(cfg: FuncSNEConfig, st: FuncSNEState, rng, ctx: AxisCtx):
    n = cfg.n_points
    start, n_loc = _phase_rows(n, ctx.all_rows)
    ids = start + jnp.arange(n_loc, dtype=jnp.int32)
    ld_l = jax.lax.dynamic_slice_in_dim(st.ld_idx, start, n_loc)
    hd_l = jax.lax.dynamic_slice_in_dim(st.hd_idx, start, n_loc)

    use_kernel = cfg.merge_fused and cfg.gather_fused
    cand = None
    fused_kw = {}
    if cfg.cand_fused:
        # §Perf H17: counter-RNG draws keyed on global row ids
        salt = knn_lib.hash3(knn_lib.as_salt(rng), st.step, _TAG_LD)
        sources = (("two_hop", 0, 0, cfg.c_ld_non),
                   ("one_hop", 1, cfg.c_ld_hd),
                   ("uniform", cfg.c_ld_rand))
        firsts, seconds = (ld_l, hd_l), (st.ld_idx,)
        if use_kernel:
            fused_kw = dict(sources=sources, salt=salt,
                            first_tables=firsts, second_tables=seconds,
                            active=st.active)
        else:
            cand = knn_lib.counter_candidates(salt, ids, sources, firsts,
                                              seconds, n_total=n)
    else:
        if ctx.all_rows is not None:
            rng = jax.random.fold_in(rng, jax.lax.axis_index(ctx.all_rows))
        r = jax.random.split(rng, 3)
        parts = []
        if cfg.c_ld_non:
            parts.append(knn_lib.sample_hops(r[0], ld_l, st.ld_idx, ids,
                                             cfg.c_ld_non))
        if cfg.c_ld_hd:
            # HD neighbours: stable LD candidates unaffected by embedding
            # motion
            parts.append(knn_lib.sample_direct(r[1], hd_l, cfg.c_ld_hd))
        if cfg.c_ld_rand:
            parts.append(knn_lib.sample_uniform(r[2], n_loc, n,
                                                cfg.c_ld_rand))
        cand = jnp.concatenate(parts, axis=1)

    if use_kernel:
        # §Perf H16 (+H17): one launch generates (cand_fused) or stages
        # the candidates, gathers + re-scores current AND candidate rows
        # (the embedding moved since the last merge), dedups and merges
        # in-register -- the whole LD selection epilogue is gone from the
        # step HLO.
        cur_valid = (ld_l != SENTINEL) & _take(st.active, ld_l)
        new_idx, new_d, _ = knn_merge(
            st.Y, ids, ld_l, None, cand,
            cand_active=None if cfg.cand_fused else _take(st.active, cand),
            cur_valid=cur_valid, backend=cfg.backend, **fused_kw)
    else:
        valid = knn_lib.dedup_candidates(ids, ld_l, cand)
        valid &= _take(st.active, cand)

        # refresh stored distances (embedding moved since the last merge)
        cur_valid = (ld_l != SENTINEL) & _take(st.active, ld_l)
        if cfg.gather_fused:
            # §Perf H12: index-taking kernel -- no (n_loc, K+C, d)
            # Y-gather buffers; one fused launch scores current +
            # candidate neighbours
            both = jnp.concatenate([ld_l, cand], axis=1)
            both_d = pairwise_sqdist_gather(st.Y, ids, both,
                                            backend=cfg.backend)
            cur_d, cand_d = jnp.split(both_d, [ld_l.shape[1]], axis=1)
        else:
            y_l = st.Y[ids]
            cur_nbr = _take(st.Y, ld_l)
            cur_d = jnp.sum((cur_nbr - y_l[:, None, :]) ** 2, axis=-1)
            cand_nbr = _take(st.Y, cand)
            cand_d = jnp.sum((cand_nbr - y_l[:, None, :]) ** 2, axis=-1)
        cur_d = jnp.where(cur_valid, cur_d, jnp.inf)

        new_idx, new_d, _ = knn_lib.merge_knn(ld_l, cur_d, cand, cand_d,
                                              valid)
    ld_idx = _gather_rows(new_idx, ctx.all_rows)
    if ctx.all_rows is None:
        ld_d = new_d
    else:
        # §Perf H10b: ld_d is re-derived from Y at the next refinement
        # (the embedding moves every step), so gathering it across chips
        # is pure wire waste; keep a local placeholder instead.
        ld_d = jnp.zeros_like(st.ld_d)
    return st._replace(ld_idx=ld_idx, ld_d=ld_d)


# --------------------------------------------------------------------------
# Phase 4: forces + embedding update


def _forces_update(cfg: FuncSNEConfig, st: FuncSNEState, hp: HParams, rng,
                   ctx: AxisCtx):
    n, d = cfg.n_points, cfg.dim_ld
    start, n_loc = _phase_rows(n, ctx.all_rows)
    ids = start + jnp.arange(n_loc, dtype=jnp.int32)
    if ctx.all_rows is not None and not cfg.cand_fused:
        # counter-RNG draws are keyed on global row ids -> shard-invariant
        rng = jax.random.fold_in(rng, jax.lax.axis_index(ctx.all_rows))

    hd_i = jax.lax.dynamic_slice_in_dim(st.hd_idx, start, n_loc)
    hd_d = jax.lax.dynamic_slice_in_dim(st.hd_d, start, n_loc)
    ld_i = jax.lax.dynamic_slice_in_dim(st.ld_idx, start, n_loc)
    beta_l = jax.lax.dynamic_slice_in_dim(st.beta, start, n_loc)
    act_l = jax.lax.dynamic_slice_in_dim(st.active, start, n_loc)
    n_act = jnp.maximum(jnp.sum(st.active.astype(jnp.float32)), 2.0)

    # ---- attraction over the HD set:  coef = p_{j|i} / (2N)  (Eq. 1)
    hd_valid = jnp.isfinite(hd_d) & (hd_i != SENTINEL)
    hd_valid &= _take(st.active, hd_i)
    p = affinities.p_rows(hd_d, beta_l, valid=hd_valid)
    coef_a = jnp.where(hd_valid & act_l[:, None], p, 0.0) / (2.0 * n_act)

    # ---- repulsion over the LD set (paper's novel middle term of Eq. 6)
    # coef 0.5: each directed edge acts on both endpoints below, so mutual
    # LD pairs would otherwise be double-counted.
    ld_valid = (ld_i != SENTINEL) & _take(st.active, ld_i)
    coef_r = 0.5 * (ld_valid & act_l[:, None]).astype(jnp.float32)

    # ---- far-field via negative sampling (third term of Eq. 6)
    # n_negatives=0 drops the far field entirely (static config): used by
    # the momentum-conservation tests, where every edge is symmetrised.
    have_neg = cfg.n_negatives > 0
    if have_neg:
        if cfg.cand_fused:
            # §Perf H17: counter-RNG negatives -- no threefry in the HLO
            salt = knn_lib.hash3(knn_lib.as_salt(rng), st.step,
                                 _TAG_NEG)
            draws = jnp.arange(cfg.n_negatives, dtype=jnp.int32)[None, :]
            neg = knn_lib.counter_randint(salt, ids[:, None], draws, n)
        else:
            neg = knn_lib.sample_uniform(rng, n_loc, n, cfg.n_negatives)
        neg = jnp.where(neg == ids[:, None], (neg + 1) % n, neg)
        coef_n = (_take(st.active, neg) & act_l[:, None]).astype(jnp.float32)
        scale_neg = jnp.maximum(n_act - 1.0 - cfg.k_ld, 1.0) / cfg.n_negatives
    else:
        scale_neg = jnp.float32(0.0)

    scatter_fused = cfg.gather_fused and cfg.scatter_fused
    if cfg.gather_fused:
        # §Perf H13: ONE batched launch over the concatenated neighbour
        # axis replaces the three per-step force launches; y_l is read
        # once (DMA'd in-kernel) instead of three gathered (n, K, d)
        # buffers round-tripping through HBM.
        nbr_idx = jnp.concatenate([hd_i, ld_i] + ([neg] if have_neg else []),
                                  axis=1)
        coef = jnp.concatenate([coef_a, coef_r]
                               + ([coef_n] if have_neg else []), axis=1)
        segments = (("attraction", cfg.k_hd), ("repulsion", cfg.k_ld)) \
            + ((("repulsion", cfg.n_negatives),) if have_neg else ())
        if scatter_fused:
            # §Perf H14: the kernel bins every edge force (and its
            # symmetric reaction, except for negatives) straight into
            # per-segment (n, d) fields -- no per-edge output exists.
            scats, wsums = ne_forces_gather(
                st.Y, ids, nbr_idx, coef, hp.alpha, segments=segments,
                scatter_fused=True,
                scatter_back=(True, True) + ((False,) if have_neg else ()),
                backend=cfg.backend)
        else:
            # negatives' edges are never scattered back -> skip their HBM
            # write
            emit = (True, True) + ((False,) if have_neg else ())
            aggs, edges, wsums = ne_forces_gather(st.Y, ids, nbr_idx, coef,
                                                  hp.alpha,
                                                  segments=segments,
                                                  emit_edges=emit,
                                                  backend=cfg.backend)
            agg_a, agg_r = aggs[0], aggs[1]
            agg_n = aggs[2] if have_neg else 0.0
            edge_a, edge_r = edges[0], edges[1]
        wsum_r = wsums[1]
        wsum_n = wsums[2] if have_neg else jnp.float32(0.0)
    else:
        y_l = st.Y[ids]
        agg_a, edge_a, _ = ne_forces(y_l, _take(st.Y, hd_i), coef_a,
                                     hp.alpha, mode="attraction",
                                     backend=cfg.backend)
        agg_r, edge_r, wsum_r = ne_forces(y_l, _take(st.Y, ld_i), coef_r,
                                          hp.alpha, mode="repulsion",
                                          backend=cfg.backend)
        if have_neg:
            agg_n, _, wsum_n = ne_forces(y_l, _take(st.Y, neg), coef_n,
                                         hp.alpha, mode="repulsion",
                                         backend=cfg.backend)
        else:
            agg_n, wsum_n = 0.0, jnp.float32(0.0)

    # ---- Z estimator:  Z ~= sum_i [ sum_{j in LD_i} w_ij + scale * mean_neg ]
    # (x2 undoes the 0.5 symmetrisation coefficient baked into coef_r)
    # The barriers pin the mul-then-add rounding: without them the CPU
    # backend FMA-contracts these scalar a*x+b*y chains *differently*
    # inside a while/scan body than in straight-line code, so the chunked
    # driver would drift 1 ulp per step from T sequential dispatches and
    # break the scan==sequential bit-parity contract.
    wsum_r_m, wsum_n_m = jax.lax.optimization_barrier(
        (wsum_r, wsum_n if have_neg else jnp.float32(0.0)))
    z_local = sum(jax.lax.optimization_barrier(
        (2.0 * jnp.sum(wsum_r_m), scale_neg * jnp.sum(wsum_n_m))))
    z_est = (jax.lax.psum(z_local, ctx.all_rows)
             if ctx.all_rows is not None else z_local)
    z_est = jnp.maximum(z_est, 1e-8)
    zhat = jnp.where(st.step == 0, z_est,
                     sum(jax.lax.optimization_barrier(
                         (cfg.z_ema_decay * st.zhat,
                          (1.0 - cfg.z_ema_decay) * z_est))))

    # ---- assemble the displacement field (one (N, d) buffer + one psum)
    attr_s = hp.attraction * hp.exaggeration
    rep_s = hp.repulsion / zhat
    if scatter_fused:
        # §Perf H14: the kernel already binned edge + reaction forces by
        # row; the epilogue is three AXPYs on (n, d) partials -- the
        # ``.at[].add`` scatters below (and the edge tensors feeding
        # them) no longer exist.
        buf = attr_s * scats[0] + rep_s * scats[1]
        if have_neg:
            buf = buf + (rep_s * scale_neg) * scats[2]
    else:
        buf = jnp.zeros((n, d), jnp.float32)
        if have_neg:
            agg_q = attr_s * agg_a + rep_s * (agg_r + scale_neg * agg_n)
        else:
            agg_q = attr_s * agg_a + rep_s * agg_r
        buf = buf.at[ids].add(agg_q)
        # scatter-free symmetrisation: each directed edge acts on both
        # endpoints
        tgt_a = jnp.clip(hd_i, 0, n - 1).reshape(-1)
        buf = buf.at[tgt_a].add(-(attr_s * edge_a).reshape(-1, d))
        tgt_r = jnp.clip(ld_i, 0, n - 1).reshape(-1)
        buf = buf.at[tgt_r].add(-(rep_s * edge_r).reshape(-1, d))
    if ctx.all_rows is not None:
        # §Perf H10a: accumulate locally in f32, cross the wire in bf16
        # (the far field is negative-sampled: force noise >> bf16 error)
        buf = jax.lax.psum(buf.astype(jnp.bfloat16), ctx.all_rows)
        buf = buf.astype(jnp.float32)
    dY = 4.0 * buf

    # ---- t-SNE gains + momentum (replicated update)
    act = st.active[:, None]
    same = jnp.sign(dY) == jnp.sign(st.vel)
    gains = jnp.where(same, st.gains + 0.2, st.gains * 0.8)
    # upper clip: with stochastic (negative-sampled) forces, unbounded gains
    # turn sampling noise into diffusive expansion of the embedding
    gains = jnp.clip(gains, 0.01, 10.0)
    vel = hp.momentum * st.vel + hp.lr * gains * dY
    vel = jnp.where(act, vel, 0.0)
    Y = st.Y + vel
    return st._replace(Y=Y, vel=vel, gains=jnp.where(act, gains, st.gains),
                       zhat=zhat)


# --------------------------------------------------------------------------
# Full step


def funcsne_step(cfg: FuncSNEConfig, st: FuncSNEState, X, hp: HParams,
                 ctx: AxisCtx = AxisCtx()) -> FuncSNEState:
    """One fused FUnc-SNE iteration (see module docstring)."""
    # stochastic HD refinement: p = 0.05 + 0.95 E[N_new/N]  (paper Sec. 3)
    p_ref = cfg.min_refresh_prob + (1.0 - cfg.min_refresh_prob) \
        * st.ema_new_frac
    if cfg.cand_fused:
        # §Perf H17: the state key is only *read* (its raw bits fold into
        # one int32 base salt), every draw this step -- gate, candidates,
        # negatives, reverse-edge fill -- is a counter hash of
        # (salt, step, tag, row, slot): zero threefry ops in the HLO.
        base = knn_lib.key_salt(st.rng)
        r_hd = r_ld = r_force = base
        u = knn_lib.counter_uniform01(
            knn_lib.hash3(base, st.step, _TAG_GATE))
        do_hd = u < jnp.clip(p_ref, 0.0, 1.0)
    else:
        rng = jax.random.fold_in(st.rng, st.step)
        r_gate, r_hd, r_ld, r_force = jax.random.split(rng, 4)
        do_hd = jax.random.bernoulli(r_gate, jnp.clip(p_ref, 0.0, 1.0))
    st = jax.lax.cond(do_hd,
                      lambda s: _hd_refine(cfg, s, X, r_hd, ctx),
                      lambda s: s, st)

    do_sigma = (st.step % cfg.sigma_refresh_every == 0) \
        & jnp.any(st.new_flag)
    st = jax.lax.cond(do_sigma,
                      lambda s: _sigma_refresh(cfg, s, hp, ctx),
                      lambda s: s, st)

    st = _ld_refine(cfg, st, r_ld, ctx)
    st = _forces_update(cfg, st, hp, r_force, ctx)
    return st._replace(step=st.step + 1)


# --------------------------------------------------------------------------
# Initialisation & drivers


def pca_directions(X, d: int, n_iter: int = 24, rng=None):
    """Top-d PCA directions via subspace (power) iteration (no scipy)."""
    if rng is None:
        rng = jax.random.PRNGKey(0)
    Xc = X - jnp.mean(X, axis=0, keepdims=True)
    W = jax.random.normal(rng, (X.shape[1], d), X.dtype)

    def body(_, W):
        W = Xc.T @ (Xc @ W)
        q, _ = jnp.linalg.qr(W)
        return q

    return jax.lax.fori_loop(0, n_iter, body, jnp.linalg.qr(W)[0])


def validate_inputs(X, cfg: FuncSNEConfig, *, check_finite: bool = True):
    """Fail fast with a clear ``ValueError`` instead of NaN embeddings.

    A single non-finite row in ``X`` poisons the squared-distance pass,
    the sigma solve and eventually every force -- the resulting NaN
    embedding surfaces hundreds of iterations later with no pointer back
    here.  ``check_finite`` costs one O(n*M) reduction + one host sync,
    once per ``fit`` (never per step).
    """
    X = jnp.asarray(X)
    if X.ndim != 2:
        raise ValueError(
            f"X must be a 2-D (n, dim_hd) array, got shape {X.shape}")
    if X.dtype.kind not in "fiu":
        raise ValueError(
            f"X must be real-numeric (float/int), got dtype {X.dtype}")
    if X.shape != (cfg.n_points, cfg.dim_hd):
        raise ValueError(
            f"X shape {X.shape} does not match cfg (n_points="
            f"{cfg.n_points}, dim_hd={cfg.dim_hd})")
    n = cfg.n_points
    for name, k in (("k_hd", cfg.k_hd), ("k_ld", cfg.k_ld)):
        if k >= n:
            raise ValueError(
                f"cfg.{name}={k} must be < n_points={n}: a row cannot "
                f"have {k} distinct neighbours among {n - 1} other points")
    if check_finite and X.dtype.kind == "f":
        bad = jnp.sum(~jnp.all(jnp.isfinite(X), axis=1))
        if int(bad):
            raise ValueError(
                f"X contains {int(bad)} row(s) with non-finite (NaN/inf) "
                f"entries; clean or drop them before embedding")


def init_state(rng, X, cfg: FuncSNEConfig, *, init: str = "pca",
               active=None, Y0=None, perplexity=30.0,
               validate: bool = True) -> FuncSNEState:
    n, d = cfg.n_points, cfg.dim_ld
    if validate:
        validate_inputs(X, cfg)
    r_y, r_hd, r_ld, r_state = jax.random.split(rng, 4)
    if Y0 is not None:
        Y = jnp.asarray(Y0, jnp.float32)
    elif init == "pca":
        W = pca_directions(X, d, rng=r_y)
        Y = (X - jnp.mean(X, axis=0)) @ W
        Y = Y / jnp.maximum(jnp.std(Y), 1e-8) * 1e-2
    else:
        Y = jax.random.normal(r_y, (n, d)) * 1e-2
    Y = Y.astype(jnp.float32)
    if active is None:
        active = jnp.ones((n,), bool)

    ids = jnp.arange(n, dtype=jnp.int32)
    hd_idx = knn_lib.init_knn_idx(r_hd, n, n, cfg.k_hd)
    if cfg.gather_fused:
        hd_d = pairwise_sqdist_gather(X, ids, hd_idx, backend=cfg.backend)
    else:
        hd_d = pairwise_sqdist(X, X[hd_idx], backend=cfg.backend)
    hd_d = jnp.where(active[hd_idx] & active[:, None], hd_d, jnp.inf)
    order = jnp.argsort(hd_d, axis=1)
    hd_idx = jnp.take_along_axis(hd_idx, order, axis=1)
    hd_d = jnp.take_along_axis(hd_d, order, axis=1)

    ld_idx = knn_lib.init_knn_idx(r_ld, n, n, cfg.k_ld)
    if cfg.gather_fused:
        ld_d = pairwise_sqdist_gather(Y, ids, ld_idx, backend=cfg.backend)
    else:
        ld_d = jnp.sum((Y[:, None, :] - Y[ld_idx]) ** 2, axis=-1)
    ld_d = jnp.where(active[ld_idx] & active[:, None], ld_d, jnp.inf)

    beta = affinities.solve_beta(hd_d, perplexity, n_iter=24)
    return FuncSNEState(
        Y=Y, vel=jnp.zeros((n, d), jnp.float32),
        gains=jnp.ones((n, d), jnp.float32),
        hd_idx=hd_idx.astype(jnp.int32), hd_d=hd_d,
        ld_idx=ld_idx.astype(jnp.int32), ld_d=ld_d,
        beta=beta, new_flag=jnp.ones((n,), bool), active=active,
        ema_new_frac=jnp.float32(1.0), zhat=jnp.float32(1.0),
        step=jnp.int32(0), rng=r_state,
        # reverse-edge cache: rev_step starts one full period in the
        # past so the first refinement always refreshes
        rev_idx=jnp.zeros((n, cfg.c_hd_rev), jnp.int32),
        rev_step=jnp.int32(-cfg.rev_refresh))


def make_step(cfg: FuncSNEConfig):
    """Jitted single-device step; state is donated."""
    return jax.jit(functools.partial(funcsne_step, cfg), donate_argnums=(0,))


# --------------------------------------------------------------------------
# Scan-chunked on-device driver (§Perf H15)


class ChunkMetrics(NamedTuple):
    """Per-chunk driver telemetry -- ONE host sync per chunk, not per step.

    All fields are device scalars; a GUI/driver reads them once per chunk
    (the headless equivalent of the paper's per-frame status line).  The
    health fields (finite_frac / y_max_abs / bad_step) are the on-device
    half of the resilience layer: they are folded into the chunk scan
    alongside the displacement EMA, so fault *detection* costs zero extra
    host syncs -- the probe in ``ResiliencePolicy.check`` reads the same
    tuple the driver already drains once per chunk.
    """
    step: Any           # () i32  global iteration count after the chunk
    n_snapshots: Any    # () i32  ring slots written this chunk
    disp_ema: Any       # () f32  EMA over the chunk of mean |vel| (active)
    zhat: Any           # () f32  Z estimator at chunk end
    ema_new_frac: Any   # () f32  HD-refinement EMA at chunk end
    finite_frac: Any    # () f32  MIN over the chunk of the fraction of
    #                     finite Y entries among active rows (1.0=healthy)
    y_max_abs: Any      # () f32  MAX over the chunk of max |Y| over
    #                     active rows' finite entries (explosion probe)
    bad_step: Any       # () i32  first global step whose embedding held a
    #                     non-finite active entry; -1 = none this chunk


# decay of the per-chunk ChunkMetrics EMAs; ``fit`` needs the same
# constant to normalise thresholds by the chunk's EMA saturation factor
# (1 - decay**T), so the two must never drift apart
_METRICS_DECAY = 0.9


def _chunk_fn(cfg: FuncSNEConfig, T: int, *, schedule=None, n_iter=None,
              snapshot_every: int = 0, ctx: AxisCtx = AxisCtx(),
              metrics_decay: float = _METRICS_DECAY,
              health_metrics: bool = True, health_reduce: bool = True):
    """Traced chunk body: ``(st, X, hp) -> (st, snaps, ChunkMetrics)``.

    Runs ``T`` iterations of :func:`funcsne_step` inside ONE
    ``jax.lax.scan`` so a dispatch's fixed host->device cost is amortised
    over the whole chunk.  Everything the per-step host loop used to do on
    the host moves into the carry:

      * hyperparameter schedule: evaluated from the carried ``st.step``
        (``schedule(it, n_iter, hp)`` with traced ``it``) -- no per-step
        scalar uploads; ``schedule=None`` applies ``hp`` unchanged, which
        makes the chunk bit-identical to ``T`` sequential ``make_step``
        calls;
      * snapshots: a device-side ``(n_snap, n, d)`` ring-buffer carry slot
        captures ``Y`` whenever ``st.step % snapshot_every == 0`` (the
        same instants the host loop device_get'd); the host drains
        ``snaps[:metrics.n_snapshots]`` once per chunk;
      * metrics: per-step scalars are EMA'd into :class:`ChunkMetrics` so
        the driver/GUI syncs one tuple per chunk;
      * health telemetry: the finite-fraction of ``Y`` (min over the
        chunk), the max |Y| (max over the chunk) and the first step with
        a non-finite active entry fold into the same carry
        (``health_metrics=False`` elides the computation entirely -- the
        A/B knob behind the ``fig8_health_*`` bench rows).  The scalars
        ride in the one ChunkMetrics sync, so the resilience layer's
        fault detection adds no host round-trips.

    Mesh semantics (``health_reduce``, default True): on a mesh each
    shard probes ONLY its own row slice of ``Y`` (the rows whose updates
    it computed) and the per-shard scalars are reduced across
    ``ctx.all_rows`` once per chunk -- ``min`` over ``finite_frac``,
    ``max`` over ``y_max_abs``, earliest ``bad_step`` -- so a NaN
    confined to ONE shard's replica trips the *global* probe.  The
    reduction is three scalar collectives per chunk (not per step) and
    zero extra host syncs.  ``health_reduce=False`` keeps the legacy
    shard-blind per-replica computation: every shard probes its full
    local copy of ``Y`` and the coordinator reads shard 0's value only
    -- a device-local corruption on any other shard (a bad HBM row, a
    miscompiled kernel, an injected ``faults.NaNChunk(shard=...)``) is
    committed silently.  Kept as the positive-control anchor for the
    regression tests; never use it in production.
    """
    assert T >= 1, T
    if schedule is not None and n_iter is None:
        raise ValueError("schedule requires a static n_iter horizon")
    n, d = cfg.n_points, cfg.dim_ld
    # worst-case dues per chunk at any chunk<->snapshot alignment
    n_snap = (T // snapshot_every + 1) if snapshot_every else 0
    # mesh-reduced health: each shard probes its own row slice, the
    # scalars pmin/pmax across the mesh after the scan
    health_axes = ctx.all_rows if health_reduce else None

    def chunk(st: FuncSNEState, X, hp: HParams):
        snaps0 = jnp.zeros((n_snap, n, d), jnp.float32)
        health0 = (jnp.float32(1.0), jnp.float32(0.0), jnp.int32(-1))

        def body(carry, _):
            st, snaps, k, disp, health = carry
            hp_t = schedule(st.step, n_iter, hp) if schedule else hp
            st = funcsne_step(cfg, st, X, hp_t, ctx)
            act_col = st.active[:, None].astype(jnp.float32)
            n_act = jnp.maximum(jnp.sum(st.active.astype(jnp.float32)), 1.0)
            act_disp = jnp.sum(jnp.abs(st.vel) * act_col) / (n_act * d)
            disp = metrics_decay * disp + (1.0 - metrics_decay) * act_disp
            if health_metrics:
                # O(n*d) elementwise reads of Y -- noise next to the
                # O(n*K*d) force phase, and entirely inside the scan:
                # zero extra host syncs, zero extra dispatches
                ff_min, ymax, bad = health
                if health_axes is not None:
                    # probe ONLY this shard's row slice of its replica:
                    # the rows whose updates this device computed.  A
                    # corruption local to one device is visible in its
                    # own slice before any collective can launder (or
                    # propagate) it -- the pmin/pmax after the scan
                    # makes that local observation global.
                    h_start, h_loc = _phase_rows(n, health_axes)
                    Y_h = jax.lax.dynamic_slice_in_dim(st.Y, h_start, h_loc)
                    a_h = jax.lax.dynamic_slice_in_dim(st.active, h_start,
                                                       h_loc)
                else:
                    Y_h, a_h = st.Y, st.active
                a_col = a_h[:, None].astype(jnp.float32)
                na_h = jnp.sum(a_h.astype(jnp.float32))
                finite = jnp.isfinite(Y_h)
                ff = jnp.sum(finite.astype(jnp.float32) * a_col) \
                    / jnp.maximum(na_h * d, 1.0)
                # a shard with no active rows is vacuously healthy (it
                # must not pmin a 0/…=0 fraction into the global probe)
                ff = jnp.where(na_h > 0, ff, jnp.float32(1.0))
                step_max = jnp.max(jnp.where(
                    finite & (a_col > 0), jnp.abs(Y_h), 0.0))
                bad = jnp.where((bad < 0) & (ff < 1.0), st.step - 1, bad)
                health = (jnp.minimum(ff_min, ff),
                          jnp.maximum(ymax, step_max), bad)
            if n_snap:
                due = (st.step % snapshot_every) == 0
                snaps = jax.lax.cond(
                    due,
                    lambda s: jax.lax.dynamic_update_index_in_dim(
                        s, st.Y, jnp.clip(k, 0, n_snap - 1), 0),
                    lambda s: s, snaps)
                k = k + due.astype(jnp.int32)
            return (st, snaps, k, disp, health), None

        (st, snaps, k, disp, health), _ = jax.lax.scan(
            body, (st, snaps0, jnp.int32(0), jnp.float32(0.0), health0),
            None, length=T)
        ff_min, ymax, bad = health
        if health_metrics and health_axes is not None:
            # one reduction per CHUNK (min/max folds commute with the
            # per-step folds above, so reducing after the scan equals
            # reducing every step): three scalar collectives, zero extra
            # host syncs -- one bad shard now trips the GLOBAL probe.
            ff_min = jax.lax.pmin(ff_min, health_axes)
            ymax = jax.lax.pmax(ymax, health_axes)
            # earliest trip across shards; -1 (none) encodes as +inf-like
            no_bad = jnp.int32(jnp.iinfo(jnp.int32).max)
            bad = jax.lax.pmin(jnp.where(bad < 0, no_bad, bad), health_axes)
            bad = jnp.where(bad == no_bad, jnp.int32(-1), bad)
        metrics = ChunkMetrics(step=st.step, n_snapshots=k, disp_ema=disp,
                               zhat=st.zhat, ema_new_frac=st.ema_new_frac,
                               finite_frac=ff_min, y_max_abs=ymax,
                               bad_step=bad)
        return st, snaps, metrics

    return chunk


def make_chunked_step(cfg: FuncSNEConfig, T: int, *, schedule=None,
                      n_iter=None, snapshot_every: int = 0,
                      health_metrics: bool = True):
    """Jitted ``T``-iteration device program; state is donated.

    Returns ``chunk(st, X, hp) -> (st, snaps, ChunkMetrics)``.  One
    dispatch runs the whole chunk: schedule, snapshot ring, metrics and
    health telemetry all live on device (see :func:`_chunk_fn`), so the
    per-iteration host cost is the per-chunk cost / ``T``.
    """
    return jax.jit(_chunk_fn(cfg, T, schedule=schedule, n_iter=n_iter,
                             snapshot_every=snapshot_every,
                             health_metrics=health_metrics),
                   donate_argnums=(0,))


def make_distributed_step(cfg: FuncSNEConfig, mesh, *,
                          points_axes=("data",), feat_axis="model",
                          chunk: int = None, schedule=None, n_iter=None,
                          snapshot_every: int = 0,
                          health_metrics: bool = True,
                          health_reduce: bool = True):
    """shard_map'd step for a production mesh (see module docstring).

    ``chunk=None`` keeps the classic one-step contract
    ``step(st, X, hp) -> st``.  ``chunk=T`` returns the scan-chunked
    driver under the same mesh: ``step(st, X, hp) -> (st, snaps,
    ChunkMetrics)`` with the per-chunk collectives identical to ``T``
    sequential distributed steps -- the chunk body is the same traced
    ``funcsne_step``, so the psum/all-gather schedule per iteration is
    unchanged and only the dispatch + host-sync cost is amortised.

    The chunked form's health telemetry is mesh-reduced by default
    (``health_reduce=True``): each shard probes its own row slice and
    ``finite_frac`` / ``y_max_abs`` / ``bad_step`` are pmin/pmax'd
    across the mesh once per chunk, so the ChunkMetrics any host reads
    reflect EVERY shard -- a NaN confined to one device's replica trips
    the global rollback.  ``health_reduce=False`` restores the legacy
    shard-blind per-replica probe (positive-control anchor for tests
    only; see :func:`_chunk_fn`).
    """
    ctx = AxisCtx(points=tuple(points_axes), feat=feat_axis)
    state_specs = FuncSNEState(*([P()] * len(FuncSNEState._fields)))
    in_specs = (state_specs, P(None, feat_axis),
                HParams(*([P()] * len(HParams._fields))))

    if chunk is None:
        def step(st, X, hp):
            return funcsne_step(cfg, st, X, hp, ctx)

        fn = compat.shard_map(step, mesh=mesh, in_specs=in_specs,
                              out_specs=state_specs, check_vma=False)
        return jax.jit(fn, donate_argnums=(0,)), ctx

    body = _chunk_fn(cfg, chunk, schedule=schedule, n_iter=n_iter,
                     snapshot_every=snapshot_every, ctx=ctx,
                     health_metrics=health_metrics,
                     health_reduce=health_reduce)
    out_specs = (state_specs, P(),
                 ChunkMetrics(*([P()] * len(ChunkMetrics._fields))))
    fn = compat.shard_map(body, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=False)
    return jax.jit(fn, donate_argnums=(0,)), ctx


def rescale_embedding(st: FuncSNEState, factor: float = 0.01):
    """The paper's 'implosion button': rescale Y so gradients matter again."""
    return st._replace(Y=st.Y * factor, vel=st.vel * 0.0)


def add_points(st: FuncSNEState, ids, rng) -> FuncSNEState:
    """Activate rows (dynamic datasets). Caller updates the X buffer first;
    HD distances refresh lazily through the iterative KNN (flags set)."""
    ids = jnp.asarray(ids, jnp.int32)
    n = st.active.shape[0]
    active = st.active.at[ids].set(True)
    fresh = (ids[:, None] + 1 + knn_lib.init_knn_idx(
        rng, ids.shape[0], n - 1, st.hd_idx.shape[1])) % n
    hd_idx = st.hd_idx.at[ids].set(fresh.astype(jnp.int32))
    hd_d = st.hd_d.at[ids].set(jnp.inf)
    new_flag = st.new_flag.at[ids].set(True)
    return st._replace(active=active, hd_idx=hd_idx, hd_d=hd_d,
                       new_flag=new_flag)


def remove_points(st: FuncSNEState, ids) -> FuncSNEState:
    ids = jnp.asarray(ids, jnp.int32)
    return st._replace(active=st.active.at[ids].set(False),
                       new_flag=st.new_flag.at[ids].set(False))


def _copy_state(st: FuncSNEState) -> FuncSNEState:
    return jax.tree.map(lambda a: jnp.array(a, copy=True), st)


def _scaled_hp(hp: HParams, lr_scale: float, ex_scale: float) -> HParams:
    """Retry backoff applied to the traced hyperparameters.

    Identity at scale 1.0 (no new arrays), so a run that never trips a
    health probe is bit-identical to one without a policy; the schedule
    composes on top (it multiplies ``hp.lr``), so backoff scales the
    whole annealing curve rather than fighting it.
    """
    if lr_scale == 1.0 and ex_scale == 1.0:
        return hp
    return hp._replace(
        lr=hp.lr * jnp.float32(lr_scale),
        exaggeration=hp.exaggeration * jnp.float32(ex_scale))


class AuditResult(NamedTuple):
    """Violation counts from :func:`audit_state` -- all () int32, all
    zero for a healthy state."""
    hd_oob: Any         # hd_idx entries outside [0, n) (mod SENTINEL)
    ld_oob: Any         # ld_idx entries outside [0, n) (mod SENTINEL)
    rev_oob: Any        # rev_idx entries outside [0, n) (mod SENTINEL)
    hd_dup: Any         # per-row duplicate hd neighbours (mod SENTINEL)
    ld_dup: Any         # per-row duplicate ld neighbours (mod SENTINEL)
    hd_sentinel: Any    # SENTINEL hd slots whose distance is not +inf
    y_nonfinite: Any    # non-finite Y entries on active rows
    x_nonfinite: Any    # non-finite X entries on active rows (0 if no X)


@functools.lru_cache(maxsize=None)
def _audit_fn(cfg: FuncSNEConfig, with_x: bool):
    n = cfg.n_points

    def _oob(idx):
        if not hasattr(idx, "ndim") or idx.ndim != 2 or idx.shape[1] == 0:
            return jnp.int32(0)
        bad = (idx != SENTINEL) & ((idx < 0) | (idx >= n))
        return jnp.sum(bad.astype(jnp.int32))

    def _dups(idx):
        # per-row duplicates via sort + adjacent-compare: O(K log K) per
        # row instead of the (K, K) broadcast; SENTINEL padding sorts to
        # the end, so equal-adjacent SENTINELs are masked out
        if not hasattr(idx, "ndim") or idx.ndim != 2 or idx.shape[1] < 2:
            return jnp.int32(0)
        s = jnp.sort(idx, axis=1)
        eq = (s[:, 1:] == s[:, :-1]) & (s[:, 1:] != SENTINEL)
        return jnp.sum(eq.astype(jnp.int32))

    def audit(st, X):
        act_col = st.active[:, None]
        # SENTINEL hd slots must carry +inf distance: the merge kernels
        # key validity off the distance, so a finite distance on a
        # SENTINEL slot resurrects a phantom neighbour.  (ld_d is a
        # zeros placeholder on the mesh path and add_points seeds valid
        # idx with +inf distance, so only hd and only this direction.)
        hd_bad_sent = (st.hd_idx == SENTINEL) & ~jnp.isinf(st.hd_d)
        res = AuditResult(
            hd_oob=_oob(st.hd_idx), ld_oob=_oob(st.ld_idx),
            rev_oob=_oob(st.rev_idx),
            hd_dup=_dups(st.hd_idx), ld_dup=_dups(st.ld_idx),
            hd_sentinel=jnp.sum(hd_bad_sent.astype(jnp.int32)),
            y_nonfinite=jnp.sum(
                (~jnp.isfinite(st.Y) & act_col).astype(jnp.int32)),
            x_nonfinite=jnp.sum(
                (~jnp.isfinite(X) & act_col).astype(jnp.int32))
            if with_x else jnp.int32(0))
        return res

    if with_x:
        return jax.jit(audit)
    return jax.jit(lambda st: audit(st, None))


def audit_state(st: FuncSNEState, cfg: FuncSNEConfig,
                X=None) -> AuditResult:
    """Cheap on-device invariant audit of a :class:`FuncSNEState`:
    KNN / reverse-edge indices in ``[0, n)`` (modulo SENTINEL), per-row
    duplicate-free neighbour lists, SENTINEL slots distance-consistent,
    and finite Y (and X, when given) on active rows.

    Every check is a fused reduction over state already on device -- one
    pass over the index tables, no gathers, no host round-trip until the
    caller reads the counts -- so it is cheap enough to run at chunk
    boundaries (``ResiliencePolicy(audit_every=)``).  It exists for the
    corruption class the finite-fraction health probes are blind to:
    a poisoned index table is made of perfectly finite integers, and the
    embedding it slowly drags out of shape stays finite too.

    Returns an :class:`AuditResult` of () int32 violation counts (all
    zero = healthy); jit-compiled once per (cfg, X-given) and cached.
    Works unchanged on mesh-replicated state (the reductions compile to
    the shard-local sum + an AllReduce).
    """
    fn = _audit_fn(cfg, X is not None)
    return fn(st, X) if X is not None else fn(st)


def fit(X, *, cfg: FuncSNEConfig = None, n_iter: int = 750, rng=None,
        hparams: HParams = None,
        schedule: Callable[[int, int, HParams], HParams] = None,
        init: str = "pca", snapshot_every: int = 0,
        callback: Callable[[int, FuncSNEState], None] = None,
        chunk_size: int = None, early_stop: float = None,
        auto_rescale: float = None,
        resilience: "ResiliencePolicy" = None, resume_from=None,
        state: FuncSNEState = None, validate: bool = True):
    """End-to-end driver on the scan-chunked step. Returns (state, snapshots).

    ``chunk_size`` iterations run per device dispatch (§Perf H15); the host
    syncs once per chunk to drain the snapshot ring.  Default: 50, or 1
    when a per-iteration ``callback`` is supplied (the callback contract
    needs the state after every step).  Schedule, snapshots and metrics
    are computed on device.  Results are bit-invariant to ``chunk_size``;
    vs the per-step host loop this replaces, parity is to fp32 codegen
    tolerance (contract pinned in tests/test_chunked_driver.py).

    ``early_stop`` (off by default) is the first :class:`ChunkMetrics`
    consumer: after each chunk the driver reads the EMA'd mean per-active
    displacement ``metrics.disp_ema`` -- already on the host, it is THE
    one sync per chunk -- and stops once it falls below the threshold
    (the embedding has converged; the remaining chunks would only stir
    negative-sampling noise).  The returned ``state.step`` tells the
    caller how many iterations actually ran.  The per-chunk EMA restarts
    from 0 each chunk and saturates at ``(1 - 0.9^T)`` of the
    steady-state per-step displacement, so the driver *normalises* it by
    that factor before comparing: thresholds are calibrated in
    steady-state per-step displacement units and are chunk-size
    independent.  The host-loop fallback compares the identical quantity
    (its per-step ``act_disp`` equals the normalised T=1 EMA), a parity
    pinned in tests/test_chunked_driver.py.

    ``auto_rescale`` (off by default) is the second ChunkMetrics
    consumer -- the paper's 'implosion button' driven by telemetry: when
    the (normalised, see above) ``metrics.disp_ema`` collapses below the
    threshold while iterations remain, the embedding has grown so large
    that gradient steps no longer move points relative to its scale, so
    the driver applies :func:`rescale_embedding` (shrink Y by 100x, zero
    the velocity) and keeps optimising instead of silently freezing.
    When both are set, ``early_stop`` is checked first (a stop wins over
    a rescale).

    ``resilience`` (a :class:`~repro.core.resilience.ResiliencePolicy`)
    arms the fault-tolerance layer: after every chunk the health fields
    of :class:`ChunkMetrics` (computed inside the scan -- no extra host
    syncs) are checked; a tripped probe rolls the state back to the last
    healthy chunk boundary and retries with exponentially backed-off
    lr/exaggeration, raising :class:`EmbeddingDiverged` once
    ``max_retries`` consecutive retries fail.  With
    ``policy.checkpoint_dir`` set, the full state is snapshotted through
    :class:`~repro.checkpoint.Checkpointer` every ``checkpoint_every``
    healthy chunks and ``fit(resume_from=dir)`` continues a killed run
    bit-identically to the uninterrupted one (chunk boundaries are
    bit-neutral, and the state carries its own RNG key and counter-RNG
    salt inputs).  ``policy.sticky_fallback`` enables guarded Pallas
    launches (``repro.kernels.fallback``): a raising kernel family is
    demoted to its XLA reference for the rest of the run instead of
    crashing it.  A :class:`~repro.runtime.straggler.StepTimeMonitor`
    watches chunk wall times as the hang/straggler watchdog.  A clean
    run under a policy is bit-identical to ``resilience=None`` (one
    extra on-device state copy per chunk is the only cost -- the chunk
    program donates its input, so rollback needs an anchor).

    Distributed-resilience matrix -- which policy knobs are mesh-aware.
    This ``fit`` drives a single process; the multi-host elastic loop on
    the same policy is :func:`repro.runtime.coordinator.fit_elastic`:

      ``min_finite_frac`` / ``max_abs_y``
          mesh-aware: under ``make_distributed_step(chunk=T)`` the
          telemetry is pmin/pmax-reduced across every shard before any
          host reads it (``health_reduce=True``), so one bad shard
          trips the global rollback.
      rollback / ``lr_backoff`` / ``max_retries``
          mesh-aware: the anchor copy is replicated on the mesh and the
          retry re-dispatches the same chunk program on all shards.
      ``checkpoint_dir`` / ``checkpoint_every`` / ``keep_last``
          mesh-aware: the coordinator writes per-host shard files
          (``Checkpointer.save(host_shard_filter=...)``, merged on
          restore) so checkpoint I/O scales with hosts; this ``fit``
          writes the single-host layout.
      ``resume_from``
          mesh-aware AND elastic: ``Checkpointer.restore(shardings=)``
          re-lays a checkpoint onto whatever mesh survives.
      ``sticky_fallback``
          process-local: the demotion registry is per process; each
          host demotes (and logs) independently.
      ``hang_timeout`` / ``straggler_z``
          coordinator-local: chunk wall time is observed where the
          dispatch happens.

    ``state`` continues an existing :class:`FuncSNEState` (dynamic
    sessions: ``add_points``/``remove_points`` between ``fit`` calls)
    instead of initialising from ``X``; ``n_iter`` then counts the
    *additional* iterations.  NB schedules are evaluated from the global
    ``st.step`` on device -- pass an identity schedule (or one keyed on
    absolute steps) when continuing.

    A ``schedule`` is evaluated with a *traced* ``it`` inside the chunk;
    one that needs a Python ``int`` (host control flow on ``it``) is
    detected up front and falls back to the per-step host loop (which
    supports neither ``resilience`` nor ``resume_from`` -- a ValueError
    says so rather than silently dropping the policy).
    """
    X = jnp.asarray(X, jnp.float32)
    if rng is None:
        rng = jax.random.PRNGKey(0)
    if cfg is None:
        cfg = FuncSNEConfig(n_points=X.shape[0], dim_hd=X.shape[1])
    if validate:
        validate_inputs(X, cfg)
    if hparams is None:
        hparams = default_hparams(cfg.n_points)
    if schedule is None:
        schedule = default_schedule
    if chunk_size is None:
        chunk_size = 1 if callback is not None else min(50, max(1, n_iter))
    try:        # host-only schedules (Python control flow on it) -> host loop
        jax.eval_shape(lambda it: schedule(it, n_iter, hparams),
                       jax.ShapeDtypeStruct((), jnp.int32))
    except jax.errors.ConcretizationTypeError:
        if resilience is not None or resume_from is not None \
                or state is not None:
            raise ValueError(
                "resilience / resume_from / state require a traceable "
                "schedule (the per-step host-loop fallback does not "
                "support them); use a schedule evaluable with a traced "
                "`it`")
        return _fit_host_loop(X, cfg, n_iter, rng, hparams, schedule, init,
                              snapshot_every, callback, early_stop,
                              auto_rescale)
    if state is not None:
        st = state
    else:
        st = init_state(rng, X, cfg, init=init,
                        perplexity=hparams.perplexity, validate=False)

    policy = resilience
    ck = monitor = None
    start_it = 0
    lr_scale = ex_scale = 1.0
    if policy is not None:
        if policy.checkpoint_dir is not None:
            from repro.checkpoint import Checkpointer
            ck = Checkpointer(policy.checkpoint_dir,
                              keep_last=policy.keep_last)
        from repro.runtime.straggler import StepTimeMonitor
        monitor = StepTimeMonitor(z_thresh=policy.straggler_z,
                                  hang_timeout=policy.hang_timeout,
                                  warmup_steps=policy.straggler_warmup)
    if resume_from is not None:
        from repro.checkpoint import Checkpointer, cfg_compat
        rck = ck if (ck is not None
                     and str(ck.dir) == str(resume_from)) else \
            Checkpointer(resume_from)
        # fallback-chain restore: a damaged newest boundary (torn write,
        # bit flip, lost shard) degrades to the previous verified one
        # instead of crashing or silently loading garbage; a cfg
        # mismatch raises CheckpointIncompatible (never falls back)
        tree, meta, fbs = rck.restore_verified(
            st, expect_compat=cfg_compat(cfg))
        for fb in fbs:
            if policy is not None:
                policy.log("checkpoint_fallback", **fb)
            else:
                warnings.warn(
                    f"[checkpoint] skipping damaged boundary step "
                    f"{fb['step']}: {fb['reason']}", RuntimeWarning)
        st = jax.tree.map(jnp.asarray, tree)
        start_it = int(meta["step"])
        lr_scale = float(meta.get("lr_scale", 1.0))
        ex_scale = float(meta.get("ex_scale", 1.0))

    snapshots = []
    chunks = {}         # T -> compiled program (final ragged chunk reuses it)
    it = start_it
    retries = 0
    n_healthy = 0       # healthy chunks since start (checkpoint cadence)
    fb_seen = fallback.n_events()
    guard = fallback.enabled(policy.sticky_fallback) \
        if policy is not None else contextlib.nullcontext()
    with contextlib.ExitStack() as stack:
        stack.enter_context(guard)
        if ck is not None:
            # every exit path -- EmbeddingDiverged, Preempted, a raising
            # callback -- joins the in-flight async write so the last
            # boundary is committed on disk for resume; close() warns on
            # an unobserved write error instead of masking the in-flight
            # exception (the happy path surfaces it via wait() below)
            stack.callback(ck.close)
        while it < n_iter:
            T = min(chunk_size, n_iter - it)
            if T not in chunks:
                chunks[T] = make_chunked_step(cfg, T, schedule=schedule,
                                              n_iter=n_iter,
                                              snapshot_every=snapshot_every)
            hp_run = _scaled_hp(hparams, lr_scale, ex_scale)
            if policy is not None or faults.current() is not None:
                # the chunk program donates its input; the live `st` is
                # the rollback anchor, so dispatch a copy.  Scripted
                # faults poison the *copy*: the anchor stays clean, as it
                # would for a divergence that happens inside the chunk.
                st_in = faults.corrupt_state(_copy_state(st), it)
            else:
                st_in = st
            t0 = time.time()
            st_out, snaps, metrics = chunks[T](st_in, X, hp_run)
            alarm = None
            if policy is not None:
                m = jax.device_get(metrics)   # THE one host sync per chunk
                alarm = monitor.observe(time.time() - t0)
                if alarm is not None:
                    policy.log("straggler", step=it, alarm=alarm)
                for e in fallback.events(fb_seen):
                    policy.log(**e)
                fb_seen = fallback.n_events()
                reason = policy.check(m)
                if reason is None and policy.audit_every \
                        and (n_healthy + 1) % policy.audit_every == 0:
                    # chunk-boundary invariant audit: catches index
                    # corruption the finite-fraction probes are blind
                    # to; a violation feeds the SAME rollback path
                    aud = jax.device_get(audit_state(st_out, cfg, X))
                    reason = policy.audit_check(aud)
                    if reason is not None:
                        policy.log("audit_violation", step=it,
                                   reason=reason)
                if reason is not None:
                    if retries >= policy.max_retries:
                        policy.log("giving_up", step=it, reason=reason,
                                   retries=retries)
                        raise EmbeddingDiverged(it, reason, retries,
                                                policy.events)
                    retries += 1
                    lr_scale *= policy.lr_backoff
                    ex_scale *= policy.exaggeration_backoff
                    policy.log("rollback", step=it, reason=reason,
                               retry=retries, lr_scale=lr_scale,
                               ex_scale=ex_scale)
                    continue    # `st` still holds the last healthy state
                retries = 0
            else:
                m = metrics
            st = st_out
            if snapshot_every:
                taken = int(m.n_snapshots)
                if taken:
                    snapshots.extend(list(jax.device_get(snaps[:taken])))
            if callback is not None:
                callback(it + T - 1, st)
            it += T
            if policy is not None:
                n_healthy += 1
                if ck is not None:
                    from repro.checkpoint import cfg_compat
                    meta = {"lr_scale": lr_scale, "ex_scale": ex_scale,
                            "compat": cfg_compat(cfg)}
                    saved = n_healthy % policy.checkpoint_every == 0
                    if saved:
                        ck.save(it, st, metadata=meta)
                    if alarm is not None:
                        # hang/straggler escalation: commit THIS
                        # boundary before the next dispatch
                        # (straggler.py's contract) so a subsequent
                        # kill loses at most one chunk
                        if saved:
                            ck.wait()       # land the in-flight write
                        else:
                            ck.save(it, st, metadata=meta,
                                    blocking=True)
                        policy.log("early_checkpoint", step=it,
                                   alarm=alarm)
            # scripted damage to the newest COMMITTED checkpoint (the
            # hook waits for the in-flight write): exercises the
            # verified-restore fallback chain on resume
            faults.maybe_corrupt_checkpoint(it, ck)
            # simulated kill between chunks; the ExitStack's ck.close()
            # is the preemption grace period that lets the in-flight
            # checkpoint write land, so the just-saved boundary is
            # committed for resume
            faults.maybe_preempt(it)
            # normalise the per-chunk EMA by its saturation factor so the
            # threshold reads in steady-state per-step displacement units
            # whatever the chunk size (host loop parity: T=1 factor is
            # exactly the 0.1 single-step weight)
            if early_stop is not None or auto_rescale is not None:
                disp = float(m.disp_ema) / (1.0 - _METRICS_DECAY ** T)
                if early_stop is not None and disp < early_stop:
                    break
                if auto_rescale is not None and it < n_iter \
                        and disp < auto_rescale:
                    # the paper's implosion button, driven by telemetry:
                    # the layout froze relative to its own scale --
                    # shrink it so gradients matter again and keep going
                    st = rescale_embedding(st)
        if ck is not None:
            ck.wait()   # surface async write failures BEFORE returning:
            #             the final checkpoint of a run must not vanish
            #             silently (close() above only warns)
    return st, snapshots


def _fit_host_loop(X, cfg, n_iter, rng, hparams, schedule, init,
                   snapshot_every, callback, early_stop=None,
                   auto_rescale=None):
    """Pre-H15 per-step host loop: kept for schedules that must see a
    Python ``it`` (``fit`` detects those and routes here)."""
    st = init_state(rng, X, cfg, init=init, perplexity=hparams.perplexity)
    step = make_step(cfg)
    snapshots = []
    for it in range(n_iter):
        st = step(st, X, schedule(it, n_iter, hparams))
        if snapshot_every and (it + 1) % snapshot_every == 0:
            snapshots.append(jax.device_get(st.Y))
        if callback is not None:
            callback(it, st)
        if early_stop is not None or auto_rescale is not None:
            # the same quantity `fit` derives from ChunkMetrics: its
            # per-chunk disp_ema normalised by the (1 - 0.9^T) saturation
            # factor is, at T=1, exactly this per-step displacement --
            # thresholds read in the same units on both drivers (parity
            # pinned in tests/test_chunked_driver.py)
            n_act = max(float(jnp.sum(st.active.astype(jnp.float32))), 1.0)
            act_disp = float(jnp.sum(
                jnp.abs(st.vel) * st.active[:, None].astype(jnp.float32))) \
                / (n_act * cfg.dim_ld)
            if early_stop is not None and act_disp < early_stop:
                break
            if auto_rescale is not None and it + 1 < n_iter \
                    and act_disp < auto_rescale:
                st = rescale_embedding(st)
    return st, snapshots


def default_schedule(it, n_iter: int, hp: HParams) -> HParams:
    """Early exaggeration, then a linear lr decay (UMAP-style).

    The paper runs a *continual* optimisation where the user counteracts the
    ever-expanding-embedding regime interactively (attraction ratio /
    'implosion' button).  For a batch ``fit`` the equivalent is annealing the
    learning rate so negative-sampling noise stops diffusing the layout.

    ``it`` may be a *traced* i32 scalar (``n_iter`` stays static): the
    chunked driver evaluates the schedule on-device from the carried
    ``st.step``, so no per-iteration host scalar upload exists.  All
    arithmetic is pinned to i32/f32 jnp ops so a host call with a Python
    ``it`` produces bit-identical hyperparameters to the traced evaluation.
    """
    ee_until = max(1, n_iter // 4)
    it = jnp.asarray(it, jnp.int32)
    ex = jnp.where(it < ee_until, 12.0, 1.0) * hp.exaggeration
    mom = jnp.where(it < ee_until, 0.5, hp.momentum)
    # the barriers pin traced == eager rounding: without them jit rewrites
    # the constant division into a reciprocal multiply and FMA-contracts
    # the 1 - 0.9*frac chain, so the chunked driver's on-device schedule
    # would drift 1 ulp from the host loop's eager evaluation
    denom = jax.lax.optimization_barrier(
        jnp.float32(max(1, n_iter - ee_until)))
    frac = jnp.maximum(jnp.float32(0.0), (it - ee_until) / denom)
    lr = hp.lr * (1.0 - jax.lax.optimization_barrier(0.9 * frac))
    return hp._replace(exaggeration=ex, momentum=mom, lr=lr)

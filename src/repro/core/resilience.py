"""Resilience layer for the chunked embedding driver (``funcsne.fit``).

The paper's pitch is an *always-on* interactive session: hyperparameters
are turned live, points stream in and out, and the optimisation simply
keeps running.  A session that dies on the first NaN chunk, diverging
learning rate or preempted worker is a batch job with extra steps.  This
module is the host-side half of the contract:

  * :class:`ResiliencePolicy` -- what ``fit`` should snapshot, when to
    trip a health probe, how far to back off on retry, and whether Pallas
    kernel failures demote to their XLA references (sticky fallback);
  * :class:`EmbeddingDiverged` -- the structured error raised when the
    bounded retry budget is exhausted (carries the step, trip reason and
    the full event log, so a service can triage without re-running);
  * the health probe itself (:meth:`ResiliencePolicy.check`) reads ONLY
    the on-device :class:`~repro.core.funcsne.ChunkMetrics` telemetry
    that already crosses the host boundary once per chunk -- fault
    detection adds zero extra host syncs.

The device-side half lives in ``funcsne._chunk_fn`` (finite-fraction /
max-|Y| / first-bad-step scalars folded into the chunk scan) and
``repro.kernels.fallback`` (sticky demotion registry); the deterministic
fault sources used by tests and CI live in ``repro.runtime.faults``.

On a mesh the same contract holds shard-globally: the chunk program
pmin/pmax-reduces the health scalars across every shard before the host
reads them (``health_reduce=True`` in ``make_distributed_step``), so
:meth:`ResiliencePolicy.check` sees the WORST shard's telemetry and a
NaN confined to one device's replica trips the global rollback.  The
policy code is identical either way -- it only ever consumes the one
ChunkMetrics tuple -- which is what lets
``repro.runtime.coordinator.fit_elastic`` reuse it unchanged for the
multi-host elastic loop (per-host checkpoint shards, remesh-and-resume
on host loss).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, List, Optional


class EmbeddingDiverged(RuntimeError):
    """Retry budget exhausted: the run kept tripping health probes.

    Attributes:
      step:    global iteration the last failed chunk started at.
      reason:  the final trip reason string.
      retries: retries consumed before giving up.
      events:  the policy's full structured event log.
    """

    def __init__(self, step: int, reason: str, retries: int,
                 events: List[dict]):
        super().__init__(
            f"embedding diverged at step {step} after {retries} "
            f"rollback-retries: {reason}")
        self.step = step
        self.reason = reason
        self.retries = retries
        self.events = events


@dataclasses.dataclass
class ResiliencePolicy:
    """Checkpoint / rollback / degradation policy consumed by ``fit``.

    With a policy active, ``fit`` keeps one extra on-device copy of the
    state (the rollback anchor; the chunk program donates its input) and
    checks the chunk's health telemetry after every dispatch.  A tripped
    probe rolls the state back to the last healthy chunk boundary and
    retries with the learning rate (and optionally exaggeration)
    multiplied by ``lr_backoff`` / ``exaggeration_backoff`` -- the
    backoff compounds per retry and *persists* once a retry succeeds (a
    run that diverged at lr is not re-trusted with lr), which is why a
    clean run under a policy is bit-identical to ``resilience=None``:
    backoff only ever engages after a trip.

    ``checkpoint_dir`` additionally snapshots the full ``FuncSNEState``
    (embedding, velocities, KNN tables, RNG key, reverse-edge cache --
    everything, so resume is bit-deterministic at chunk granularity)
    through :class:`repro.checkpoint.Checkpointer` every
    ``checkpoint_every`` healthy chunks; ``fit(resume_from=dir)`` picks
    up after a kill bit-identically to the uninterrupted run.
    """
    # -- checkpointing ----------------------------------------------------
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 1           # healthy chunks between snapshots
    keep_last: int = 3
    # -- rollback & retry -------------------------------------------------
    max_retries: int = 3                # consecutive trips before raising
    lr_backoff: float = 0.5
    exaggeration_backoff: float = 1.0
    # -- health probe thresholds ------------------------------------------
    min_finite_frac: float = 1.0        # trip when finite_frac < this
    max_abs_y: float = 1e8              # trip when max |Y| exceeds this
    # -- chunk-boundary state audit ---------------------------------------
    # run funcsne.audit_state every N healthy chunks (0 = off): catches
    # index-table corruption that is invisible to the finite-fraction
    # probes (poisoned indices are perfectly finite integers); costs one
    # extra host sync per audited chunk, so leave sparse in production
    audit_every: int = 0
    # -- graceful degradation ---------------------------------------------
    sticky_fallback: bool = True        # Pallas failure -> XLA ref, sticky
    # -- hang / straggler watchdog ----------------------------------------
    hang_timeout: float = 600.0         # seconds per *chunk* dispatch
    straggler_z: float = 4.0
    straggler_warmup: int = 5
    # -- telemetry sink ---------------------------------------------------
    on_event: Optional[Callable[[dict], None]] = None
    events: List[dict] = dataclasses.field(default_factory=list)

    def log(self, kind: str, **info) -> dict:
        event = {"kind": kind, **info}
        self.events.append(event)
        if self.on_event is not None:
            self.on_event(event)
        return event

    def check(self, metrics) -> Optional[str]:
        """Trip reason from one chunk's telemetry, or None when healthy.

        Comparisons are written so NaN telemetry trips too (a NaN
        ``finite_frac`` fails ``>=``): a probe that can itself go NaN
        must fail closed.
        """
        ff = float(metrics.finite_frac)
        if not (ff >= self.min_finite_frac):
            bad = int(metrics.bad_step)
            return (f"non-finite embedding: finite_frac={ff:.4f} < "
                    f"{self.min_finite_frac} (first bad step {bad})")
        ym = float(metrics.y_max_abs)
        if not (ym <= self.max_abs_y) or math.isnan(ym):
            return (f"embedding explosion: max|Y|={ym:.3e} > "
                    f"{self.max_abs_y:.3e}")
        return None

    def audit_check(self, audit) -> Optional[str]:
        """Trip reason from an :class:`~repro.core.funcsne.AuditResult`
        (any non-zero violation counter), or None when clean.  Feeds the
        same rollback/backoff path as :meth:`check`."""
        bad = [f"{name}={int(v)}" for name, v in
               zip(audit._fields, audit) if int(v) != 0]
        if bad:
            return "state audit violation: " + ", ".join(bad)
        return None

"""Embedding / KNN quality criteria: R_NX(K) and its AUC (paper's metric).

R_NX(K) (Lee et al. 2015) rescales the K-ary neighbourhood agreement
Q_NX(K) = (1/NK) sum_i |est_i[:K] & true_i[:K]| so that 0 = random, 1 =
perfect:  R_NX(K) = ((N-1) Q_NX(K) - K) / (N - 1 - K).

The AUC uses 1/K weights (multi-scale overview, emphasising local scales):
AUC = sum_K R_NX(K)/K / sum_K 1/K.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.knn import exact_knn


def _rank_in_true(est_idx, true_idx):
    """Position of each estimated neighbour inside the true order (or inf)."""
    match = est_idx[:, :, None] == true_idx[:, None, :]   # (N, Ke, Kt)
    pos = jnp.argmax(match, axis=-1)
    found = jnp.any(match, axis=-1)
    return jnp.where(found, pos, jnp.iinfo(jnp.int32).max)


def qnx_curve(est_idx, true_idx):
    """Q_NX(K) for K = 1..Kmax, Kmax = min(est K, true K).

    est_idx rows must be sorted by estimated distance; true_idx by true
    distance.  Overlap(K) counts pairs present in both prefixes; an est
    entry at position a with true-rank r joins every K > max(a, r).
    """
    kmax = min(est_idx.shape[1], true_idx.shape[1])
    est_idx = est_idx[:, :kmax]
    true_idx = true_idx[:, :kmax]
    n = est_idx.shape[0]
    rank = _rank_in_true(est_idx, true_idx)               # (N, K)
    a = jnp.arange(kmax)[None, :]
    m = jnp.maximum(a, rank)                              # joins at K = m+1
    m = jnp.where(m < kmax, m, kmax)                      # kmax bin = never
    hist = jnp.zeros((kmax + 1,)).at[m.reshape(-1)].add(1.0)
    overlap = jnp.cumsum(hist)[:kmax]                     # overlap(K=1..kmax)
    ks = jnp.arange(1, kmax + 1)
    return overlap / (n * ks)


def rnx_curve(est_idx, true_idx, n_total=None):
    if n_total is None:
        n_total = est_idx.shape[0]
    q = qnx_curve(est_idx, true_idx)
    ks = jnp.arange(1, q.shape[0] + 1)
    return ((n_total - 1) * q - ks) / jnp.maximum(n_total - 1 - ks, 1)


def rnx_auc(rnx):
    """1/K-weighted AUC of an R_NX curve."""
    ks = jnp.arange(1, rnx.shape[0] + 1, dtype=jnp.float32)
    w = 1.0 / ks
    return jnp.sum(rnx * w) / jnp.sum(w)


def knn_set_quality(est_idx, X, kmax: int = None):
    """AUC of R_NX comparing estimated HD KNN sets to the exact sets."""
    k = est_idx.shape[1] if kmax is None else kmax
    true_idx, _ = exact_knn(X, k)
    return rnx_auc(rnx_curve(est_idx[:, :k], true_idx, X.shape[0]))


def embedding_quality(X, Y, kmax: int = 64):
    """AUC of R_NX comparing LD neighbourhoods to HD neighbourhoods."""
    kmax = min(kmax, X.shape[0] - 2)
    true_idx, _ = exact_knn(X, kmax)
    emb_idx, _ = exact_knn(Y, kmax)
    return rnx_auc(rnx_curve(emb_idx, true_idx, X.shape[0]))


def embedding_rnx_curve(X, Y, kmax: int = 64):
    kmax = min(kmax, X.shape[0] - 2)
    true_idx, _ = exact_knn(X, kmax)
    emb_idx, _ = exact_knn(Y, kmax)
    return rnx_curve(emb_idx, true_idx, X.shape[0])


def one_nn_accuracy(Z, labels, rng, n_trials: int = 1, one_shot: bool = False):
    """1-NN classification accuracy in representation Z (paper Table 2).

    one_shot: reveal one random labelled example per class per trial and
    classify the rest; otherwise leave-one-out 1-NN.
    """
    Z = jnp.asarray(Z, jnp.float32)
    labels = jnp.asarray(labels)
    n = Z.shape[0]
    if not one_shot:
        idx, _ = exact_knn(Z, 1)
        return jnp.mean(labels[idx[:, 0]] == labels)

    classes = jnp.unique(labels)
    accs = []
    for t in range(n_trials):
        r = jax.random.fold_in(rng, t)
        # pick one prototype per class
        protos = []
        for ci in range(classes.shape[0]):
            members = jnp.nonzero(labels == classes[ci], size=n,
                                  fill_value=0)[0]
            count = jnp.sum(labels == classes[ci])
            pick = jax.random.randint(jax.random.fold_in(r, ci), (), 0,
                                      jnp.maximum(count, 1))
            protos.append(members[pick])
        protos = jnp.stack(protos)
        d2 = jnp.sum((Z[:, None, :] - Z[protos][None, :, :]) ** 2, axis=-1)
        pred = classes[jnp.argmin(d2, axis=1)]
        mask = ~jnp.isin(jnp.arange(n), protos)
        accs.append(jnp.sum((pred == labels) & mask) / jnp.sum(mask))
    return jnp.mean(jnp.stack(accs))

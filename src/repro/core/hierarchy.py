"""Hierarchical cluster-graph extraction via an alpha sweep (paper Sec. 4.2).

A continual FUnc-SNE optimisation is run while the LD kernel tails slowly
get heavier (alpha decreases level by level).  Snapshots Y^(l) are clustered
with DBSCAN; clusters become nodes and consecutive-level nodes are linked by

    e_ij = |C_i^(g) cap C_j^(h)| / min(|C_i|, |C_j|)   if |h - g| = 1.

The result is a graph capturing how clusters fragment as alpha decreases --
the paper's 'tweakable pre-clustering' repurposing of NE.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import numpy as np

from repro.core import funcsne
from repro.core.dbscan import dbscan, relabel_compact


@dataclasses.dataclass
class HierarchyLevel:
    alpha: float
    labels: np.ndarray          # (N,) cluster id per point, -1 = noise
    n_clusters: int
    sizes: List[int]


@dataclasses.dataclass
class ClusterGraph:
    levels: List[HierarchyLevel]
    edges: List[tuple]          # (level_g, i, level_h=g+1, j, weight)

    def summary(self) -> str:
        lines = []
        for li, lv in enumerate(self.levels):
            lines.append(f"level {li}: alpha={lv.alpha:.3f} "
                         f"clusters={lv.n_clusters} sizes={lv.sizes[:12]}")
        lines.append(f"{len(self.edges)} inter-level edges")
        return "\n".join(lines)


def cluster_graph_edges(levels: List[HierarchyLevel], min_weight: float = 0.1):
    edges = []
    for g in range(len(levels) - 1):
        a, b = levels[g], levels[g + 1]
        for i in range(a.n_clusters):
            mi = a.labels == i
            for j in range(b.n_clusters):
                mj = b.labels == j
                inter = int(np.sum(mi & mj))
                denom = min(int(np.sum(mi)), int(np.sum(mj)))
                if denom and inter / denom >= min_weight:
                    edges.append((g, i, g + 1, j, inter / denom))
    return edges


def select_eps(Y, quantile: float, *, max_rows: int = 1024,
               seed: int = 0) -> float:
    """DBSCAN ``eps`` = the ``quantile`` of pairwise snapshot distances.

    The full pairwise matrix is O(N^2) memory and time on every level's
    snapshot, which dominates the sweep long before DBSCAN does; a seeded
    row subsample caps the cost at O(max_rows^2) while the quantile's
    sampling error stays well inside DBSCAN's sensitivity to eps
    (regression-tested against the full-matrix value).
    """
    Y = np.asarray(Y)
    n = Y.shape[0]
    m = min(n, int(max_rows))
    idx = np.random.default_rng(seed).choice(n, size=m, replace=False)
    d = np.sqrt(((Y[idx, None, :] - Y[None, idx, :]) ** 2).sum(-1))
    pos = d[d > 0]
    if pos.size == 0:
        # fully collapsed snapshot (all sampled rows coincide): there is
        # no distance scale to pick from -- eps 0 makes DBSCAN cluster
        # exact duplicates instead of crashing on an empty quantile
        return 0.0
    return float(np.quantile(pos, quantile))


def extract_hierarchy(X, alphas, *, cfg: Optional[funcsne.FuncSNEConfig] = None,
                      iters_per_level: int = 300, warmup_iters: int = 300,
                      eps_quantile: float = 0.02, min_pts: int = 5, rng=None,
                      eps_sample_rows: int = 1024, eps_seed: int = 0,
                      hparams: Optional[funcsne.HParams] = None,
                      dbscan_fn: Callable = dbscan,
                      chunk_size: int = 50) -> ClusterGraph:
    """Run the continual optimisation, snapshot per alpha level, and build
    the cluster graph.  ``alphas`` should decrease (heavier tails).

    The inner optimisation runs on the scan-chunked driver (funcsne §Perf
    H15): ``chunk_size`` iterations per device dispatch instead of the
    old per-step host loop.  The warmup chunk evaluates the early-
    exaggeration schedule on device from the carried step; the per-level
    runs reuse ONE compiled chunk for every alpha (alpha is a traced
    hyperparameter), so a deep alpha sweep costs two compiles total plus
    any ragged-tail sizes.  The host syncs once per chunk and once per
    level (the DBSCAN snapshot).
    """
    import jax
    import jax.numpy as jnp

    X = jnp.asarray(X, jnp.float32)
    n = X.shape[0]
    if rng is None:
        rng = jax.random.PRNGKey(0)
    if cfg is None:
        cfg = funcsne.FuncSNEConfig(n_points=n, dim_hd=X.shape[1], dim_ld=4)
    if hparams is None:
        hparams = funcsne.default_hparams(n)
    st = funcsne.init_state(rng, X, cfg)

    chunks = {}      # (T, scheduled, horizon) -> compiled chunk program

    def run_steps(st, n_steps, hp, schedule=None, horizon=None):
        it = 0
        while it < n_steps:
            T = min(chunk_size, n_steps - it)
            # the horizon is baked into the traced schedule, so it must
            # be part of the compile key: same-T calls with a different
            # horizon may not reuse the program
            key = (T, schedule is not None, horizon)
            if key not in chunks:
                chunks[key] = funcsne.make_chunked_step(
                    cfg, T, schedule=schedule, n_iter=horizon)
            st, _, _ = chunks[key](st, X, hp)
            it += T
        return st

    # warmup at the first alpha (with early exaggeration): the device-side
    # schedule reads the carried st.step, which starts at 0 here, so it
    # sees the same (it, warmup_iters) pairs the host loop fed make_step
    st = run_steps(st, warmup_iters,
                   hparams._replace(alpha=jnp.float32(alphas[0])),
                   schedule=funcsne.default_schedule, horizon=warmup_iters)

    levels: List[HierarchyLevel] = []
    for alpha in alphas:
        hp = hparams._replace(alpha=jnp.float32(alpha))
        st = run_steps(st, iters_per_level, hp)
        Y = np.asarray(jax.device_get(st.Y))
        eps = select_eps(Y, eps_quantile, max_rows=eps_sample_rows,
                         seed=eps_seed)
        labels, k = relabel_compact(dbscan_fn(Y, eps, min_pts))
        sizes = [int(np.sum(labels == i)) for i in range(k)]
        levels.append(HierarchyLevel(float(alpha), labels, k, sizes))

    return ClusterGraph(levels, cluster_graph_edges(levels))

"""Dense JAX DBSCAN (Ester et al., 1996) for the hierarchy extraction.

O(N^2) adjacency + min-label propagation: adequate for the embedding
snapshots the hierarchy pass clusters (N up to a few 10^4).  The paper uses
DBSCAN on LD snapshots because NE broadens inter-cluster gaps, making
density clustering easy (paper Sec. 4.2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dbscan(Y, eps: float, min_pts: int = 5, max_sweeps: int = 0):
    """Returns integer labels; -1 = noise.

    Core points: >= min_pts neighbours within eps (inclusive of self).
    Clusters: connected components of the core-core eps-graph; border
    points adopt the label of their nearest core neighbour within eps.
    """
    Y = jnp.asarray(Y, jnp.float32)
    n = Y.shape[0]
    if max_sweeps <= 0:
        max_sweeps = int(jnp.ceil(jnp.log2(n))) + 2
    n2 = jnp.sum(Y * Y, axis=1)
    d2 = jnp.maximum(n2[:, None] + n2[None, :] - 2.0 * (Y @ Y.T), 0.0)
    within = d2 <= eps * eps
    core = jnp.sum(within, axis=1) >= min_pts

    adj = within & core[:, None] & core[None, :]        # core-core edges
    adj = adj | jnp.diag(core)
    labels = jnp.where(core, jnp.arange(n), n)          # n = unassigned

    def sweep(_, lab):
        # propagate the min label across core-core edges
        neigh = jnp.where(adj, lab[None, :], n)
        return jnp.minimum(lab, jnp.min(neigh, axis=1))

    labels = jax.lax.fori_loop(0, max_sweeps, sweep, labels)

    # border points: nearest core point within eps
    d2_core = jnp.where(within & core[None, :], d2, jnp.inf)
    nearest = jnp.argmin(d2_core, axis=1)
    has_core = jnp.any(within & core[None, :], axis=1)
    border_lab = jnp.where(has_core, labels[nearest], -1)
    out = jnp.where(core, labels, border_lab)
    return jnp.where(out == n, -1, out)


def relabel_compact(labels):
    """Map labels to 0..k-1 (noise stays -1); returns (labels, k)."""
    labels = jnp.asarray(labels)
    uniq = jnp.unique(jnp.where(labels < 0, jnp.max(labels) + 1, labels),
                      size=labels.shape[0], fill_value=-2)
    # jnp.unique with padding is awkward under jit; do it in numpy instead.
    import numpy as np
    lab = np.asarray(labels)
    uniq = np.unique(lab[lab >= 0])
    remap = {int(u): i for i, u in enumerate(uniq)}
    out = np.array([remap.get(int(v), -1) for v in lab], dtype=np.int32)
    return out, len(uniq)

"""Variable-tail LD similarity kernel (paper Eq. 4) and exact losses.

w_ij = (1 + ||y_i - y_j||^2 / alpha)^(-alpha),   alpha in (0, inf)
  alpha = 1   -> Student-t with 1 dof (t-SNE)
  alpha < 1   -> heavier tails (finer cluster fragmentation)
  alpha -> inf -> Gaussian limit (SNE)

Closed forms (used by kernels & tests):
  w^(1/alpha)     = (1 + d2/alpha)^(-1)
  w^(1+1/alpha)   = (1 + d2/alpha)^(-(alpha+1))
"""
from __future__ import annotations

import jax.numpy as jnp


def w_tail(d2, alpha):
    """Unnormalised LD similarity w(d2; alpha)."""
    alpha = jnp.asarray(alpha, jnp.float32)
    return jnp.exp(-alpha * jnp.log1p(d2 / alpha))


def w_pow_inv_alpha(d2, alpha):
    """w^(1/alpha) = 1 / (1 + d2/alpha)."""
    alpha = jnp.asarray(alpha, jnp.float32)
    return 1.0 / (1.0 + d2 / alpha)


def w_pow_one_plus_inv_alpha(d2, alpha):
    """w^(1+1/alpha) = (1 + d2/alpha)^(-(alpha+1))."""
    alpha = jnp.asarray(alpha, jnp.float32)
    return jnp.exp(-(alpha + 1.0) * jnp.log1p(d2 / alpha))


def pairwise_sqdists_full(Y):
    """Dense (N, N) squared distances (exact baselines / small N only)."""
    n2 = jnp.sum(Y * Y, axis=1)
    d2 = n2[:, None] + n2[None, :] - 2.0 * (Y @ Y.T)
    return jnp.maximum(d2, 0.0)


def q_matrix(Y, alpha):
    """Dense normalised LD similarities q_ij (Eq. 4); q_ii = 0."""
    d2 = pairwise_sqdists_full(Y)
    w = w_tail(d2, alpha)
    w = w * (1.0 - jnp.eye(Y.shape[0]))
    return w / jnp.sum(w), w


def kl_loss(P, Y, alpha, eps: float = 1e-12):
    """Exact KL(P || Q) with the variable-tail kernel (validation oracle)."""
    q, _ = q_matrix(Y, alpha)
    mask = P > 0
    ratio = jnp.where(mask, P / jnp.maximum(q, eps), 1.0)
    return jnp.sum(jnp.where(mask, P * jnp.log(ratio), 0.0))

"""HD affinities: perplexity-calibrated per-point bandwidths (paper Eq. 1).

t-SNE models the HD neighbourhood of point i as
  p_{j|i} = exp(-beta_i * d2_ij) / sum_k exp(-beta_i * d2_ik),
with beta_i = 1/(2 sigma_i^2) solved so that the row entropy equals
log(perplexity).  FUnc-SNE solves this over the *current estimated* KNN set
and refreshes only flagged rows (warm restart) as the neighbour sets improve.

The solver is a vectorised bisection with exponential bracket expansion; a
warm start (previous beta as first probe) halves the bracket immediately,
which is the TPU-friendly equivalent of the paper's warm restart.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_INF = jnp.inf


def entropy_of_beta(d2, beta, valid):
    """Shannon entropy (nats) of the p_{.|i} row for bandwidth beta.

    d2: (..., K) squared distances; valid: (..., K) bool; beta: (...,).
    Shift-invariant in d2 (normalised), so we subtract the row min.
    """
    d2s = jnp.where(valid, d2, _INF)
    dmin = jnp.min(jnp.where(valid, d2, _INF), axis=-1, keepdims=True)
    dmin = jnp.where(jnp.isfinite(dmin), dmin, 0.0)
    logits = -beta[..., None] * (d2s - dmin)
    logits = jnp.where(valid, logits, -_INF)
    m = jnp.max(logits, axis=-1, keepdims=True)
    m = jnp.where(jnp.isfinite(m), m, 0.0)
    e = jnp.where(valid, jnp.exp(logits - m), 0.0)
    z = jnp.sum(e, axis=-1)
    p = e / jnp.maximum(z[..., None], 1e-30)
    plogp = jnp.where(p > 0, p * jnp.log(jnp.maximum(p, 1e-30)), 0.0)
    return -jnp.sum(plogp, axis=-1)


def solve_beta(d2, perplexity, valid=None, beta0=None, n_iter: int = 40):
    """Vectorised bisection for beta_i s.t. H_i = log(perplexity).

    Entropy is monotonically decreasing in beta.  Bracket: [0, inf) with
    exponential expansion while the upper bound is open.  ``beta0`` warm-starts
    the first probe (paper's warm restart).
    """
    if valid is None:
        valid = jnp.isfinite(d2)
    target = jnp.log(jnp.asarray(perplexity, jnp.float32))
    n = d2.shape[0]
    beta = (jnp.ones((n,), jnp.float32) if beta0 is None
            else jnp.asarray(beta0, jnp.float32))
    lo = jnp.zeros((n,), jnp.float32)
    hi = jnp.full((n,), _INF, jnp.float32)

    def body(_, carry):
        beta, lo, hi = carry
        h = entropy_of_beta(d2, beta, valid)
        too_flat = h > target          # entropy too high -> increase beta
        lo = jnp.where(too_flat, beta, lo)
        hi = jnp.where(too_flat, hi, beta)
        beta_up = jnp.where(jnp.isfinite(hi), 0.5 * (lo + hi), beta * 2.0)
        beta_dn = 0.5 * (lo + hi)
        beta = jnp.where(too_flat, beta_up, beta_dn)
        return beta, lo, hi

    beta, _, _ = jax.lax.fori_loop(0, n_iter, body, (beta, lo, hi))
    return beta


def p_rows(d2, beta, valid=None):
    """Row-normalised p_{j|i} over the (estimated) KNN set."""
    if valid is None:
        valid = jnp.isfinite(d2)
    d2s = jnp.where(valid, d2, _INF)
    dmin = jnp.min(d2s, axis=-1, keepdims=True)
    dmin = jnp.where(jnp.isfinite(dmin), dmin, 0.0)
    e = jnp.where(valid, jnp.exp(-beta[:, None] * (d2s - dmin)), 0.0)
    z = jnp.sum(e, axis=-1, keepdims=True)
    return e / jnp.maximum(z, 1e-30)

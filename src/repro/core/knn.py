"""Iterative joint KNN refinement (the paper's novel ANN subroutine).

Neighbour sets are fixed-width sorted arrays (idx, d2) of shape (n, K),
ascending in d2; invalid slots hold (SENTINEL, +inf).  Each iteration
generates a fixed number of candidates per point from several *sources*
(paper Sec. 3):

  - neighbours-of-neighbours within the same space (NND-style local join),
  - cross-space: LD neighbours (and their neighbours) proposed as HD
    candidates and vice versa -- this is the positive-feedback-loop channel,
  - uniform random probes (escape local minima; paper Fig. 7 'Disjointed'),
  - optionally reverse edges (Dong et al.'s local join; used by the NND
    baseline, off by default for FUnc-SNE).

All shapes are static -> one fused XLA/TPU program per iteration; the GPU
paper's ragged atomically-updated lists become a dense top-k merge.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp

SENTINEL = jnp.iinfo(jnp.int32).max  # invalid-slot index marker


def init_knn_idx(rng, n_rows, n_total, k, row_offset: int = 0):
    """Random initial neighbour sets (paper: 'randomly initialised').

    Rows are (random base + 0..k-1) mod n: distinct within a row by
    construction (duplicate entries would double-count forces and violate
    the merge invariants); diversity comes from the first refinements.
    """
    assert k <= n_total - 1, (k, n_total)
    base = jax.random.randint(rng, (n_rows, 1), 0, n_total, dtype=jnp.int32)
    rows = row_offset + jnp.arange(n_rows, dtype=jnp.int32)[:, None]
    # offsets in [1, n_total-1]: distinct and never 0 (no self-loops)
    offs = 1 + (base + jnp.arange(k, dtype=jnp.int32)[None, :]) \
        % (n_total - 1)
    return ((rows + offs) % n_total).astype(jnp.int32)


def sample_hops(rng, first_idx, second_idx, rows, n_samples):
    """Two-hop candidates: second_idx[first_idx[i, a], b] for random (a, b).

    first_idx: (n, K1) rows for the local points; second_idx: (N, K2) global
    table (may equal first_idx's global source).  Returns (n, n_samples).
    """
    n, k1 = first_idx.shape
    k2 = second_idx.shape[1]
    ra, rb = jax.random.split(rng)
    a = jax.random.randint(ra, (n, n_samples), 0, k1)
    b = jax.random.randint(rb, (n, n_samples), 0, k2)
    mid = jnp.take_along_axis(first_idx, a, axis=1)          # (n, s)
    mid = jnp.where(mid == SENTINEL, rows[:, None] % second_idx.shape[0], mid)
    cand = second_idx[jnp.clip(mid, 0, second_idx.shape[0] - 1)]  # (n, s, K2)
    return jnp.take_along_axis(cand, b[..., None], axis=2)[..., 0]


def sample_direct(rng, idx, n_samples):
    """One-hop candidates: random entries of the point's own list."""
    n, k = idx.shape
    a = jax.random.randint(rng, (n, n_samples), 0, k)
    return jnp.take_along_axis(idx, a, axis=1)


def sample_uniform(rng, n, n_total, n_samples):
    return jax.random.randint(rng, (n, n_samples), 0, n_total,
                              dtype=jnp.int32)


def reverse_neighbors(idx, n_total, r, fill_rng):
    """Sampled reverse edges: up to ``r`` points that list i as a neighbour.

    Built with one argsort over the E = n*K directed edges (TPU-friendly
    replacement for the GPU scatter-append).  Rows with fewer than r reverse
    edges are padded with uniform random points.
    """
    n, k = idx.shape
    tgt = idx.reshape(-1)
    src = jnp.repeat(jnp.arange(n, dtype=jnp.int32), k)
    order = jnp.argsort(tgt)
    tgt_s = tgt[order]
    src_s = src[order]
    starts = jnp.searchsorted(tgt_s, jnp.arange(n_total, dtype=jnp.int32))
    counts = jnp.diff(jnp.append(starts, tgt_s.shape[0]))
    pos = starts[:, None] + jnp.arange(r)[None, :]
    valid = jnp.arange(r)[None, :] < counts[:, None]
    gathered = src_s[jnp.clip(pos, 0, src_s.shape[0] - 1)]
    rand = sample_uniform(fill_rng, n_total, n_total, r)
    return jnp.where(valid, gathered, rand)


def dedup_candidates(rows, cur_idx, cand_idx):
    """Mark duplicate candidates invalid.

    A candidate is invalid if it equals the row's own id, an existing
    neighbour, or an earlier candidate in the same row.  Returns a bool mask.
    """
    self_dup = cand_idx == rows[:, None]
    in_cur = jnp.any(cand_idx[:, :, None] == cur_idx[:, None, :], axis=-1)
    earlier = cand_idx[:, :, None] == cand_idx[:, None, :]
    c = cand_idx.shape[1]
    tri = jnp.tril(jnp.ones((c, c), bool), k=-1)
    within = jnp.any(earlier & tri[None], axis=-1)
    sentinel = cand_idx == SENTINEL
    return ~(self_dup | in_cur | within | sentinel)


def merge_knn(cur_idx, cur_d, cand_idx, cand_d, valid_mask):
    """Merge candidates into the sorted K-NN arrays.

    Returns (idx, d, row_improved).  row_improved is True iff at least one
    candidate was admitted (drives the paper's refresh probability and the
    sigma refresh flags).
    """
    k = cur_idx.shape[1]
    cand_d = jnp.where(valid_mask, cand_d, jnp.inf)
    all_idx = jnp.concatenate([cur_idx, cand_idx], axis=1)
    all_d = jnp.concatenate([cur_d, cand_d], axis=1)
    neg_top, pos = jax.lax.top_k(-all_d, k)       # k smallest distances
    new_d = -neg_top
    new_idx = jnp.take_along_axis(all_idx, pos, axis=1)
    worst = cur_d[:, -1]
    improved = jnp.any(cand_d < worst[:, None], axis=1)
    return new_idx, new_d, improved


@functools.partial(jax.jit, static_argnames=("k",))
def exact_knn(X, k: int, active=None):
    """O(N^2) exact KNN (ground truth for tests/benchmarks; small N only)."""
    n = X.shape[0]
    n2 = jnp.sum(X * X, axis=1)
    d2 = n2[:, None] + n2[None, :] - 2.0 * (X @ X.T)
    d2 = jnp.maximum(d2, 0.0)
    d2 = jnp.where(jnp.eye(n, dtype=bool), jnp.inf, d2)  # not eye*inf: 0*inf=NaN
    if active is not None:
        d2 = jnp.where(active[None, :], d2, jnp.inf)
    neg_top, idx = jax.lax.top_k(-d2, k)
    return idx.astype(jnp.int32), -neg_top

"""Iterative joint KNN refinement (the paper's novel ANN subroutine).

Neighbour sets are fixed-width sorted arrays (idx, d2) of shape (n, K),
ascending in d2; invalid slots hold (SENTINEL, +inf).  Each iteration
generates a fixed number of candidates per point from several *sources*
(paper Sec. 3):

  - neighbours-of-neighbours within the same space (NND-style local join),
  - cross-space: LD neighbours (and their neighbours) proposed as HD
    candidates and vice versa -- this is the positive-feedback-loop channel,
  - uniform random probes (escape local minima; paper Fig. 7 'Disjointed'),
  - optionally reverse edges (Dong et al.'s local join; used by the NND
    baseline, off by default for FUnc-SNE).

All shapes are static -> one fused XLA/TPU program per iteration; the GPU
paper's ragged atomically-updated lists become a dense top-k merge.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

SENTINEL = jnp.iinfo(jnp.int32).max  # invalid-slot index marker


# --------------------------------------------------------------------------
# Counter-based hash RNG (§Perf H17: candidate-fused sampling)
#
# A splittable, order-invariant uniform generator: every draw is a pure
# int32 hash of ``(salt, row, draw)`` -- no carried PRNG state, no
# threefry chain in the step HLO, and the exact same arithmetic runs
# vectorised in jnp (the reference sampler below) and as scalar ops
# inside the Pallas gather kernel, so kernel-vs-ref parity is bit-exact.
# The mixer is the 'lowbias32' xorshift-multiply finalizer (Wellons'
# hash-prospector output); constants are pre-wrapped into int32 so
# multiplication relies only on two's-complement wraparound, which jnp,
# XLA and Mosaic all share.

_MIX1 = np.int32(np.uint32(0x21f0aaad))
_MIX2 = np.int32(np.uint32(0xd35a2d97))
_KEY_ROW = np.int32(np.uint32(0x85ebca6b))
_KEY_DRAW = np.int32(np.uint32(0xc2b2ae35))
_POS_MASK = np.int32(0x7fffffff)


def hash_mix(h):
    """lowbias32 finalizer on int32 bits (wrapping multiply semantics)."""
    h = h ^ jax.lax.shift_right_logical(h, 16)
    h = h * _MIX1
    h = h ^ jax.lax.shift_right_logical(h, 15)
    h = h * _MIX2
    h = h ^ jax.lax.shift_right_logical(h, 15)
    return h


def hash3(salt, row, draw):
    """Counter hash of ``(salt, row, draw)`` -> int32 uniform bits.

    All inputs are int32 scalars/arrays (broadcasting); two mix rounds so
    row and draw each pass through a full-avalanche finalizer.  Inputs
    are coerced to int32 so Python-int keys take the same wrapping
    multiply path as traced values (no eager-numpy overflow).
    """
    row = jnp.asarray(row, jnp.int32)
    draw = jnp.asarray(draw, jnp.int32)
    h = hash_mix(jnp.asarray(salt, jnp.int32) ^ (row * _KEY_ROW))
    return hash_mix(h ^ (draw * _KEY_DRAW))


def counter_randint(salt, row, draw, bound):
    """Uniform int32 in [0, bound) from the counter hash (31-bit mod)."""
    return (hash3(salt, row, draw) & _POS_MASK) % bound


def counter_uniform01(h):
    """int32 hash bits -> f32 uniform in [0, 1) (top 24 bits, exact)."""
    bits = jax.lax.shift_right_logical(h, 8)
    return bits.astype(jnp.float32) * np.float32(1.0 / (1 << 24))


def key_salt(rng):
    """Fold a PRNG key's raw bits into one int32 salt (no threefry ops).

    The key is only *read* (``jax.random.key_data``), never advanced, so
    deriving per-step salts from the carried state key adds zero random-op
    HLO to the step.
    """
    data = jax.lax.bitcast_convert_type(
        jax.random.key_data(rng).reshape(-1), jnp.int32)
    salt = jnp.int32(0)
    for i in range(data.shape[0]):
        salt = hash_mix(salt ^ data[i])
    return salt


def as_salt(rng_or_salt):
    """Coerce a phase RNG argument to an int32 salt.

    The step driver passes the already-folded base salt (an int32
    scalar, passthrough); direct phase calls (tests, external drivers)
    may still hand a PRNG key, whose raw bits are folded via
    :func:`key_salt`.
    """
    x = jnp.asarray(rng_or_salt)
    if x.ndim == 0 and x.dtype == jnp.int32:
        return x
    return key_salt(rng_or_salt)


def counter_candidates(salt, rows, sources, first_tables=(),
                       second_tables=(), n_total=None, extra=None):
    """Pure-jnp reference of the candidate-fused sampler (§Perf H17).

    Generates the (B, C) candidate block that ``knn_merge``'s
    ``cand_fused`` kernel derives in-kernel, with bit-identical draws:
    slot ``g`` of row ``r`` consumes ``hash3(salt, rows[r], 2g)`` (the
    'a' stream) and, for two-hop slots, ``hash3(salt, rows[r], 2g+1)``
    (the 'b' stream).  Being keyed on *global* row ids makes the draws
    order- and shard-invariant: a row samples the same candidates
    whichever device or batch slice it lands in.

    ``sources`` is a static tuple describing the candidate layout:
      ("uniform", c)           c uniform probes over [0, n_total)
      ("one_hop", f, c)        c entries of ``first_tables[f]`` (own row)
      ("two_hop", f, s, c)     c chained picks
                               ``second_tables[s][first_tables[f][r, a], b]``
                               (SENTINEL mids fall back to the row id, as
                               ``sample_hops`` does); the gather is flat
                               (``reshape(-1)``), so no (B, c, K2)
                               broadcast exists in the HLO
      ("extra", c)             c precomputed candidates from ``extra``
                               (e.g. cached reverse edges); consumes slot
                               ids but no draws
    """
    b = rows.shape[0]
    rows_c = rows.astype(jnp.int32)[:, None]
    parts = []
    g = 0
    e0 = 0
    for src in sources:
        kind, c = src[0], src[-1]
        if c == 0:
            continue
        slots = g + jnp.arange(c, dtype=jnp.int32)[None, :]
        if kind == "uniform":
            cand = counter_randint(salt, rows_c, 2 * slots, n_total)
        elif kind == "one_hop":
            f = first_tables[src[1]]
            a = counter_randint(salt, rows_c, 2 * slots, f.shape[1])
            cand = jnp.take_along_axis(f, a, axis=1)
        elif kind == "two_hop":
            f = first_tables[src[1]]
            s = second_tables[src[2]]
            n2, k2 = s.shape
            a = counter_randint(salt, rows_c, 2 * slots, f.shape[1])
            mid = jnp.take_along_axis(f, a, axis=1)
            mid = jnp.where(mid == SENTINEL, rows_c % n2, mid)
            mid = jnp.clip(mid, 0, n2 - 1)
            bb = counter_randint(salt, rows_c, 2 * slots + 1, k2)
            cand = s.reshape(-1)[mid * k2 + bb]
        elif kind == "extra":
            cand = extra[:, e0:e0 + c]
            e0 += c
        else:
            raise ValueError(f"unknown candidate source {kind!r}")
        parts.append(cand.astype(jnp.int32))
        g += c
    if not parts:
        return jnp.zeros((b, 0), jnp.int32)
    return jnp.concatenate(parts, axis=1)


def counter_fill(salt, n, r):
    """(n, r) uniform fill table for ``reverse_neighbors`` (counter RNG)."""
    rows = jnp.arange(n, dtype=jnp.int32)[:, None]
    draws = jnp.arange(r, dtype=jnp.int32)[None, :]
    return counter_randint(salt, rows, draws, n)


def init_knn_idx(rng, n_rows, n_total, k, row_offset: int = 0):
    """Random initial neighbour sets (paper: 'randomly initialised').

    Rows are (random base + 0..k-1) mod n: distinct within a row by
    construction (duplicate entries would double-count forces and violate
    the merge invariants); diversity comes from the first refinements.
    """
    assert k <= n_total - 1, (k, n_total)
    base = jax.random.randint(rng, (n_rows, 1), 0, n_total, dtype=jnp.int32)
    rows = row_offset + jnp.arange(n_rows, dtype=jnp.int32)[:, None]
    # offsets in [1, n_total-1]: distinct and never 0 (no self-loops)
    offs = 1 + (base + jnp.arange(k, dtype=jnp.int32)[None, :]) \
        % (n_total - 1)
    return ((rows + offs) % n_total).astype(jnp.int32)


def sample_hops(rng, first_idx, second_idx, rows, n_samples):
    """Two-hop candidates: second_idx[first_idx[i, a], b] for random (a, b).

    first_idx: (n, K1) rows for the local points; second_idx: (N, K2) global
    table (may equal first_idx's global source).  Returns (n, n_samples).
    """
    n, k1 = first_idx.shape
    k2 = second_idx.shape[1]
    ra, rb = jax.random.split(rng)
    a = jax.random.randint(ra, (n, n_samples), 0, k1)
    b = jax.random.randint(rb, (n, n_samples), 0, k2)
    mid = jnp.take_along_axis(first_idx, a, axis=1)          # (n, s)
    mid = jnp.where(mid == SENTINEL, rows[:, None] % second_idx.shape[0], mid)
    cand = second_idx[jnp.clip(mid, 0, second_idx.shape[0] - 1)]  # (n, s, K2)
    return jnp.take_along_axis(cand, b[..., None], axis=2)[..., 0]


def sample_direct(rng, idx, n_samples):
    """One-hop candidates: random entries of the point's own list."""
    n, k = idx.shape
    a = jax.random.randint(rng, (n, n_samples), 0, k)
    return jnp.take_along_axis(idx, a, axis=1)


def sample_uniform(rng, n, n_total, n_samples):
    return jax.random.randint(rng, (n, n_samples), 0, n_total,
                              dtype=jnp.int32)


def reverse_neighbors(idx, n_total, r, fill_rng=None, fill=None):
    """Sampled reverse edges: up to ``r`` points that list i as a neighbour.

    Built with one argsort over the E = n*K directed edges (TPU-friendly
    replacement for the GPU scatter-append).  Rows with fewer than r reverse
    edges are padded with uniform random points: either threefry-sampled
    from ``fill_rng`` (legacy) or a caller-precomputed ``fill`` table (the
    counter-RNG path, which must keep threefry out of the step HLO).

    The full rebuild costs an argsort over all n*K directed edges, so
    callers cache the result in state and refresh it every
    ``rev_refresh`` steps (``refresh=1`` == the legacy per-iteration
    rebuild, bit-for-bit).
    """
    assert (fill is None) != (fill_rng is None), "pass fill_rng xor fill"
    n, k = idx.shape
    tgt = idx.reshape(-1)
    src = jnp.repeat(jnp.arange(n, dtype=jnp.int32), k)
    order = jnp.argsort(tgt)
    tgt_s = tgt[order]
    src_s = src[order]
    starts = jnp.searchsorted(tgt_s, jnp.arange(n_total, dtype=jnp.int32))
    counts = jnp.diff(jnp.append(starts, tgt_s.shape[0]))
    pos = starts[:, None] + jnp.arange(r)[None, :]
    valid = jnp.arange(r)[None, :] < counts[:, None]
    gathered = src_s[jnp.clip(pos, 0, src_s.shape[0] - 1)]
    if fill is None:
        fill = sample_uniform(fill_rng, n_total, n_total, r)
    return jnp.where(valid, gathered, fill)


def dedup_candidates(rows, cur_idx, cand_idx):
    """Mark duplicate candidates invalid.

    A candidate is invalid if it equals the row's own id, an existing
    neighbour, or an earlier candidate in the same row.  Returns a bool mask.
    """
    self_dup = cand_idx == rows[:, None]
    in_cur = jnp.any(cand_idx[:, :, None] == cur_idx[:, None, :], axis=-1)
    earlier = cand_idx[:, :, None] == cand_idx[:, None, :]
    c = cand_idx.shape[1]
    tri = jnp.tril(jnp.ones((c, c), bool), k=-1)
    within = jnp.any(earlier & tri[None], axis=-1)
    sentinel = cand_idx == SENTINEL
    return ~(self_dup | in_cur | within | sentinel)


def merge_knn(cur_idx, cur_d, cand_idx, cand_d, valid_mask):
    """Merge candidates into the sorted K-NN arrays.

    Returns (idx, d, row_improved).  row_improved is True iff at least one
    candidate was admitted (drives the paper's refresh probability and the
    sigma refresh flags).
    """
    k = cur_idx.shape[1]
    cand_d = jnp.where(valid_mask, cand_d, jnp.inf)
    all_idx = jnp.concatenate([cur_idx, cand_idx], axis=1)
    all_d = jnp.concatenate([cur_d, cand_d], axis=1)
    neg_top, pos = jax.lax.top_k(-all_d, k)       # k smallest distances
    new_d = -neg_top
    new_idx = jnp.take_along_axis(all_idx, pos, axis=1)
    worst = cur_d[:, -1]
    improved = jnp.any(cand_d < worst[:, None], axis=1)
    return new_idx, new_d, improved


@functools.partial(jax.jit, static_argnames=("k",))
def exact_knn(X, k: int, active=None):
    """O(N^2) exact KNN (ground truth for tests/benchmarks; small N only)."""
    n = X.shape[0]
    n2 = jnp.sum(X * X, axis=1)
    d2 = n2[:, None] + n2[None, :] - 2.0 * (X @ X.T)
    d2 = jnp.maximum(d2, 0.0)
    d2 = jnp.where(jnp.eye(n, dtype=bool), jnp.inf, d2)  # not eye*inf: 0*inf=NaN
    if active is not None:
        d2 = jnp.where(active[None, :], d2, jnp.inf)
    neg_top, idx = jax.lax.top_k(-d2, k)
    return idx.astype(jnp.int32), -neg_top

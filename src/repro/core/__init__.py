"""FUnc-SNE core: the paper's contribution as composable JAX modules.

Public surface:
  funcsne     -- config/state/step/fit + shard_map distribution
  affinities  -- perplexity-calibrated HD similarities
  knn         -- joint iterative KNN machinery
  ld_kernels  -- variable-tail LD kernel + exact losses
  quality     -- R_NX(K) / AUC criteria, 1-NN evaluation
  nnd         -- nearest-neighbour descent baseline
  baselines   -- exact variable-tail t-SNE, NS-only (UMAP-regime) embedding
  dbscan, hierarchy -- alpha-sweep cluster-graph extraction
"""

from repro.core.funcsne import (  # noqa: F401
    AxisCtx, ChunkMetrics, FuncSNEConfig, FuncSNEState, HParams, add_points,
    default_hparams, default_schedule, fit, funcsne_step, init_state,
    make_chunked_step, make_distributed_step, make_step, pca_directions,
    remove_points, rescale_embedding)

"""Baselines the paper compares against, in the same JAX substrate.

- ``exact_tsne``: O(N^2) gradient descent on the exact variable-tail KL
  (Eqs. 4-5).  This is the quality oracle: FIt-SNE/BH-t-SNE are
  *approximations of this exact gradient* (their quality at small N matches
  it), so at benchmark scale it stands in for FIt-SNE; it also validates
  FUnc-SNE's force decomposition against jax.grad of the true loss.
- ``negative_sampling_embed``: the UMAP/LargeVis regime inside our force
  machinery -- two-phase (exact KNN precomputed, fixed), attraction over HD
  neighbours, repulsion by *negative sampling only* (no LD-neighbour term).
  Ablating the paper's middle term of Eq. 6 isolates its contribution
  (paper Table 1 row 1 vs row 3).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import affinities
from repro.core import knn as knn_lib
from repro.core.funcsne import HParams, default_hparams, default_schedule
from repro.core.ld_kernels import (kl_loss, pairwise_sqdists_full, w_tail,
                                   w_pow_inv_alpha)
from repro.kernels.ne_forces.ops import ne_forces


def exact_p_matrix(X, perplexity: float):
    """Dense symmetrised p_ij from exact pairwise distances (Eq. 1)."""
    n = X.shape[0]
    d2 = pairwise_sqdists_full(X)
    d2 = jnp.where(jnp.eye(n, dtype=bool), jnp.inf, d2)  # not eye*inf: 0*inf=NaN
    beta = affinities.solve_beta(d2, perplexity)
    p_cond = affinities.p_rows(d2, beta)
    return (p_cond + p_cond.T) / (2.0 * n)


def exact_tsne_grad(Y, P, alpha):
    """Analytic Eq. 5 gradient: 4 sum_j (p_ij - q_ij) w^(1/alpha) (y_i-y_j)."""
    n = Y.shape[0]
    d2 = pairwise_sqdists_full(Y)
    w = w_tail(d2, alpha) * (1.0 - jnp.eye(n))
    q = w / jnp.sum(w)
    wi = w_pow_inv_alpha(d2, alpha)
    m = (P - q) * wi
    # grad_i = 4 [ y_i * sum_j m_ij - sum_j m_ij y_j ]
    return 4.0 * (Y * jnp.sum(m, axis=1, keepdims=True) - m @ Y)


def exact_tsne(X=None, P=None, *, dim_ld: int = 2, alpha: float = 1.0,
               perplexity: float = 30.0, n_iter: int = 500, rng=None,
               lr: float = None, use_autodiff: bool = False, Y0=None):
    """Exact (quadratic) variable-tail t-SNE with gains + momentum."""
    if P is None:
        P = exact_p_matrix(jnp.asarray(X, jnp.float32), perplexity)
    n = P.shape[0]
    if rng is None:
        rng = jax.random.PRNGKey(0)
    if lr is None:
        lr = max(50.0, n / 12.0)
    Y = (jax.random.normal(rng, (n, dim_ld)) * 1e-2 if Y0 is None
         else jnp.asarray(Y0, jnp.float32))
    vel = jnp.zeros_like(Y)
    gains = jnp.ones_like(Y)

    grad_fn = (jax.grad(lambda y: kl_loss(P, y, alpha)) if use_autodiff
               else lambda y, p=P: exact_tsne_grad(y, p, alpha))

    @jax.jit
    def step(carry, ex):
        Y, vel, gains = carry
        g = grad_fn(Y) if use_autodiff else exact_tsne_grad(Y, P * ex, alpha)
        # note: exaggeration multiplies the attractive p term only
        dY = -g
        same = jnp.sign(dY) == jnp.sign(vel)
        gains = jnp.clip(jnp.where(same, gains + 0.2, gains * 0.8), 0.01)
        vel = 0.8 * vel + lr * gains * dY
        return (Y + vel, vel, gains), None

    for it in range(n_iter):
        ex = 12.0 if it < n_iter // 4 else 1.0
        (Y, vel, gains), _ = step((Y, vel, gains), ex)
    return Y


@dataclasses.dataclass(frozen=True)
class NSConfig:
    """Negative-sampling-only (UMAP-regime) embedding config."""
    k_hd: int = 32
    n_negatives: int = 8
    backend: str = "auto"


def negative_sampling_embed(X, *, cfg: NSConfig = NSConfig(),
                            dim_ld: int = 2, n_iter: int = 750,
                            hparams: HParams = None, rng=None):
    """Two-phase NS-only baseline (UMAP/LargeVis regime).

    Phase 1: exact KNN + perplexity calibration (fixed thereafter).
    Phase 2: attraction over the KNN graph, repulsion from uniform negative
    samples only.  Identical kernels/optimiser to FUnc-SNE; the only
    difference is the missing LD-neighbour repulsion term and the frozen
    neighbour sets.
    """
    X = jnp.asarray(X, jnp.float32)
    n = X.shape[0]
    if rng is None:
        rng = jax.random.PRNGKey(0)
    if hparams is None:
        hparams = default_hparams(n)
    r_y, r_it = jax.random.split(rng)

    idx, d2 = knn_lib.exact_knn(X, cfg.k_hd)
    beta = affinities.solve_beta(d2, hparams.perplexity)
    p = affinities.p_rows(d2, beta)
    Y = jax.random.normal(r_y, (n, dim_ld)) * 1e-2
    vel = jnp.zeros_like(Y)
    gains = jnp.ones_like(Y)
    zhat = jnp.float32(float(n))

    @jax.jit
    def step(carry, rng, hp: HParams):
        Y, vel, gains, zhat, it = carry
        coef_a = p / (2.0 * n)
        agg_a, edge_a, _ = ne_forces(Y, Y[idx], coef_a, hp.alpha,
                                     mode="attraction", backend=cfg.backend)
        neg = jax.random.randint(rng, (n, cfg.n_negatives), 0, n)
        ones = jnp.ones((n, cfg.n_negatives), jnp.float32)
        agg_n, _, wsum_n = ne_forces(Y, Y[neg], ones, hp.alpha,
                                     mode="repulsion", backend=cfg.backend)
        scale = (n - 1.0) / cfg.n_negatives
        z_est = jnp.maximum(scale * jnp.sum(wsum_n), 1e-8)
        zhat = jnp.where(it == 0, z_est, 0.9 * zhat + 0.1 * z_est)
        buf = hp.attraction * hp.exaggeration * agg_a \
            + hp.repulsion * scale / zhat * agg_n
        buf = buf.at[idx.reshape(-1)].add(
            -(hp.attraction * hp.exaggeration * edge_a).reshape(-1, Y.shape[1]))
        dY = 4.0 * buf
        same = jnp.sign(dY) == jnp.sign(vel)
        gains = jnp.clip(jnp.where(same, gains + 0.2, gains * 0.8), 0.01)
        vel = hp.momentum * vel + hp.lr * gains * dY
        return (Y + vel, vel, gains, zhat, it + 1)

    carry = (Y, vel, gains, zhat, jnp.int32(0))
    for it in range(n_iter):
        hp = default_schedule(it, n_iter, hparams)
        carry = step(carry, jax.random.fold_in(r_it, it), hp)
    return carry[0]

"""Production mesh construction + sharding utilities.

The assigned production mesh is (data=16, model=16) per pod (256 chips,
v5e), and (pod=2, data=16, model=16) for the 2-pod multi-pod dry-run.
Importing this module never touches jax device state; meshes are built
only inside ``make_production_mesh()``.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def host_device_blocks(devices, n_hosts: int) -> list:
    """Partition a flat device list into ``n_hosts`` contiguous blocks.

    The simulated-pod convention used by the elastic coordinator (and by
    :func:`repro.checkpoint.row_shard_filter` for rows): host ``h`` owns
    ``devices[h*n/H : (h+1)*n/H]``.  Matches how real pods enumerate --
    ``jax.devices()`` orders by process, so a process's devices ARE a
    contiguous block.
    """
    devices = list(devices)
    n = len(devices)
    if not 1 <= n_hosts <= n:
        raise ValueError(f"n_hosts={n_hosts} for {n} devices")
    return [devices[h * n // n_hosts:(h + 1) * n // n_hosts]
            for h in range(n_hosts)]


def batch_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _axis_size(mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, (tuple, list)):
        n = 1
        for a in entry:
            n *= mesh.shape[a]
        return n
    return mesh.shape[entry]


def sanitize_spec(mesh, spec: P, shape) -> P:
    """Drop spec entries whose mesh-axis product does not divide the dim.

    Keeps every (arch x shape) cell shardable without per-arch special
    cases (e.g. 24 SSD heads on a 16-wide model axis, batch=1 decode).
    """
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, entry in zip(shape, entries):
        size = _axis_size(mesh, entry)
        out.append(entry if size > 1 and dim % size == 0 else None)
    return P(*out)


def tree_shardings(mesh, spec_tree, shape_tree) -> Any:
    """NamedSharding tree from a PartitionSpec tree + eval_shape tree."""
    def one(spec, shaped):
        return NamedSharding(mesh, sanitize_spec(mesh, spec, shaped.shape))

    return jax.tree.map(one, spec_tree, shape_tree,
                        is_leaf=lambda x: isinstance(x, P))


def replicated(mesh):
    return NamedSharding(mesh, P())

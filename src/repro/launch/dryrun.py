import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
# ^ MUST precede any jax import: jax locks the device count on first init.
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver:
  1. builds the production mesh ((16,16) or (2,16,16) CPU stand-ins),
  2. eval_shape's params/opt/cache (ShapeDtypeStruct only -- no allocation),
  3. jits train_step (train shapes) or serve_step (decode shapes) with the
     full sharding config and ``.lower().compile()``s it,
  4. records memory_analysis / cost_analysis / per-collective wire bytes /
     roofline terms to results/dryrun/<arch>__<shape>__<mesh>.json.

The FUnc-SNE production cell ('funcsne-1m': N=2^20 points, M=192, d_ld=32)
is lowered through the same path via its shard_map'd distributed step.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import dataclasses
import gzip
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import SHAPES, get_arch, list_archs
from repro.core import funcsne
from repro.launch import roofline as rl
from repro.launch.mesh import (batch_axes, make_production_mesh,
                               sanitize_spec, tree_shardings)
from repro.launch.steps import (batch_struct, decode_structs, make_model,
                                make_optimizer, make_serve_step,
                                make_train_step, params_and_opt_structs)

RESULTS_DIR = Path(__file__).resolve().parents[3] / "results" / "dryrun"

FUNCSNE_CELLS = {
    "embed_1m": dict(n_points=1 << 20, dim_hd=192, dim_ld=32, k_hd=32,
                     k_ld=16, n_negatives=16),
}


def _spec_bytes(struct, sharding) -> float:
    n = struct.size * jnp.dtype(struct.dtype).itemsize
    shards = 1
    for entry in sharding.spec:
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        for a in axes:
            shards *= sharding.mesh.shape[a]
    return n / shards


def _tree_bytes_per_chip(structs, shardings) -> float:
    leaves_s = jax.tree.leaves(structs)
    leaves_h = jax.tree.leaves(shardings,
                               is_leaf=lambda x: isinstance(x, NamedSharding))
    return float(sum(_spec_bytes(s, h) for s, h in zip(leaves_s, leaves_h)))


def _memory_analysis(compiled):
    try:
        ma = compiled.memory_analysis()
        if ma is None:
            return None
        return {k: getattr(ma, k) for k in (
            "argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes") if hasattr(ma, k)}
    except Exception as e:            # CPU backend may not support it
        return {"error": repr(e)}


def _cost_analysis(compiled):
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        return {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float)) and (
                    "flops" in k or "bytes" in k or k in ("transcendentals",))}
    except Exception as e:
        return {"error": repr(e)}


def run_lm_cell(arch: str, shape_name: str, multi_pod: bool,
                save_hlo: bool = False, overrides: dict = None) -> dict:
    cfg = get_arch(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    mesh_name = "multi" if multi_pod else "single"
    res = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "overrides": overrides or {}}

    if shape.kind == "decode" and shape_name == "long_500k" \
            and not cfg.supports_long:
        res["status"] = "skipped"
        res["reason"] = ("pure full-attention arch; long_500k needs "
                         "sub-quadratic attention (DESIGN.md Sec. 4)")
        return res

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    model = make_model(cfg, mesh, global_batch=shape.global_batch)
    opt = make_optimizer(cfg)
    p_struct, o_struct = params_and_opt_structs(cfg, model, opt)
    p_sh = tree_shardings(mesh, model.param_specs(), p_struct)
    o_sh = _opt_shardings(mesh, model, o_struct)

    t0 = time.time()
    if shape.kind in ("train", "prefill"):
        # prefill shapes are exercised through the fwd+bwd train graph too;
        # kind='prefill' lowers forward-only loss (no optimiser update).
        b_struct = batch_struct(cfg, shape.seq_len, shape.global_batch)
        b_sh = jax.tree.map(
            lambda s: NamedSharding(mesh, sanitize_spec(
                mesh, P(batch_axes(mesh)), s.shape)), b_struct)
        if shape.kind == "train":
            step = make_train_step(model, opt)
            fn = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                         out_shardings=(p_sh, o_sh, None),
                         donate_argnums=(0, 1))
            lowered = fn.lower(p_struct, o_struct, b_struct)
        else:
            # inference prefill: forward to next-token logits (the KV-cache
            # store is pure data movement; see EXPERIMENTS.md Sec. Dry-run)
            def prefill(params, batch):
                h = model.hidden_states(params, batch["inputs"])
                return model._logits_fn(params)(h[:, -1:, :])
            fn = jax.jit(prefill, in_shardings=(p_sh, b_sh))
            lowered = fn.lower(p_struct, b_struct)
    else:
        c_struct, in_struct, len_struct = decode_structs(
            cfg, model, shape.seq_len, shape.global_batch)
        c_sh = tree_shardings(mesh, model.cache_specs(), c_struct)
        res["_cache_struct"] = c_struct
        res["_cache_sh"] = c_sh
        serve = make_serve_step(model)
        in_sh = NamedSharding(mesh, sanitize_spec(
            mesh, P(batch_axes(mesh)), in_struct.shape))
        fn = jax.jit(serve,
                     in_shardings=(p_sh, c_sh, in_sh, NamedSharding(
                         mesh, P())),
                     donate_argnums=(1,))
        lowered = fn.lower(p_struct, c_struct, in_struct, len_struct)
    res["lower_s"] = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    res["compile_s"] = time.time() - t0
    res["status"] = "ok"

    _fill_analysis(res, compiled, chips, save_hlo,
                   f"{arch}__{shape_name}__{mesh_name}")
    n_total = rl.count_params(p_struct)
    n_active = rl.active_params(cfg, n_total)
    res["params_total"] = n_total
    res["params_active"] = n_active
    param_bytes = _tree_bytes_per_chip(p_struct, p_sh)
    opt_bytes = _tree_bytes_per_chip(o_struct, o_sh)
    res["param_bytes_per_chip"] = param_bytes
    res["opt_bytes_per_chip"] = opt_bytes
    res["state_bytes_per_chip"] = param_bytes + opt_bytes

    mf = rl.model_flops(cfg, n_total, n_active, shape.seq_len,
                        shape.global_batch, shape.kind)
    res["model_flops_total"] = mf
    hlo_flops = res["dot_flops_per_chip"]
    if hlo_flops:
        res["model_flops_ratio"] = mf / chips / hlo_flops

    # analytic HBM traffic (see rl.memory_traffic_*)
    cbytes = jnp.dtype(cfg.compute_dtype).itemsize

    def per_chip(shape_t, spec):
        n = cbytes
        for d in shape_t:
            n *= d
        sp = sanitize_spec(mesh, spec, shape_t)
        shards = 1
        for entry in sp:
            if entry is None:
                continue
            for a in (entry if isinstance(entry, tuple) else (entry,)):
                shards *= mesh.shape[a]
        return n / shards

    baxes = batch_axes(mesh)
    B, S, D, V = (shape.global_batch, shape.seq_len, cfg.d_model,
                  cfg.vocab_size)
    if shape.kind == "train":
        carry = model.n_stack * per_chip((B, S, D), P(baxes, "model", None))
        logits = per_chip((B, S, V), P(baxes, None, "model"))
        attn_io = 0.0
        if cfg.family not in ("ssm",):
            nq = max(1, S // cfg.attn_chunk_q)
            if cfg.is_mla:
                kv = per_chip((B, S, cfg.kv_lora_rank + cfg.q_rope_dim),
                              P(baxes, None, None))
            else:
                kv = 2 * per_chip((B, S, cfg.n_kv_heads,
                                   cfg.resolved_head_dim),
                                  P(baxes, None, "model", None))
            n_attn = (cfg.n_layers if cfg.family != "hybrid"
                      else cfg.n_layers // cfg.shared_attn_every)
            attn_io = n_attn * nq * kv
        traffic = rl.memory_traffic_train(param_bytes, param_bytes,
                                          opt_bytes, carry, logits, attn_io)
    elif shape.kind == "prefill":
        carry = 0.0
        logits = per_chip((B, 1, V), P(baxes, None, "model"))
        attn_io = 0.0
        if cfg.family not in ("ssm",):
            nq = max(1, S // cfg.attn_chunk_q)
            if cfg.is_mla:
                kv = per_chip((B, S, cfg.kv_lora_rank + cfg.q_rope_dim),
                              P(baxes, None, None))
            else:
                kv = 2 * per_chip((B, S, cfg.n_kv_heads,
                                   cfg.resolved_head_dim),
                                  P(baxes, None, "model", None))
            n_attn = (cfg.n_layers if cfg.family != "hybrid"
                      else cfg.n_layers // cfg.shared_attn_every)
            attn_io = n_attn * nq * kv
        traffic = param_bytes + attn_io + logits
    else:
        cache_bytes = _tree_bytes_per_chip(
            res.pop("_cache_struct"), res.pop("_cache_sh"))
        res["cache_bytes_per_chip"] = cache_bytes
        traffic = rl.memory_traffic_decode(param_bytes, cache_bytes)
    res["hbm_traffic_per_chip"] = traffic

    terms = rl.roofline_terms(hlo_flops, traffic,
                              res["collectives"]["wire_bytes_per_chip"],
                              chips)
    res["roofline"] = terms
    return res


def _opt_shardings(mesh, model, o_struct):
    """Adam moments follow the param specs (ZeRO); int8 QTensor moments
    keep the PARAM'S shape (quantized.py H3) so q/scale inherit the param
    PartitionSpec verbatim -- no resharding inside the optimiser."""
    from repro.optim.quantized import QTensor
    pspecs = model.param_specs()

    def moment_sh(spec, leaf):
        if isinstance(leaf, QTensor):
            return QTensor(
                NamedSharding(mesh, sanitize_spec(mesh, spec, leaf.q.shape)),
                NamedSharding(mesh, sanitize_spec(mesh, spec,
                                                  leaf.scale.shape)),
                leaf.shape, leaf.block)
        return NamedSharding(mesh, sanitize_spec(mesh, spec, leaf.shape))

    is_spec = lambda x: isinstance(x, P)
    m_sh = jax.tree.map(moment_sh, pspecs, o_struct.m, is_leaf=is_spec)
    v_sh = jax.tree.map(moment_sh, pspecs, o_struct.v, is_leaf=is_spec)
    return type(o_struct)(count=NamedSharding(mesh, P()), m=m_sh, v=v_sh)


def _fill_analysis(res, compiled, chips, save_hlo, tag):
    from repro.launch import hlo_analysis
    res["memory"] = _memory_analysis(compiled)
    res["cost_raw"] = _cost_analysis(compiled)   # NB: counts loop bodies once
    text = compiled.as_text()
    res["hlo_chars"] = len(text)
    mod = hlo_analysis.analyze(text)
    res["collectives"] = {"counts": mod.coll_counts,
                          "result_bytes": mod.coll_result_bytes,
                          "wire_bytes_per_chip": mod.coll_wire}
    res["dot_flops_per_chip"] = mod.dot_flops    # loop-corrected
    res["loops"] = mod.loops[:40]
    if save_hlo:
        RESULTS_DIR.mkdir(parents=True, exist_ok=True)
        with gzip.open(RESULTS_DIR / f"{tag}.hlo.gz", "wt") as f:
            f.write(text)
    res["chips"] = chips


def run_funcsne_cell(cell: str, multi_pod: bool,
                     save_hlo: bool = False) -> dict:
    """Lower + compile the distributed FUnc-SNE step at production scale."""
    mesh_name = "multi" if multi_pod else "single"
    res = {"arch": "funcsne-1m", "shape": cell, "mesh": mesh_name}
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    cfg = funcsne.FuncSNEConfig(backend="xla", **FUNCSNE_CELLS[cell])
    points_axes = batch_axes(mesh)
    step, _ = funcsne.make_distributed_step(cfg, mesh,
                                            points_axes=points_axes,
                                            feat_axis="model")
    n, m = cfg.n_points, cfg.dim_hd
    x_struct = jax.ShapeDtypeStruct(
        (n, m), jnp.float32, sharding=NamedSharding(mesh, P(None, "model")))
    repl = NamedSharding(mesh, P())
    st_struct = jax.eval_shape(
        lambda: funcsne.init_state(jax.random.PRNGKey(0),
                                   jnp.zeros((n, m), jnp.float32), cfg,
                                   init="random"))
    st_struct = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=repl),
        st_struct)
    hp_struct = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=repl),
        funcsne.default_hparams(n))

    t0 = time.time()
    lowered = step.lower(st_struct, x_struct, hp_struct)
    res["lower_s"] = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    res["compile_s"] = time.time() - t0
    res["status"] = "ok"
    _fill_analysis(res, compiled, chips, save_hlo,
                   f"funcsne-1m__{cell}__{mesh_name}")
    # analytic work per iteration: candidate dists + forces (f32 MACs)
    c_tot = cfg.c_hd + cfg.c_ld
    res["model_flops_total"] = float(
        3 * n * cfg.c_hd * m                              # HD dists
        + 3 * n * cfg.c_ld * cfg.dim_ld                   # LD dists
        + 8 * n * (cfg.k_hd + cfg.k_ld + cfg.n_negatives) * cfg.dim_ld)
    res["params_total"] = n * m
    # distances/forces are elementwise (no HLO dots): use the analytic count
    flops_per_chip = res["model_flops_total"] / chips \
        + res["dot_flops_per_chip"]
    res["model_flops_ratio"] = 1.0
    state_bytes = float(sum(
        s.size * jnp.dtype(s.dtype).itemsize
        for s in jax.tree.leaves(st_struct)))
    x_gather = 4.0 * n * cfg.c_hd * m / chips
    res["hbm_traffic_per_chip"] = 2.0 * state_bytes + x_gather
    res["state_bytes_per_chip"] = state_bytes + 4.0 * n * m / chips
    res["roofline"] = rl.roofline_terms(
        flops_per_chip, res["hbm_traffic_per_chip"],
        res["collectives"]["wire_bytes_per_chip"], chips)
    del c_tot
    return res


# --------------------------------------------------------------------------
# CLI


def all_cells():
    cells = []
    for arch in list_archs():
        for shape in SHAPES:
            cells.append((arch, shape))
    for cell in FUNCSNE_CELLS:
        cells.append(("funcsne-1m", cell))
    return cells


def run_one(arch: str, shape: str, mesh: str, *, force=False,
            save_hlo=False, overrides: dict = None, tag: str = "") -> dict:
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    out = RESULTS_DIR / f"{arch}__{shape}__{mesh}{suffix}.json"
    if out.exists() and not force:
        return json.loads(out.read_text())
    multi = mesh == "multi"
    try:
        if arch == "funcsne-1m":
            res = run_funcsne_cell(shape, multi, save_hlo)
        else:
            res = run_lm_cell(arch, shape, multi, save_hlo, overrides)
    except Exception as e:
        res = {"arch": arch, "shape": shape, "mesh": mesh,
               "status": "error", "error": repr(e),
               "traceback": traceback.format_exc()}
    out.write_text(json.dumps(res, indent=1, default=float))
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--override", action="append", default=[],
                    help="cfg field override, e.g. moe_impl=a2a")
    ap.add_argument("--tag", default="", help="result filename suffix")
    args = ap.parse_args()

    overrides = {}
    for ov in args.override:
        key, val = ov.split("=", 1)
        for cast in (int, float):
            try:
                val = cast(val)
                break
            except ValueError:
                continue
        overrides[key] = val

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        cells = all_cells()
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]
    for arch, shape in cells:
        for mesh in meshes:
            t0 = time.time()
            res = run_one(arch, shape, mesh, force=args.force,
                          save_hlo=args.save_hlo,
                          overrides=overrides or None, tag=args.tag)
            status = res.get("status")
            extra = ""
            if status == "ok":
                r = res.get("roofline", {})
                extra = (f" compute={r.get('compute_s', 0):.3e}s "
                         f"mem={r.get('memory_s', 0):.3e}s "
                         f"coll={r.get('collective_s', 0):.3e}s "
                         f"bottleneck={r.get('bottleneck')}")
            elif status == "error":
                extra = " " + res.get("error", "")[:200]
            print(f"[{time.time() - t0:7.1f}s] {arch} {shape} {mesh}: "
                  f"{status}{extra}", flush=True)


if __name__ == "__main__":
    main()

"""Post-SPMD HLO analyzer: loop-aware FLOPs and collective wire bytes.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, so any
scan-over-layers model is undercounted by the trip count (verified
empirically: an 8-step scanned matmul reports 1 matmul of flops).  This
module re-derives, from ``compiled.as_text()``:

  - dot FLOPs per computation (2 * prod(result) * prod(contracted dims)),
  - collective wire bytes per chip (ring formulas, replica-group aware),

and multiplies each computation's totals by the product of enclosing
while-loop trip counts (inferred from the loop-condition comparison
constant).  The result is the per-chip per-step cost of the partitioned
module, which feeds the roofline compute / collective terms.

Known approximations (documented in EXPERIMENTS.md):
  - elementwise/transcendental FLOPs are ignored (dots dominate),
  - conv ops are absent from our models (explicit shift-conv),
  - trip counts use the largest constant in the condition computation.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_COMP_HEADER = re.compile(r"^(?:ENTRY )?%?([\w\.\-]+)\s*\((.*)\)\s*->")
_INSTR = re.compile(r"^\s*(?:ROOT )?%([\w\.\-]+)\s*=\s*(.+?)\s*"
                    r"([a-z][a-z0-9\-]*)\(")
_PARAM_DECL = re.compile(r"%?([\w\.\-]+):\s*([^,()]+(?:\([^)]*\))?)")
_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_WHILE = re.compile(r"while\(.*?\),\s*condition=%?([\w\.\-]+),\s*"
                    r"body=%?([\w\.\-]+)")
_CALLS = re.compile(r"(?:calls|to_apply|true_computation|false_computation)"
                    r"=%?([\w\.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST = re.compile(r"constant\((\d+)\)")
_GROUPS_CURLY = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CDIMS = re.compile(r"rhs_contracting_dims=\{([0-9,]*)\}")
_LHS_CDIMS = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _first_shape(type_str: str):
    """(dtype, dims) of the first array shape in a type string."""
    m = _SHAPE.search(type_str)
    if not m:
        return None
    dims = [int(d) for d in m.group(2).split(",") if d]
    return m.group(1), dims


def _all_shapes_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class Computation:
    name: str
    shapes: Dict[str, str]                  # instr/param name -> type string
    dot_flops: float = 0.0
    coll_wire: float = 0.0
    coll_result_bytes: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    coll_counts: Dict[str, int] = dataclasses.field(default_factory=dict)
    whiles: List[tuple] = dataclasses.field(default_factory=list)
    # (cond_name, body_name)
    calls: List[str] = dataclasses.field(default_factory=list)
    max_const: int = 0                       # for trip-count inference


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if not line.startswith(" ") and ("->" in line) and line.endswith("{"):
            m = _COMP_HEADER.match(line.strip())
            if m:
                cur = Computation(name=m.group(1), shapes={})
                comps[cur.name] = cur
                for pname, ptype in _PARAM_DECL.findall(m.group(2)):
                    cur.shapes[pname] = ptype
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            continue
        mi = _INSTR.match(line)
        if not mi:
            mc = _CONST.search(line)
            if mc:
                cur.max_const = max(cur.max_const, int(mc.group(1)))
            continue
        name, type_str, op = mi.groups()
        cur.shapes[name] = type_str
        mc = _CONST.search(line)
        if mc:
            cur.max_const = max(cur.max_const, int(mc.group(1)))
        if op == "dot":
            cur.dot_flops += _dot_flops(line, type_str, cur.shapes)
        elif op in COLLECTIVES or any(
                op == c + s for c in COLLECTIVES for s in ("-start",)):
            base = op.replace("-start", "")
            if base in COLLECTIVES:
                out_b = _all_shapes_bytes(type_str)
                g = _group_size(line)
                cur.coll_counts[base] = cur.coll_counts.get(base, 0) + 1
                cur.coll_result_bytes[base] = \
                    cur.coll_result_bytes.get(base, 0.0) + out_b
                cur.coll_wire += _wire_bytes(base, out_b, g)
        elif op == "while":
            mw = _WHILE.search(line)
            if mw:
                cur.whiles.append((mw.group(1), mw.group(2)))
        elif op in ("fusion", "call", "conditional", "map"):
            for callee in _CALLS.findall(line):
                cur.calls.append(callee)
            mb = _BRANCHES.search(line)
            if mb:     # NB: all branches counted (upper bound for gated work)
                for c in mb.group(1).split(","):
                    cur.calls.append(c.strip().lstrip("%"))
    return comps


def _dot_flops(line: str, result_type: str, shapes: Dict[str, str]) -> float:
    res = _first_shape(result_type)
    if res is None:
        return 0.0
    _, rdims = res
    n_out = 1
    for d in rdims:
        n_out *= d
    # contracted size from the lhs operand shape
    args = re.search(r"\bdot\(([^)]*)\)", line)
    k = 1
    mc = _LHS_CDIMS.search(line)
    if args and mc:
        argstr = args.group(1)
        # newer XLA prints operand types inline: dot(f32[256,256]{1,0} %a,
        # ...); older text is name-only: dot(%a, %b) -> look the type up
        sh = _first_shape(argstr)
        if sh is None:
            ops = [a.strip().lstrip("%") for a in argstr.split(",")]
            lhs_type = shapes.get(ops[0]) if ops else None
            sh = _first_shape(lhs_type) if lhs_type else None
        if sh:
            for ci in [int(c) for c in mc.group(1).split(",") if c]:
                if ci < len(sh[1]):
                    k *= sh[1][ci]
    return 2.0 * n_out * k


def _group_size(line: str) -> int:
    m = _GROUPS_CURLY.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    m = _GROUPS_IOTA.search(line)
    if m:
        return int(m.group(2))      # [n_groups, group_size]
    return 2


def _wire_bytes(op: str, out_b: float, g: int) -> float:
    if op == "all-gather":
        return out_b * (g - 1) / max(g, 1)
    if op == "all-reduce":
        return 2.0 * out_b * (g - 1) / max(g, 1)
    if op == "reduce-scatter":
        return out_b * (g - 1)
    if op == "all-to-all":
        return out_b * (g - 1) / max(g, 1)
    return out_b                     # collective-permute


def module_array_shapes(text: str):
    """Every array shape appearing in the module, as {(dtype, dims)}.

    Covers parameter declarations and instruction result types of all
    computations (including fusion bodies and loop bodies), so a buffer
    that exists anywhere in the compiled module shows up.  Used by tests
    that assert a data-path rewrite really removed a materialisation
    (e.g. the scatter-fused force epilogue: no (n, K, d) per-edge force
    tensor may appear in the step's HLO).
    """
    shapes = set()
    for comp in parse_module(text).values():
        for type_str in comp.shapes.values():
            for dtype, dims in _SHAPE.findall(type_str):
                shapes.add((dtype,
                            tuple(int(d) for d in dims.split(",") if d)))
    return shapes


@dataclasses.dataclass
class ModuleCost:
    dot_flops: float
    coll_wire: float
    coll_counts: Dict[str, float]
    coll_result_bytes: Dict[str, float]
    loops: List[dict]

    def as_dict(self):
        return {"dot_flops": self.dot_flops,
                "wire_bytes_per_chip": self.coll_wire,
                "counts": self.coll_counts,
                "result_bytes": self.coll_result_bytes,
                "loops": self.loops}


def analyze(text: str, entry: str = None) -> ModuleCost:
    comps = parse_module(text)
    if entry is None:
        entry = next((c for c in comps if "main" in c), None) \
            or next(iter(comps))
    loops: List[dict] = []

    def walk(name: str, mult: float, depth: int):
        comp = comps.get(name)
        if comp is None:
            return 0.0, 0.0, {}, {}
        flops = comp.dot_flops * mult
        wire = comp.coll_wire * mult
        counts = {k: v * mult for k, v in comp.coll_counts.items()}
        rbytes = {k: v * mult for k, v in comp.coll_result_bytes.items()}
        subcalls = [(body, max(comps.get(cond, Computation("", {}))
                               .max_const, 1))
                    for cond, body in comp.whiles]
        for name_, trip in subcalls:
            loops.append({"body": name_, "trip": trip, "depth": depth})
        subcalls += [(callee, 1) for callee in comp.calls]
        for sub, trip in subcalls:
            f, w, c, rb = walk(sub, mult * trip, depth + 1)
            flops += f
            wire += w
            for k, v in c.items():
                counts[k] = counts.get(k, 0) + v
            for k, v in rb.items():
                rbytes[k] = rbytes.get(k, 0) + v
        return flops, wire, counts, rbytes

    flops, wire, counts, rbytes = walk(entry, 1.0, 0)
    return ModuleCost(flops, wire, counts, rbytes, loops)

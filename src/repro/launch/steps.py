"""Train / serve step builders shared by the trainer and the dry-run."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.launch.mesh import batch_axes
from repro.models.common import ShardCtx
from repro.models.transformer import LMModel
from repro.optim import adamw, clip_by_global_norm, warmup_cosine


def make_model(cfg: ArchConfig, mesh=None,
               global_batch: Optional[int] = None) -> LMModel:
    baxes = batch_axes(mesh) if mesh is not None else ("data",)
    model_axis = "model"
    if (cfg.pure_dp and mesh is not None and global_batch is not None
            and global_batch % mesh.size == 0):
        baxes = baxes + ("model",)     # §Perf H9: model axis as extra DP
        model_axis = None
    ctx = ShardCtx(mesh=mesh, batch=baxes, model=model_axis)
    return LMModel(cfg, ctx)


def make_optimizer(cfg: ArchConfig, *, peak_lr: float = 3e-4,
                   warmup: int = 200, total: int = 10000):
    return adamw(warmup_cosine(peak_lr, warmup, total),
                 moment_dtype=cfg.opt_state_dtype)


def make_train_step(model: LMModel, opt, *, clip_norm: float = 1.0):
    """(params, opt_state, batch{inputs,labels}) -> (params, opt_state,
    metrics).  Pure; jit/shard at the call site."""

    def train_step(params, opt_state, batch):
        def loss_fn(p):
            return model.loss_and_aux(p, batch["inputs"], batch["labels"])

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params)
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        params, opt_state = opt.update(grads, opt_state, params)
        metrics = dict(metrics)
        metrics["loss"] = loss
        metrics["grad_norm"] = gnorm
        return params, opt_state, metrics

    return train_step


def make_serve_step(model: LMModel):
    def serve_step(params, cache, inputs, cur_len):
        return model.serve_step(params, cache, inputs, cur_len)

    return serve_step


def batch_struct(cfg: ArchConfig, seq_len: int, global_batch: int):
    """ShapeDtypeStruct stand-ins for one training batch."""
    if cfg.input_mode == "tokens":
        inputs = jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32)
    else:  # modality frontend stub: precomputed frame/patch embeddings
        inputs = jax.ShapeDtypeStruct((global_batch, seq_len, cfg.d_model),
                                      jnp.bfloat16)
    labels = jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32)
    return {"inputs": inputs, "labels": labels}


def decode_structs(cfg: ArchConfig, model: LMModel, seq_len: int,
                   global_batch: int):
    """(cache, inputs, cur_len) ShapeDtypeStructs for one decode step."""
    cache = jax.eval_shape(
        functools.partial(model.init_cache, global_batch, seq_len))
    if cfg.input_mode == "tokens":
        inputs = jax.ShapeDtypeStruct((global_batch, 1), jnp.int32)
    else:
        inputs = jax.ShapeDtypeStruct((global_batch, 1, cfg.d_model),
                                      jnp.bfloat16)
    cur_len = jax.ShapeDtypeStruct((), jnp.int32)
    return cache, inputs, cur_len


def params_and_opt_structs(cfg: ArchConfig, model: LMModel, opt):
    params = jax.eval_shape(
        lambda: model.init_params(jax.random.PRNGKey(0)))
    opt_state = jax.eval_shape(lambda: opt.init(params))
    return params, opt_state

"""End-to-end LM training launcher (single host; mesh-ready).

Example (a ~160M qwen2-style model for a few hundred steps):
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --reduce \
      --steps 300 --batch 8 --seq 512

``--reduce`` shrinks the arch to a CPU/laptop-trainable size while keeping
its family topology; without it the full assigned config is built (real
hardware).  Checkpoint/restart: re-running the same command resumes from
the last committed checkpoint (see --fail-at for the injection test).
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import get_arch, smoke_variant
from repro.data.tokens import TokenStream, TokenStreamConfig
from repro.launch.steps import (make_model, make_optimizer, make_train_step)
from repro.runtime.trainer import Trainer, TrainerConfig


def reduced_variant(cfg, d_model=256, n_layers=4):
    base = smoke_variant(cfg)
    return dataclasses.replace(
        base, name=cfg.name + "-reduced", d_model=d_model,
        n_layers=max(n_layers, 2 if base.shared_attn_every == 0 else 4),
        n_heads=8, n_kv_heads=min(cfg.n_kv_heads, 4) or 4, head_dim=32,
        d_ff=4 * d_model if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 8192))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--reduce", action="store_true")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="checkpoints/train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fail-at", type=int, default=None)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduce:
        cfg = reduced_variant(cfg)
    model = make_model(cfg)
    opt = make_optimizer(cfg, peak_lr=args.lr, warmup=50, total=args.steps)
    step_fn = jax.jit(make_train_step(model, opt), donate_argnums=(0, 1))

    stream = TokenStream(TokenStreamConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch))

    def data_fn(step):
        x, y = stream.train_pair(step)
        if cfg.input_mode == "embeds":
            emb = jax.random.normal(
                jax.random.fold_in(jax.random.PRNGKey(7), step),
                (args.batch, args.seq, cfg.d_model), jnp.float32)
            return {"inputs": emb, "labels": jnp.asarray(y)}
        return {"inputs": jnp.asarray(x), "labels": jnp.asarray(y)}

    params = model.init_params(jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    n_params = sum(x.size for x in jax.tree.leaves(params)
                   if hasattr(x, "size"))
    print(f"[train] arch={cfg.name} params={n_params/1e6:.1f}M "
          f"steps={args.steps}")

    trainer = Trainer(TrainerConfig(
        total_steps=args.steps, checkpoint_every=args.ckpt_every,
        checkpoint_dir=args.ckpt_dir, fail_at_step=args.fail_at),
        step_fn, data_fn, params, opt_state)
    trainer.maybe_restore()
    history = trainer.run()
    print(f"[train] done: first loss {history[0]['loss']:.4f} "
          f"last loss {history[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()

"""Roofline-term derivation from a compiled dry-run artifact.

Terms (per EXPERIMENTS.md methodology, v5e constants):
  compute    = HLO_FLOPs / (chips * 197e12)              [s]
  memory     = HLO_bytes / (chips * 819e9)               [s]
  collective = wire_bytes_per_chip / 50e9                [s]

cost_analysis() reports whole-program FLOPs/bytes (all chips together in
SPMD, i.e. per-chip values times... XLA reports the per-module numbers of
the partitioned module, which is per-chip); we treat them as per-chip and
therefore divide the analytic MODEL_FLOPS by `chips` when comparing.

Wire bytes per chip per collective op (ring algorithms, G = group size):
  all-gather      : out * (G-1)/G
  all-reduce      : 2 * out * (G-1)/G
  reduce-scatter  : out * (G-1)          (input = out*G)
  all-to-all      : out * (G-1)/G
  collective-permute : out
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List

PEAK_FLOPS = 197e12          # bf16 / chip (v5e)
HBM_BW = 819e9               # B/s / chip
LINK_BW = 50e9               # B/s / link (ICI)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_COLL_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|([a-z0-9_\[\]{},: ]+?))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.IGNORECASE)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    result_bytes: Dict[str, int]
    wire_bytes_per_chip: float

    def as_dict(self):
        return {"counts": self.counts, "result_bytes": self.result_bytes,
                "wire_bytes_per_chip": self.wire_bytes_per_chip}


def parse_collectives(hlo_text: str) -> CollectiveStats:
    counts: Dict[str, int] = {}
    result_bytes: Dict[str, int] = {}
    wire = 0.0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group(3).lower()
        if "-done(" in line:      # async pair: count only the -start
            continue
        shape_str = m.group(1) or m.group(2) or ""
        out_b = _shape_bytes(shape_str)
        if out_b == 0:
            continue
        g = _group_size(line)
        counts[op] = counts.get(op, 0) + 1
        result_bytes[op] = result_bytes.get(op, 0) + out_b
        if op == "all-gather":
            wire += out_b * (g - 1) / max(g, 1)
        elif op == "all-reduce":
            wire += 2.0 * out_b * (g - 1) / max(g, 1)
        elif op == "reduce-scatter":
            wire += out_b * (g - 1)
        elif op == "all-to-all":
            wire += out_b * (g - 1) / max(g, 1)
        else:                      # collective-permute
            wire += out_b
    return CollectiveStats(counts, result_bytes, wire)


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))       # iota form is [n_groups, group_size]
    return 2


def roofline_terms(flops: float, bytes_accessed: float, wire_bytes: float,
                   chips: int) -> Dict[str, float]:
    """All inputs are per-chip (SPMD partitioned module) quantities."""
    compute = flops / PEAK_FLOPS
    memory = bytes_accessed / HBM_BW
    collective = wire_bytes / LINK_BW
    terms = {"compute_s": compute, "memory_s": memory,
             "collective_s": collective}
    terms["bottleneck"] = max(terms, key=lambda k: terms[k]).replace("_s", "")
    return terms


def model_flops(cfg, n_params_total: int, n_params_active: int,
                seq_len: int, global_batch: int, kind: str) -> float:
    """6*N*D for train, 2*N_active*D for decode/prefill forward-only."""
    tokens = seq_len * global_batch if kind != "decode" else global_batch
    n = n_params_active
    return (6.0 if kind == "train" else 2.0) * n * tokens


def memory_traffic_train(param_bytes: float, grad_bytes: float,
                         opt_bytes: float, carry_bytes: float,
                         logits_bytes: float, attn_io_bytes: float) -> float:
    """Per-chip HBM traffic model for one train step (lower bound).

    params are read in fwd, remat-recompute, and bwd (3x); gradients are
    written then read by the optimiser (2x); optimiser state is read and
    written (2x); remat carries are written in fwd and read in bwd (2x);
    logits are produced in fwd, recomputed, and consumed by the CE grad
    (3x); attention KV streaming reads per q-chunk (attn_io) happen in fwd
    + recompute + bwd (3x).
    """
    return (3.0 * param_bytes + 2.0 * grad_bytes + 2.0 * opt_bytes
            + 2.0 * carry_bytes + 3.0 * logits_bytes + 3.0 * attn_io_bytes)


def memory_traffic_decode(param_bytes: float, cache_bytes: float) -> float:
    """Decode reads every live parameter and the whole KV cache once."""
    return param_bytes + cache_bytes


def count_params(shapes_tree) -> int:
    import jax
    total = 0
    for leaf in jax.tree.leaves(shapes_tree):
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
    return total


def active_params(cfg, total: int) -> int:
    """Active parameters per token for MoE archs (else = total)."""
    if not cfg.is_moe:
        return total
    F = cfg.d_ff_expert or cfg.d_ff
    expert_p = cfg.n_experts * 3 * cfg.d_model * F
    n_moe_layers = cfg.n_layers - (1 if cfg.moe_dense_first else 0)
    routed_total = n_moe_layers * expert_p
    routed_active = routed_total * cfg.moe_top_k / cfg.n_experts
    return int(total - routed_total + routed_active)

"""End-to-end FUnc-SNE embedding launcher (the paper's workload).

  PYTHONPATH=src python -m repro.launch.embed --n 5000 --dataset cells \
      --alpha 1.0 --iters 1500 --dim-ld 2 --chunk 50

Runs on the scan-chunked driver: ``--chunk T`` iterations execute per
device dispatch (T=1 reproduces the per-step dispatch baseline).  A full
warmup chunk runs before the clock starts, so the reported steps/sec
excludes compile time and is the paper-style speed number.  Prints R_NX
AUC quality and (optionally) writes the embedding to .npy.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import funcsne
from repro.core.quality import embedding_quality
from repro.data import synthetic


def load_dataset(name: str, n: int, seed: int = 0):
    if name == "blobs":
        return synthetic.blobs(n=n, n_centers=8, center_std=6.0, seed=seed)
    if name == "cells":
        X, major, _ = synthetic.hierarchical_cells(n=n, seed=seed)
        return X, major
    if name == "coil":
        return synthetic.coil_rings(n_objects=max(4, n // 72),
                                    n_per_object=72, seed=seed)
    if name == "mnist-like":
        return synthetic.mnist_like(n=n, seed=seed)
    raise ValueError(name)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="cells",
                    choices=["blobs", "cells", "coil", "mnist-like"])
    ap.add_argument("--n", type=int, default=4000)
    ap.add_argument("--iters", type=int, default=1500,
                    help="rounded to a multiple of --chunk")
    ap.add_argument("--chunk", type=int, default=50,
                    help="iterations per device dispatch (1 = per-step "
                         "dispatch baseline)")
    ap.add_argument("--alpha", type=float, default=1.0)
    ap.add_argument("--perplexity", type=float, default=20.0)
    ap.add_argument("--dim-ld", type=int, default=2)
    ap.add_argument("--out", default=None)
    ap.add_argument("--devices", type=int, default=1,
                    help=">1 routes through the elastic coordinator "
                         "(repro.runtime.coordinator.fit_elastic) on a "
                         "mesh over that many devices")
    ap.add_argument("--hosts", type=int, default=1,
                    help="simulated hosts (contiguous device blocks); "
                         "per-host checkpoint shard files when "
                         "--checkpoint-dir is set")
    ap.add_argument("--model", type=int, default=1,
                    help="requested model-axis width (remesh picks the "
                         "largest feasible width <= this)")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="arm checkpoint/rollback resilience; required "
                         "to survive host loss")
    ap.add_argument("--resume", action="store_true",
                    help="resume from --checkpoint-dir via the verified "
                         "fallback chain (damaged boundaries are "
                         "skipped with a checkpoint_fallback event)")
    ap.add_argument("--audit-every", type=int, default=0,
                    help="run the chunk-boundary state auditor every N "
                         "healthy chunks (0 = off); a violation rolls "
                         "back like any health-probe trip")
    ap.add_argument("--num-processes", type=int, default=1,
                    help=">1 joins a real multi-process pod: every "
                         "process runs this command with the same "
                         "--coordinator and a distinct --process-id")
    ap.add_argument("--process-id", type=int, default=None,
                    help="this process's rank in the pod "
                         "(required when --num-processes > 1)")
    ap.add_argument("--coordinator", default=None,
                    help="host:port of process 0's distributed "
                         "coordinator (required when "
                         "--num-processes > 1)")
    args = ap.parse_args()
    if args.resume and not args.checkpoint_dir:
        ap.error("--resume requires --checkpoint-dir")

    multiprocess = args.num_processes > 1
    if multiprocess:
        if args.process_id is None or args.coordinator is None:
            ap.error("--num-processes > 1 requires --process-id "
                     "and --coordinator")
        if args.hosts != 1:
            ap.error("--hosts simulates a pod on one process; a real "
                     "multi-process pod must keep --hosts 1")
        # must run before any JAX device use: join the pod, then the
        # elastic path below spans every process's devices
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
        jax.distributed.initialize(coordinator_address=args.coordinator,
                                   num_processes=args.num_processes,
                                   process_id=args.process_id)

    X, labels = load_dataset(args.dataset, args.n)
    Xj = jnp.asarray(X, jnp.float32)
    n = X.shape[0]
    T = max(1, min(args.chunk, args.iters))
    n_chunks = max(1, args.iters // T)
    iters = n_chunks * T                 # schedule horizon == steps run
    cfg = funcsne.FuncSNEConfig(n_points=n, dim_hd=X.shape[1],
                                dim_ld=args.dim_ld)
    hp = funcsne.default_hparams(n, alpha=args.alpha,
                                 perplexity=args.perplexity)

    if args.devices > 1 or multiprocess:
        # distributed path: the elastic coordinator owns the loop
        # (mesh-reduced health probes, per-host checkpoint shards,
        # remesh-and-resume on host loss)
        from repro.core.resilience import ResiliencePolicy
        from repro.runtime.coordinator import fit_elastic
        policy = ResiliencePolicy(checkpoint_dir=args.checkpoint_dir,
                                  audit_every=args.audit_every) \
            if args.checkpoint_dir or args.audit_every else None
        if multiprocess:
            # the pod's mesh spans every process's devices; each
            # process checkpoints only its own row shard
            devices = jax.devices()
        else:
            devices = jax.devices()[:args.devices]
        first = jax.process_index() == 0
        t0 = time.time()
        st = fit_elastic(Xj, cfg=cfg, n_iter=iters, chunk_size=T,
                         hparams=hp, n_hosts=args.hosts,
                         model=args.model, devices=devices,
                         resilience=policy,
                         resume_from=args.checkpoint_dir
                         if args.resume else None)
        jax.block_until_ready(st.Y)
        dt = time.time() - t0
        Y = np.asarray(jax.device_get(st.Y))
        if first:
            q = float(embedding_quality(jnp.asarray(X), jnp.asarray(Y)))
            print(f"[embed] {args.dataset} n={n} iters={iters} chunk={T} "
                  f"devices={len(devices)} hosts={args.hosts} "
                  f"processes={args.num_processes}: {dt:.1f}s "
                  f"(compile included), R_NX AUC={q:.3f}")
            if args.out:
                np.save(args.out, Y)
                print(f"[embed] wrote {args.out}")
        return

    if args.checkpoint_dir or args.audit_every:
        # resilient single-device path: funcsne.fit owns the loop
        # (checkpoints, verified resume, rollback, optional audit)
        from repro.core.resilience import ResiliencePolicy
        policy = ResiliencePolicy(checkpoint_dir=args.checkpoint_dir,
                                  audit_every=args.audit_every)
        t0 = time.time()
        st, _ = funcsne.fit(Xj, cfg=cfg, n_iter=iters, chunk_size=T,
                            hparams=hp, resilience=policy,
                            resume_from=args.checkpoint_dir
                            if args.resume else None)
        jax.block_until_ready(st.Y)
        dt = time.time() - t0
        Y = np.asarray(jax.device_get(st.Y))
        q = float(embedding_quality(jnp.asarray(X), jnp.asarray(Y)))
        resumed = [e for e in policy.events
                   if e["kind"] == "checkpoint_fallback"]
        note = f", {len(resumed)} damaged boundary(ies) skipped" \
            if resumed else ""
        print(f"[embed] {args.dataset} n={n} iters={iters} chunk={T} "
              f"alpha={args.alpha}: {dt:.1f}s (compile included), "
              f"R_NX AUC={q:.3f}{note}")
        if args.out:
            np.save(args.out, Y)
            print(f"[embed] wrote {args.out}")
        return

    st = funcsne.init_state(jax.random.PRNGKey(0), Xj, cfg,
                            perplexity=hp.perplexity)
    chunk = funcsne.make_chunked_step(cfg, T,
                                      schedule=funcsne.default_schedule,
                                      n_iter=iters)

    # warmup chunk on a throwaway state copy (the program donates its
    # input): compile time never enters the clock below
    warm = jax.tree.map(lambda a: jnp.array(a, copy=True), st)
    warm, _, m = chunk(warm, Xj, hp)
    jax.block_until_ready(m.step)

    t0 = time.time()
    for _ in range(n_chunks):
        st, _, metrics = chunk(st, Xj, hp)
    jax.block_until_ready(st.Y)
    dt = time.time() - t0

    Y = np.asarray(jax.device_get(st.Y))
    q = float(embedding_quality(jnp.asarray(X), jnp.asarray(Y)))
    print(f"[embed] {args.dataset} n={n} iters={iters} chunk={T} "
          f"alpha={args.alpha}: {dt:.1f}s "
          f"({iters / dt:.0f} it/s, compile excluded), R_NX AUC={q:.3f}")
    if args.out:
        np.save(args.out, Y)
        print(f"[embed] wrote {args.out}")


if __name__ == "__main__":
    main()

from repro.runtime.trainer import Trainer, TrainerConfig  # noqa: F401
from repro.runtime.straggler import StepTimeMonitor  # noqa: F401

"""Fault-tolerant training driver.

Wraps the jitted train step with: periodic async checkpointing (params,
optimiser state, data cursor, RNG), crash-recovery restore on start,
step-time straggler monitoring, and an optional failure-injection hook used
by the restart test (kill at step N, relaunch, verify bit-exact data-order
resumption and loss continuity).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.checkpoint import Checkpointer
from repro.runtime.straggler import StepTimeMonitor


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int
    checkpoint_every: int = 50
    checkpoint_dir: str = "checkpoints"
    keep_last: int = 3
    log_every: int = 10
    fail_at_step: Optional[int] = None      # failure injection (tests)


class SimulatedFailure(RuntimeError):
    pass


class Trainer:
    def __init__(self, cfg: TrainerConfig, step_fn: Callable,
                 data_fn: Callable[[int], Dict[str, Any]],
                 params, opt_state, logger: Callable[[str], None] = print):
        """step_fn(params, opt_state, batch) -> (params, opt_state, metrics);
        data_fn(step) -> batch (deterministic per step for exact restart)."""
        self.cfg = cfg
        self.step_fn = step_fn
        self.data_fn = data_fn
        self.params = params
        self.opt_state = opt_state
        self.log = logger
        self.ckpt = Checkpointer(cfg.checkpoint_dir, keep_last=cfg.keep_last)
        self.monitor = StepTimeMonitor()
        self.start_step = 0
        self.history: list = []

    # -- recovery ---------------------------------------------------------

    def maybe_restore(self, shardings=None):
        step = self.ckpt.latest_step()
        if step is None:
            return False
        tree = {"params": self.params, "opt": self.opt_state}
        tree, meta = self.ckpt.restore(tree, step=step, shardings=shardings)
        self.params, self.opt_state = tree["params"], tree["opt"]
        self.start_step = meta["step"]
        self.log(f"[trainer] restored checkpoint at step {self.start_step}")
        return True

    # -- main loop ---------------------------------------------------------

    def run(self):
        cfg = self.cfg
        for step in range(self.start_step, cfg.total_steps):
            if cfg.fail_at_step is not None and step == cfg.fail_at_step:
                # crash BEFORE the step commits, like a real preemption
                self.ckpt.wait()
                raise SimulatedFailure(f"injected failure at step {step}")
            batch = self.data_fn(step)
            t0 = time.time()
            self.params, self.opt_state, metrics = self.step_fn(
                self.params, self.opt_state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.time() - t0
            alarm = self.monitor.observe(dt)
            if alarm:
                self.log(f"[trainer][step {step}] {alarm}; snapshotting")
                self._checkpoint(step)
            loss = float(metrics["loss"])
            self.history.append({"step": step, "loss": loss, "sec": dt})
            if step % cfg.log_every == 0:
                self.log(f"[trainer] step {step} loss {loss:.4f} "
                         f"({dt * 1e3:.0f} ms)")
            if (step + 1) % cfg.checkpoint_every == 0:
                self._checkpoint(step + 1)
        self.ckpt.wait()
        return self.history

    def _checkpoint(self, step: int):
        self.ckpt.save(step, {"params": self.params, "opt": self.opt_state},
                       metadata={"step": step})

"""Multi-process elastic control plane: supervisor + disposable workers.

``repro.runtime.coordinator.fit_elastic`` survives a *simulated* host
loss inside one process.  This module makes the real thing survivable --
a worker process SIGKILLed mid-run -- by splitting the runtime in two:

  **supervisor** (this module's :class:`Supervisor`; long-lived,
  JAX-free -- it never initialises a JAX runtime, so nothing about a
  generation's death can wedge it): spawns the workers, watches their
  liveness, and on a failure kills the whole generation and relaunches
  it over the survivors;

  **workers** (one per pod; disposable, one *generation* at a time):
  each runs ``fit_elastic`` under ``jax.distributed.initialize`` with
  gloo CPU collectives.  Workers are disposable because a survivor
  CANNOT re-initialise ``jax.distributed`` in-process after a peer dies
  (jaxlib aborts the process); recovery is therefore always
  kill-the-generation + relaunch, and every generation gets a fresh
  coordinator port (``base + generation``) so a lingering socket from
  the dead generation can never collide.

Liveness is the observer-stamped beat-counter contract of
``repro.runtime.elastic``: each worker bumps a counter in its per-pod
heartbeat file at every chunk boundary (``fit_elastic(on_boundary=)``);
the supervisor stamps counter *changes* with its OWN
``time.monotonic()`` and feeds the records to
``elastic.surviving_pods``.  Wall clocks are never compared across
processes -- a pod with a skewed clock is exactly as alive as its
counter progress says.  A worker process that *exits* abnormally is the
fast path of the same signal (its counter can never change again), so
the supervisor reports it as ``heartbeat_lost`` with
``via="process_exit"`` instead of waiting out the timeout.

On a detected death the supervisor:

  1. logs ``heartbeat_lost`` for every dead/stale pod and snapshots the
     survivors (fresh AND alive at detection time);
  2. SIGKILLs and reaps every remaining worker of the generation
     (``generation_killed``) -- survivors are blocked in a collective
     with a dead peer and cannot make progress anyway;
  3. re-forms the pod over the survivors (``remesh``) and relaunches a
     new generation on a fresh coordinator port; the workers
     ``restore_verified()`` from the last committed chunk boundary.
     Checkpoint shards are generation-tagged
     (``shardNNN-of-MMM-gGGGGGG.npz``), so anything the dead generation
     left half-staged is evicted by the new generation's completing
     writer instead of merging into a boundary.

Every control-plane event (and, via ``ResiliencePolicy.on_event``,
every worker runtime event) is appended as one JSON line to
``<workdir>/events.jsonl`` -- the structured trail
``heartbeat_lost -> generation_killed -> remesh -> restore`` that the
``process_kill`` smoke scenario asserts.

CLI::

    # supervised 2-process run (the supervisor spawns the workers)
    PYTHONPATH=src python -m repro.runtime.control \
        --workdir /tmp/run --pods 2 --n-iter 200 --chunk-size 25

    # one worker (normally spawned by the supervisor, not by hand)
    PYTHONPATH=src python -m repro.runtime.control --worker \
        --workdir /tmp/run --pod 0 --process-id 0 --num-processes 2 \
        --coordinator 127.0.0.1:29618 --generation 0 ...

``python -m repro.launch.embed --num-processes N --process-id I
--coordinator H:P`` is the manual (no-supervisor) multi-process launch
of the same worker loop.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import socket
import subprocess
import sys
import time
from pathlib import Path
from typing import Dict, List, Optional

from repro.runtime import elastic

DEFAULT_BASE_PORT = 29618


class SupervisorError(RuntimeError):
    """The control plane gave up: no survivors, nothing committed to
    resume from, the generation budget is exhausted, or the total
    deadline passed.  Carries the structured event trail."""

    def __init__(self, reason: str, events: List[dict]):
        super().__init__(reason)
        self.reason = reason
        self.events = events


def gloo_available() -> bool:
    """True when this jaxlib exposes CPU cross-process collectives.

    Feature-detected through the PUBLIC config API -- ``jax.config
    .update`` raises for unknown option names -- never through private
    registries a jax refactor can silently rename (``hasattr(jax.config,
    ...)`` is additionally a false negative for config knobs).  The
    probe re-writes the current value, so it never changes the probing
    process's behaviour.  Imports jax lazily: the supervisor itself must
    stay JAX-runtime-free."""
    try:
        import jax
        prev = jax.config.read("jax_cpu_collectives_implementation")
        jax.config.update("jax_cpu_collectives_implementation", prev)
        return True
    except Exception:
        return False


def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def _append_event(path: Path, event: dict) -> None:
    # one line per event, single write: concurrent appends from the
    # supervisor and every worker interleave whole lines on Linux
    with open(path, "a") as f:
        f.write(json.dumps(event) + "\n")


def _read_events(path: Path) -> List[dict]:
    if not path.exists():
        return []
    out = []
    for line in path.read_text().splitlines():
        try:
            out.append(json.loads(line))
        except ValueError:      # torn tail line from a killed writer
            continue
    return out


def _write_json_atomic(path: Path, obj: dict) -> None:
    tmp = path.with_suffix(path.suffix + ".tmp")
    tmp.write_text(json.dumps(obj))
    os.replace(tmp, path)


def committed_steps(ckpt_dir: Path) -> List[int]:
    """Committed boundary steps, oldest first -- pure directory listing
    (the supervisor's JAX-free stand-in for ``Checkpointer.all_steps``)."""
    return sorted(int(p.name.split("_")[1])
                  for p in Path(ckpt_dir).glob("step_*")
                  if (p / "meta.json").exists())


# --------------------------------------------------------------------------
# Worker side


def _beat_writer(hb_dir: Path, pod: int, generation: int):
    """Returns ``beat(it)``: atomically publish one heartbeat tick.

    The counter is worker-local and monotone within the generation; the
    observer treats ``(generation, counter)`` as an opaque value and
    stamps *changes* with its own clock, so the absolute numbers (and
    this process's wall clock, which is never written) do not matter."""
    path = hb_dir / f"pod{pod}.beat"
    state = {"k": 0}

    def beat(it: int) -> None:
        state["k"] += 1
        _write_json_atomic(path, {
            "pod": pod, "generation": generation,
            "counter": state["k"], "step": int(it)})
    return beat


def worker_main(args) -> int:
    """One disposable worker: ``jax.distributed`` init, then
    ``fit_elastic`` with heartbeats, generation-tagged checkpoint
    shards, and resume-from-last-committed when anything is committed."""
    workdir = Path(args.workdir)
    hb_dir = workdir / "hb"
    ckpt_dir = workdir / "ckpt"
    events_path = workdir / "events.jsonl"
    for d in (hb_dir, ckpt_dir):
        d.mkdir(parents=True, exist_ok=True)

    def log_event(event: dict) -> None:
        _append_event(events_path, {
            **event, "src": "worker", "pod": args.pod,
            "generation": args.generation, "pid": os.getpid()})

    beat = _beat_writer(hb_dir, args.pod, args.generation)
    beat(-1)            # publish before runtime init: the file exists
    #                     and the first counter change marks progress

    import jax
    jax.config.update("jax_cpu_collectives_implementation", "gloo")
    jax.distributed.initialize(coordinator_address=args.coordinator,
                               num_processes=args.num_processes,
                               process_id=args.process_id)
    import jax.numpy as jnp

    from repro.core import funcsne
    from repro.core.resilience import ResiliencePolicy
    from repro.data.synthetic import blobs
    from repro.runtime import faults
    from repro.runtime.coordinator import fit_elastic

    log_event({"kind": "worker_start",
               "process_id": args.process_id,
               "num_processes": args.num_processes,
               "coordinator": args.coordinator,
               "devices": jax.device_count()})

    X, _ = blobs(n=args.n, dim=args.dim, n_centers=2, center_std=5.0,
                 seed=args.seed)
    Xj = jnp.asarray(X, jnp.float32)
    cfg = funcsne.FuncSNEConfig(n_points=args.n, dim_hd=args.dim,
                                backend=args.backend, n_negatives=4)
    policy = ResiliencePolicy(checkpoint_dir=str(ckpt_dir),
                              checkpoint_every=1,
                              keep_last=args.keep_last,
                              on_event=log_event)
    resume = ckpt_dir if committed_steps(ckpt_dir) else None

    def on_boundary(it: int) -> None:
        beat(it)
        faults.maybe_process_kill(it, args.pod)

    script = None
    if args.kill_pod is not None:
        script = faults.FaultScript(
            faults.ProcessKill(at_chunk=args.kill_at_chunk,
                               pod=args.kill_pod))
    import contextlib
    with (faults.active(script) if script is not None
          else contextlib.nullcontext()):
        st = fit_elastic(Xj, cfg=cfg, n_iter=args.n_iter,
                         chunk_size=args.chunk_size, model=args.model,
                         resilience=policy, resume_from=resume,
                         on_boundary=on_boundary,
                         generation=args.generation)

    import numpy as np
    Y = np.asarray(jax.device_get(st.Y))
    final = {"step": int(st.step), "n_iter": args.n_iter,
             "generation": args.generation,
             "finite": bool(np.isfinite(Y).all()),
             "y_std": float(Y.std())}
    log_event({"kind": "worker_done", **final})
    if args.process_id == 0:
        _write_json_atomic(workdir / "result.json", final)
    return 0


# --------------------------------------------------------------------------
# Supervisor side


@dataclasses.dataclass
class _Worker:
    pod: int
    proc: subprocess.Popen
    log_path: Path


class Supervisor:
    """Spawns and babysits worker generations (see module docstring).

    ``heartbeat_timeout`` is the steady-state staleness bound; it only
    takes over from ``startup_grace`` once a pod publishes a beat from
    PAST the resume boundary -- i.e. after runtime init and the
    first-chunk compile, the slow part every relaunch repeats.  (Stale
    beat files are swept before each generation launches, so leftover
    counters can never fake that progress.)  A pod whose beat file
    never appears at all is judged against ``startup_grace`` measured
    from the generation's spawn.  ``kill_pod``/``kill_at_chunk`` arm
    the deterministic
    :class:`repro.runtime.faults.ProcessKill` injector in generation 0
    only -- the smoke-test hook for a real SIGKILL mid-run.
    """

    def __init__(self, workdir, *, n_pods: int = 2, n_iter: int = 16,
                 chunk_size: int = 4, n: int = 64, dim: int = 6,
                 seed: int = 0, backend: str = "interpret",
                 model: int = 1, keep_last: int = 3,
                 base_port: Optional[int] = None,
                 heartbeat_timeout: float = 15.0,
                 startup_grace: float = 300.0,
                 poll_interval: float = 0.1,
                 max_generations: Optional[int] = None,
                 total_timeout: Optional[float] = None,
                 kill_pod: Optional[int] = None,
                 kill_at_chunk: Optional[int] = None,
                 extra_env: Optional[Dict[str, str]] = None,
                 echo: bool = False):
        self.workdir = Path(workdir)
        self.hb_dir = self.workdir / "hb"
        self.ckpt_dir = self.workdir / "ckpt"
        self.log_dir = self.workdir / "logs"
        self.events_path = self.workdir / "events.jsonl"
        for d in (self.hb_dir, self.ckpt_dir, self.log_dir):
            d.mkdir(parents=True, exist_ok=True)
        self.n_pods = int(n_pods)
        self.n_iter = int(n_iter)
        self.chunk_size = int(chunk_size)
        self.n, self.dim, self.seed = int(n), int(dim), int(seed)
        self.backend, self.model = backend, int(model)
        self.keep_last = int(keep_last)
        self.base_port = _free_port() if base_port is None \
            else int(base_port)
        self.heartbeat_timeout = float(heartbeat_timeout)
        self.startup_grace = float(startup_grace)
        self.poll_interval = float(poll_interval)
        self.max_generations = (self.n_pods + 1 if max_generations is None
                                else int(max_generations))
        self.total_timeout = total_timeout
        self.kill_pod, self.kill_at_chunk = kill_pod, kill_at_chunk
        self.extra_env = dict(extra_env or {})
        self.echo = echo
        self.events: List[dict] = []
        self.all_pids: List[int] = []
        self._live: List[_Worker] = []

    # -- telemetry --------------------------------------------------------

    def log(self, kind: str, **info) -> dict:
        event = {"kind": kind, **info, "src": "supervisor"}
        self.events.append(event)
        _append_event(self.events_path, event)
        if self.echo:
            print(f"[control] {kind}: "
                  f"{ {k: v for k, v in info.items()} }", flush=True)
        return event

    # -- process management ----------------------------------------------

    def _worker_argv(self, gen: int, pods: List[int], idx: int,
                     port: int) -> List[str]:
        pod = pods[idx]
        argv = [sys.executable, "-m", "repro.runtime.control", "--worker",
                "--workdir", str(self.workdir),
                "--pod", str(pod), "--process-id", str(idx),
                "--num-processes", str(len(pods)),
                "--coordinator", f"127.0.0.1:{port}",
                "--generation", str(gen),
                "--n-iter", str(self.n_iter),
                "--chunk-size", str(self.chunk_size),
                "--n", str(self.n), "--dim", str(self.dim),
                "--seed", str(self.seed), "--backend", self.backend,
                "--model", str(self.model),
                "--keep-last", str(self.keep_last)]
        if gen == 0 and self.kill_pod is not None:
            argv += ["--kill-pod", str(self.kill_pod),
                     "--kill-at-chunk", str(self.kill_at_chunk or 0)]
        return argv

    def _spawn_generation(self, gen: int, pods: List[int]) -> List[_Worker]:
        port = self.base_port + gen
        # a fresh generation must not inherit beat files: a stale file
        # from the previous generation makes the new worker's very
        # first write read as "progress", silently swapping
        # startup_grace for the steady-state timeout while the worker
        # is still in jax.distributed init + first-chunk compile.  The
        # previous generation is killed AND reaped before we get here,
        # so no writer can race this sweep.
        self._clear_beats()
        env = dict(os.environ)
        # workers must resolve `repro` exactly as the supervisor did
        # (repro is a namespace package: derive src from __path__)
        import repro
        src = os.path.dirname(list(repro.__path__)[0])
        env["PYTHONPATH"] = src + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
        env.update(self.extra_env)
        workers = []
        for idx in range(len(pods)):
            log_path = self.log_dir / f"gen{gen}-pod{pods[idx]}.log"
            with open(log_path, "ab") as lf:
                proc = subprocess.Popen(
                    self._worker_argv(gen, pods, idx, port),
                    stdout=lf, stderr=subprocess.STDOUT, env=env)
            workers.append(_Worker(pods[idx], proc, log_path))
            self.all_pids.append(proc.pid)
        self._live = workers
        self.log("generation_start", generation=gen, pods=list(pods),
                 n_processes=len(pods), port=port,
                 pids=[w.proc.pid for w in workers])
        return workers

    def _kill_generation(self, workers: List[_Worker],
                         generation: int) -> None:
        killed = []
        for w in workers:
            if w.proc.poll() is None:
                try:
                    w.proc.kill()
                except OSError:
                    pass
                killed.append(w.pod)
            w.proc.wait()           # reap: no zombies, no orphans
        self._live = []
        self.log("generation_killed", generation=generation,
                 killed_pods=killed)

    # -- the watch loop ---------------------------------------------------

    def _clear_beats(self) -> None:
        for f in self.hb_dir.glob("pod*.beat*"):    # incl. .tmp strays
            try:
                f.unlink()
            except OSError:     # pragma: no cover
                pass

    def _read_beat(self, pod: int):
        """``((generation, counter), step)`` from the pod's beat file,
        or None while it is absent/torn."""
        path = self.hb_dir / f"pod{pod}.beat"
        try:
            b = json.loads(path.read_text())
            return (b.get("generation"), b.get("counter")), b.get("step")
        except (OSError, ValueError):
            return None

    def _watch(self, gen: int, workers: List[_Worker], deadline):
        """Poll heartbeats + child exits until the generation finishes
        ("done") or a pod dies ("failed", survivors)."""
        obs = elastic.HeartbeatObserver()
        finished, dead = set(), {}
        spawn_t = time.monotonic()
        # startup_grace holds until the FIRST post-entry boundary beat.
        # Workers beat once before runtime init and once on entering the
        # chunk loop (both BEFORE the first-chunk compile), so counter
        # changes alone cannot prove the slow part is over; only a beat
        # whose step is PAST the resume point does.  entry_step is the
        # boundary this generation resumes from (0 for a fresh run):
        # the entry beat carries exactly it, the first committed chunk
        # boundary carries more.
        entry_step = max([0] + committed_steps(self.ckpt_dir))
        started = set()
        while True:
            if deadline is not None and time.monotonic() > deadline:
                raise SupervisorError(
                    f"total_timeout={self.total_timeout}s exceeded in "
                    f"generation {gen}", self._trail())
            now = time.monotonic()
            for w in workers:
                if w.pod in finished or w.pod in dead:
                    continue
                rec = self._read_beat(w.pod)
                if rec is not None:
                    counter, step = rec
                    obs.observe(w.pod, counter, now)
                    if counter[0] == gen and step is not None \
                            and step > entry_step:
                        started.add(w.pod)
                rc = w.proc.poll()
                if rc is None:
                    continue
                if rc == 0:
                    finished.add(w.pod)
                else:
                    dead[w.pod] = rc
            if len(finished) == len(workers):
                return "done", []
            # per-pod staleness: startup grace until the first post-
            # entry boundary beat (init + first compile are behind it),
            # steady-state bound after.  A pod that never published a
            # beat file at all is judged against the grace measured
            # from generation spawn -- it must not escape detection.
            stale = []
            for w in workers:
                if w.pod in finished or w.pod in dead:
                    continue
                b = obs.beats.get(w.pod)
                if b is None:
                    if now - spawn_t > self.startup_grace:
                        stale.append(w.pod)
                    continue
                timeout = self.heartbeat_timeout if w.pod in started \
                    else self.startup_grace
                if w.pod not in \
                        elastic.surviving_pods({w.pod: b}, timeout, now):
                    stale.append(w.pod)
            if dead or stale:
                for pod, rc in sorted(dead.items()):
                    sig = -rc if rc < 0 else None
                    self.log("heartbeat_lost", generation=gen, pod=pod,
                             via="process_exit", returncode=rc,
                             signal=sig)
                for pod in stale:
                    b = obs.beats.get(pod)
                    last = b.stamped if b is not None else spawn_t
                    self.log("heartbeat_lost", generation=gen, pod=pod,
                             via="timeout",
                             stale_s=round(now - last, 3))
                survivors = [w.pod for w in workers
                             if w.pod not in dead and w.pod not in stale]
                self._kill_generation(workers, gen)
                return "failed", survivors
            time.sleep(self.poll_interval)

    def _trail(self) -> List[dict]:
        return _read_events(self.events_path)

    # -- entry point ------------------------------------------------------

    def run(self) -> dict:
        """Drive worker generations to completion; returns the report
        dict (result, trail, pids).  Raises :class:`SupervisorError`
        when recovery is impossible."""
        deadline = None if self.total_timeout is None \
            else time.monotonic() + self.total_timeout
        pods = list(range(self.n_pods))
        gen = 0
        try:
            while True:
                if gen >= self.max_generations:
                    raise SupervisorError(
                        f"generation budget exhausted "
                        f"({self.max_generations})", self._trail())
                workers = self._spawn_generation(gen, pods)
                outcome, survivors = self._watch(gen, workers, deadline)
                if outcome == "done":
                    result_path = self.workdir / "result.json"
                    if not result_path.exists():
                        raise SupervisorError(
                            f"generation {gen} exited 0 without a "
                            f"result", self._trail())
                    result = json.loads(result_path.read_text())
                    self.log("run_done", generation=gen,
                             step=result.get("step"))
                    return {"ok": True, "generations": gen + 1,
                            "result": result, "pids": self.all_pids,
                            "checkpoint_dir": str(self.ckpt_dir),
                            "trail": self._trail()}
                if not survivors:
                    raise SupervisorError(
                        f"generation {gen}: no surviving pods",
                        self._trail())
                if not committed_steps(self.ckpt_dir):
                    raise SupervisorError(
                        f"generation {gen} died before any boundary "
                        f"committed: nothing to resume from",
                        self._trail())
                gen += 1
                self.log("remesh", generation=gen, survivors=survivors,
                         n_processes=len(survivors),
                         port=self.base_port + gen,
                         resume_step=committed_steps(self.ckpt_dir)[-1])
                pods = survivors
        finally:
            # no orphans on ANY exit path (including SupervisorError
            # and KeyboardInterrupt): kill + reap whatever still runs
            for w in self._live:
                if w.proc.poll() is None:
                    try:
                        w.proc.kill()
                    except OSError:
                        pass
                w.proc.wait()
            self._live = []


def run_supervised(workdir, **kw) -> dict:
    """One-call form of :class:`Supervisor` -- see its docstring."""
    return Supervisor(workdir, **kw).run()


# --------------------------------------------------------------------------
# CLI


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.runtime.control",
        description="supervisor/worker control plane for multi-process "
                    "elastic embedding runs")
    ap.add_argument("--workdir", required=True,
                    help="run directory (heartbeats, checkpoints, "
                         "events.jsonl, worker logs)")
    ap.add_argument("--worker", action="store_true",
                    help="run ONE worker process (normally only the "
                         "supervisor passes this)")
    # shared workload spec
    ap.add_argument("--n-iter", type=int, default=16)
    ap.add_argument("--chunk-size", type=int, default=4)
    ap.add_argument("--n", type=int, default=64)
    ap.add_argument("--dim", type=int, default=6)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", default="interpret",
                    choices=["interpret", "xla", "pallas"])
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--keep-last", type=int, default=3)
    # supervisor knobs
    ap.add_argument("--pods", type=int, default=2)
    ap.add_argument("--base-port", type=int, default=None)
    ap.add_argument("--heartbeat-timeout", type=float, default=15.0)
    ap.add_argument("--startup-grace", type=float, default=300.0)
    ap.add_argument("--max-generations", type=int, default=None)
    ap.add_argument("--total-timeout", type=float, default=None)
    ap.add_argument("--kill-pod", type=int, default=None,
                    help="test hook: arm faults.ProcessKill in this pod "
                         "(generation 0)")
    ap.add_argument("--kill-at-chunk", type=int, default=None)
    # worker identity (supervisor-provided)
    ap.add_argument("--pod", type=int, default=0)
    ap.add_argument("--process-id", type=int, default=0)
    ap.add_argument("--num-processes", type=int, default=1)
    ap.add_argument("--coordinator", default=None)
    ap.add_argument("--generation", type=int, default=0)
    args = ap.parse_args(argv)

    if args.worker:
        if args.coordinator is None:
            ap.error("--worker requires --coordinator")
        return worker_main(args)

    sup = Supervisor(args.workdir, n_pods=args.pods, n_iter=args.n_iter,
                     chunk_size=args.chunk_size, n=args.n, dim=args.dim,
                     seed=args.seed, backend=args.backend,
                     model=args.model, keep_last=args.keep_last,
                     base_port=args.base_port,
                     heartbeat_timeout=args.heartbeat_timeout,
                     startup_grace=args.startup_grace,
                     max_generations=args.max_generations,
                     total_timeout=args.total_timeout,
                     kill_pod=args.kill_pod,
                     kill_at_chunk=args.kill_at_chunk, echo=True)
    try:
        report = sup.run()
    except SupervisorError as e:
        print(f"[control] FAILED: {e}", file=sys.stderr)
        return 1
    r = report["result"]
    print(f"[control] done: step={r['step']}/{r['n_iter']} after "
          f"{report['generations']} generation(s), "
          f"finite={r['finite']}, y_std={r['y_std']:.3f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

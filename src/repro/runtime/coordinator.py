"""Elastic multi-host coordinator for the resilient embedding runtime.

``funcsne.fit`` survives faults a *single* process can survive: NaN
chunks roll back, kernel failures demote, preemption resumes in a fresh
process.  A pod adds the failure mode none of those cover -- a host (its
whole block of devices) drops out while the survivors keep running.
:func:`fit_elastic` is the host-side loop for that case:

  1. drive the chunked distributed program (``make_distributed_step``
     with ``chunk=T``) under the same rollback / backoff / checkpoint
     policy as ``fit`` -- the health telemetry is mesh-reduced inside
     the scan, so one bad shard trips the global rollback;
  2. every checkpoint is written as per-host shard files
     (``Checkpointer.save(host_shard_filter=...)``), so checkpoint I/O
     scales with the pod instead of funnelling through one host;
  3. on a host loss (``faults.HostLost`` here; a heartbeat timeout in a
     real deployment) the survivors quiesce (the in-flight checkpoint
     write lands), ``elastic.remesh`` re-forms the mesh over the
     remaining devices, the last committed chunk boundary is restored
     ONTO THE SHRUNKEN MESH (``Checkpointer.restore(shardings=new)``)
     and the schedule replays from the carried step.

Chunk boundaries are bit-neutral, so no iteration is lost or repeated
across the remesh; the replayed steps differ from the uninterrupted
run only by the collective reduction grouping of the smaller mesh
(fp32-level, quality-neutral -- pinned in tests/test_elastic_resume.py).

The loop runs in two deployment shapes:

  * **simulated pod** (default, one Python process): hosts are
    contiguous device blocks, loss is an injected ``faults.HostLost``,
    and the HostLost handler below remeshes in-process -- the CI-sized
    harness every elastic test drives;
  * **real multi-process pod** (``jax.process_count() > 1``, i.e. the
    caller ran ``jax.distributed.initialize``): every process executes
    this same loop SPMD, each writes ONLY its own generation-tagged
    checkpoint shard (``host_id=jax.process_index()``), and liveness is
    proven through the ``on_boundary`` heartbeat hook.  A real process
    death is NOT handled here -- a survivor cannot re-initialise
    ``jax.distributed`` in-process after a peer dies (jaxlib aborts), so
    the supervisor in ``repro.runtime.control`` kills the whole worker
    generation and relaunches it over the survivors on a fresh
    coordinator port; the relaunched generation re-enters this function
    with ``resume_from=`` pointing at the last committed boundary.
"""
from __future__ import annotations

import contextlib
import time
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core import funcsne
from repro.core.resilience import EmbeddingDiverged
from repro.kernels import fallback
from repro.launch.mesh import host_device_blocks
from repro.runtime import elastic, faults


def fit_elastic(X, *, cfg: "funcsne.FuncSNEConfig" = None,
                n_iter: int = 750, chunk_size: int = None, rng=None,
                hparams: "funcsne.HParams" = None,
                schedule: Callable = None, init: str = "pca",
                n_hosts: int = 1, model: int = 1,
                devices: Optional[Sequence] = None,
                resilience=None, state=None, resume_from=None,
                on_boundary: Optional[Callable[[int], None]] = None,
                generation: Optional[int] = None):
    """``funcsne.fit``'s rollback/checkpoint loop on a device mesh, with
    elastic resume across simulated host loss.  Returns the final
    :class:`~repro.core.funcsne.FuncSNEState` (replicated on the
    surviving mesh).

    ``n_hosts`` partitions ``devices`` (default: all of
    ``jax.devices()``) into contiguous blocks -- the simulated pod.
    ``model`` is the requested tensor-parallel width; the actual mesh is
    whatever :func:`repro.runtime.elastic.remesh` finds feasible for the
    surviving device count (``cfg.dim_hd`` must stay divisible by the
    model axis because ``X`` is feature-sharded), so a remesh after a
    loss may shrink the model axis rather than drop devices.

    A :class:`~repro.runtime.faults.HostLost` raised at a chunk boundary
    is survivable only when ``resilience.checkpoint_dir`` is set and at
    least one boundary committed; otherwise it propagates (there is
    nothing to resume from).

    ``on_boundary(it)`` is called after every committed chunk boundary
    (and once at entry with the starting step): the liveness hook the
    multi-process control plane uses to bump the pod's heartbeat
    counter.  It must be cheap and must not raise.

    Under ``jax.distributed`` (``jax.process_count() > 1``) every
    process runs this loop SPMD over the global device set; checkpoint
    writes automatically switch to one generation-tagged shard per
    process (``generation`` defaults to 0 there) and the process-local
    straggler alarm only logs -- an early checkpoint decided by one
    process's clock would stage an incomplete shard set.  ``n_hosts``
    must stay 1 in that mode (the real process set IS the pod).
    """
    Xh = jnp.asarray(X, jnp.float32)
    if rng is None:
        rng = jax.random.PRNGKey(0)
    if cfg is None:
        cfg = funcsne.FuncSNEConfig(n_points=Xh.shape[0],
                                    dim_hd=Xh.shape[1])
    if hparams is None:
        hparams = funcsne.default_hparams(cfg.n_points)
    if schedule is None:
        schedule = funcsne.default_schedule
    if chunk_size is None:
        chunk_size = min(50, max(1, n_iter))
    devices = list(jax.devices() if devices is None else devices)
    if not 1 <= n_hosts <= len(devices):
        raise ValueError(f"n_hosts={n_hosts} for {len(devices)} devices")
    n_procs = jax.process_count()
    multiprocess = n_procs > 1
    if multiprocess:
        if n_hosts != 1:
            raise ValueError(
                "n_hosts simulates pods in single-process mode; under "
                "jax.distributed the process set IS the pod (n_hosts=1)")
        if generation is None:
            generation = 0
    beat = on_boundary if on_boundary is not None else (lambda _it: None)

    policy = resilience
    log = policy.log if policy is not None else (lambda *a, **k: None)
    on_mesh_event = (lambda e: policy.log(**e)) if policy is not None \
        else None
    ck = monitor = None
    if policy is not None:
        if policy.checkpoint_dir is not None:
            from repro.checkpoint import Checkpointer
            ck = Checkpointer(policy.checkpoint_dir,
                              keep_last=policy.keep_last)
        from repro.runtime.straggler import StepTimeMonitor
        monitor = StepTimeMonitor(z_thresh=policy.straggler_z,
                                  hang_timeout=policy.hang_timeout,
                                  warmup_steps=policy.straggler_warmup)
    from repro.checkpoint import row_shard_filter

    def build(devs):
        """(mesh, sharded X, replicated sharding) over the survivors."""
        mesh = elastic.remesh(len(devs), model=model, devices=devs,
                              divides=(cfg.dim_hd,),
                              on_event=on_mesh_event)
        Xs = jax.device_put(Xh, NamedSharding(mesh, P(None, "model")))
        return mesh, Xs, NamedSharding(mesh, P())

    mesh, Xs, repl = build(devices)

    if state is not None:
        st = state
    else:
        st = funcsne.init_state(rng, Xh, cfg, init=init,
                                perplexity=hparams.perplexity,
                                validate=False)
    from repro.checkpoint import cfg_compat

    def restore_chain(rck, like):
        """Fallback-chain restore onto the CURRENT mesh, logging one
        ``checkpoint_fallback`` event per damaged boundary skipped."""
        tree, meta, fbs = rck.restore_verified(
            like, shardings=jax.tree.map(lambda _: repl, like),
            expect_compat=cfg_compat(cfg))
        for fb in fbs:
            log("checkpoint_fallback", **fb)
        return tree, meta

    start_it = 0
    lr_scale = ex_scale = 1.0
    if resume_from is not None:
        from repro.checkpoint import Checkpointer
        rck = ck if (ck is not None
                     and str(ck.dir) == str(resume_from)) else \
            Checkpointer(resume_from)
        tree, meta = restore_chain(rck, st)
        st = tree
        start_it = int(meta["step"])
        lr_scale = float(meta.get("lr_scale", 1.0))
        ex_scale = float(meta.get("ex_scale", 1.0))
        log("restore", step=start_it, source=str(resume_from),
            from_generation=meta.get("generation"))
    st = jax.device_put(st, repl)

    def save_all_hosts(it, st, blocking=False):
        meta = {"lr_scale": lr_scale, "ex_scale": ex_scale,
                "compat": cfg_compat(cfg)}
        if multiprocess:
            # real pod: THIS process writes only its own generation-
            # tagged row shard; whichever process completes the set
            # commits the merged step dir (and evicts any stale shards
            # a dead generation left staged)
            ck.save(it, st, metadata=meta, blocking=blocking,
                    host_shard_filter=row_shard_filter(
                        jax.process_index(), n_procs, cfg.n_points),
                    host_id=jax.process_index(), n_hosts=n_procs,
                    generation=generation)
            return
        # one save() per simulated host: each writes only its row slice
        # (+ host 0 the replicated leaves); the completing write commits
        # the merged step dir.  save() joins the previous write first,
        # so the per-host writes serialise the way distinct hosts would
        # proceed independently.
        if n_hosts == 1:
            ck.save(it, st, metadata=meta, blocking=blocking,
                    generation=generation)
            return
        for h in range(n_hosts):
            ck.save(it, st, metadata=meta,
                    host_shard_filter=row_shard_filter(
                        h, n_hosts, cfg.n_points),
                    host_id=h, n_hosts=n_hosts, generation=generation)
        if blocking:
            ck.wait()

    chunks = {}         # T -> compiled program for the CURRENT mesh
    it = start_it
    retries = 0
    n_healthy = 0
    fb_seen = fallback.n_events()
    guard = fallback.enabled(policy.sticky_fallback) \
        if policy is not None else contextlib.nullcontext()
    with contextlib.ExitStack() as stack:
        stack.enter_context(guard)
        if ck is not None:
            stack.callback(ck.close)    # flush on every exit path
        beat(it)    # entry beat: the pod is alive before first compile
        while it < n_iter:
            T = min(chunk_size, n_iter - it)
            if T not in chunks:
                chunks[T], _ = funcsne.make_distributed_step(
                    cfg, mesh, chunk=T, schedule=schedule, n_iter=n_iter)
            hp_run = funcsne._scaled_hp(hparams, lr_scale, ex_scale)
            if policy is not None or faults.current() is not None:
                # donated input: dispatch a copy, keep `st` as the
                # rollback anchor (scripted faults poison the copy)
                st_in = faults.corrupt_state(funcsne._copy_state(st), it)
            else:
                st_in = st
            t0 = time.time()
            st_out, _, metrics = chunks[T](st_in, Xs, hp_run)
            alarm = None
            if policy is not None:
                m = jax.device_get(metrics)   # the one host sync
                alarm = monitor.observe(time.time() - t0)
                if alarm is not None:
                    log("straggler", step=it, alarm=alarm)
                for e in fallback.events(fb_seen):
                    log(**e)
                fb_seen = fallback.n_events()
                reason = policy.check(m)
                if reason is None and policy.audit_every \
                        and (n_healthy + 1) % policy.audit_every == 0:
                    # chunk-boundary invariant audit (index corruption
                    # is invisible to the finite-fraction probes); the
                    # reductions AllReduce across the mesh, so one bad
                    # replica trips the global rollback
                    aud = jax.device_get(
                        funcsne.audit_state(st_out, cfg, Xs))
                    reason = policy.audit_check(aud)
                    if reason is not None:
                        log("audit_violation", step=it, reason=reason)
                if reason is not None:
                    if retries >= policy.max_retries:
                        log("giving_up", step=it, reason=reason,
                            retries=retries)
                        raise EmbeddingDiverged(it, reason, retries,
                                                policy.events)
                    retries += 1
                    lr_scale *= policy.lr_backoff
                    ex_scale *= policy.exaggeration_backoff
                    log("rollback", step=it, reason=reason,
                        retry=retries, lr_scale=lr_scale,
                        ex_scale=ex_scale)
                    beat(it)    # a retry storm is alive, not dead
                    continue
                retries = 0
            st = st_out
            it += T
            if policy is not None:
                n_healthy += 1
                if ck is not None:
                    saved = n_healthy % policy.checkpoint_every == 0
                    if saved:
                        save_all_hosts(it, st)
                    if alarm is not None and not multiprocess:
                        # hang/straggler escalation: commit this
                        # boundary now so a kill loses at most one chunk.
                        # Multi-process pods skip this: the alarm is
                        # decided by ONE process's clock, and a shard
                        # set only some processes stage never commits
                        # (the straggler event above still logs).
                        if saved:
                            ck.wait()
                        else:
                            save_all_hosts(it, st, blocking=True)
                        log("early_checkpoint", step=it, alarm=alarm)
            beat(it)
            faults.maybe_corrupt_checkpoint(it, ck)
            faults.maybe_preempt(it)
            try:
                faults.maybe_host_loss(it)
            except faults.HostLost as e:
                if ck is None or ck.latest_step() is None:
                    raise   # nothing committed: the run is not resumable
                log("host_lost", step=e.step, host=e.host)
                ck.wait()   # quiesce: the in-flight write is the truth
                blocks = host_device_blocks(devices, n_hosts)
                lost = blocks[e.host % n_hosts]
                devices = [d for d in devices if d not in lost]
                n_hosts = max(1, n_hosts - 1)
                mesh, Xs, repl = build(devices)
                chunks.clear()          # old programs pin the old mesh
                # fallback-chain restore: the newest boundary may be the
                # one the lost host's write tore -- degrade to the last
                # verified one instead of materialising garbage
                tree, meta = restore_chain(ck, st)
                st = tree
                it = int(meta["step"])
                lr_scale = float(meta.get("lr_scale", 1.0))
                ex_scale = float(meta.get("ex_scale", 1.0))
                retries = 0
                log("remesh", step=it, host_lost=e.host,
                    n_devices=len(devices), n_hosts=n_hosts,
                    mesh=dict(mesh.shape))
        if ck is not None:
            ck.wait()   # surface async write failures before returning
    return st

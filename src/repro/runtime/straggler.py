"""Straggler / hang detection from per-step wall times.

At pod scale the scheduler cannot see inside an SPMD step; what it CAN see
is the host-side step time.  StepTimeMonitor keeps an EWMA + variance of
step durations and raises an alarm when a step exceeds
``mean + z_thresh * std`` (slow host / flaky ICI link / preempted worker)
or an absolute ``hang_timeout``.  ``funcsne.fit`` and
``coordinator.fit_elastic`` respond by committing the current chunk
boundary early -- a blocking checkpoint save (or a join of the in-flight
one), logged as an ``early_checkpoint`` event -- so a subsequent kill
loses at most one chunk; at real scale the same signal drives the
hot-spare remesh in ``repro.runtime.elastic``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional


@dataclasses.dataclass
class StepTimeMonitor:
    decay: float = 0.95
    z_thresh: float = 4.0
    hang_timeout: float = 600.0
    warmup_steps: int = 5

    _mean: float = 0.0
    _var: float = 0.0
    _count: int = 0

    def observe(self, seconds: float) -> Optional[str]:
        """Record one step; returns an alarm string or None."""
        self._count += 1
        if self._count <= self.warmup_steps:
            # seed statistics; never alarm during compile/warmup steps
            w = 1.0 / self._count
            self._mean = (1 - w) * self._mean + w * seconds
            self._var = max(self._var, (seconds - self._mean) ** 2)
            return None
        alarm = None
        std = math.sqrt(self._var)
        if seconds > self.hang_timeout:
            alarm = f"hang: step took {seconds:.1f}s > {self.hang_timeout}s"
        elif seconds > self._mean + self.z_thresh * max(std, 0.05 * self._mean):
            alarm = (f"straggler: step {seconds * 1e3:.0f}ms vs "
                     f"mean {self._mean * 1e3:.0f}ms (z>{self.z_thresh})")
        self._mean = self.decay * self._mean + (1 - self.decay) * seconds
        self._var = self.decay * self._var \
            + (1 - self.decay) * (seconds - self._mean) ** 2
        return alarm

    @property
    def mean(self) -> float:
        return self._mean

"""Deterministic fault injection for the resilient embedding runtime.

Every recovery path in ``funcsne.fit``'s resilience layer is exercised by
*scripted* faults rather than by hoping a real TPU misbehaves on cue:

  :class:`NaNChunk`          corrupts the state handed to one chunk
                             dispatch (the rollback copy stays clean), so
                             the in-scan health telemetry sees a chunk
                             whose optimisation blew up mid-flight;
  :class:`KernelLaunchFault` raises inside the guarded Pallas launch of
                             one kernel family (``repro.kernels.fallback``
                             consults this module right before calling the
                             Pallas builder), driving the sticky
                             demote-to-XLA path;
  :class:`Preemption`        raises :class:`Preempted` at a chunk
                             boundary -- the SIGTERM-between-dispatches
                             case; a subsequent ``fit(resume_from=dir)``
                             must reproduce the uninterrupted run
                             bit-for-bit.

Faults are one-shot by default (``fired`` latches), so a rolled-back
retry of the same steps does not re-trip: the script models a transient
fault, which is exactly what rollback-and-retry is for.  Persistent
faults (``once=False``) model real divergence and exhaust the retry
budget instead.

Usage::

    script = FaultScript(NaNChunk(at_step=40))
    with faults.active(script):
        st, _ = funcsne.fit(X, resilience=ResiliencePolicy(), ...)

``python -m repro.runtime.faults --smoke`` runs the three recovery
scenarios end-to-end on tiny data with the kernels in interpret mode --
the CI gate that keeps every path green in minutes.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import List, Optional

_SENTINEL_NOT_ACTIVE = None


class Preempted(RuntimeError):
    """Simulated preemption: the run was killed between chunk dispatches."""

    def __init__(self, step: int):
        super().__init__(f"simulated preemption at step {step}")
        self.step = step


class InjectedKernelFault(RuntimeError):
    """Raised in place of a Pallas launch by :class:`KernelLaunchFault`."""


@dataclasses.dataclass
class NaNChunk:
    """Poison the state entering the first chunk whose start step is
    ``>= at_step``: the first ``rows`` rows of ``Y`` become NaN, as if the
    optimiser diverged mid-chunk.  The caller's rollback copy (taken
    before injection) stays clean, so rollback + retry recovers."""
    at_step: int
    rows: int = 8
    once: bool = True
    fired: bool = False

    def apply(self, st, it: int):
        if (self.fired and self.once) or it < self.at_step:
            return st
        self.fired = True
        import jax.numpy as jnp
        rows = min(self.rows, st.Y.shape[0])
        return st._replace(Y=st.Y.at[:rows].set(jnp.nan))


@dataclasses.dataclass
class KernelLaunchFault:
    """Raise :class:`InjectedKernelFault` in place of the ``at_launch``-th
    guarded Pallas launch of ``family`` (see ``repro.kernels.fallback``)."""
    family: str
    at_launch: int = 0
    once: bool = True
    fired: bool = False
    _count: int = 0

    def check(self, family: str):
        if family != self.family or (self.fired and self.once):
            return
        launch, self._count = self._count, self._count + 1
        if launch >= self.at_launch:
            self.fired = True
            raise InjectedKernelFault(
                f"injected launch failure: {self.family} "
                f"(launch {launch})")


@dataclasses.dataclass
class Preemption:
    """Raise :class:`Preempted` at the first chunk boundary ``>= at_step``
    -- AFTER the state advanced past the chunk, like a kill signal landing
    between dispatches."""
    at_step: int
    once: bool = True
    fired: bool = False

    def check(self, it: int):
        if (self.fired and self.once) or it < self.at_step:
            return
        self.fired = True
        raise Preempted(it)


class FaultScript:
    """An ordered bag of fault objects consulted by the runtime hooks."""

    def __init__(self, *faults):
        self.faults: List = list(faults)

    def corrupt_state(self, st, it: int):
        for f in self.faults:
            if isinstance(f, NaNChunk):
                st = f.apply(st, it)
        return st

    def maybe_preempt(self, it: int):
        for f in self.faults:
            if isinstance(f, Preemption):
                f.check(it)

    def check_kernel(self, family: str):
        for f in self.faults:
            if isinstance(f, KernelLaunchFault):
                f.check(family)


_ACTIVE: Optional[FaultScript] = _SENTINEL_NOT_ACTIVE


@contextlib.contextmanager
def active(script: FaultScript):
    """Install ``script`` as the process-wide fault source."""
    global _ACTIVE
    prev, _ACTIVE = _ACTIVE, script
    try:
        yield script
    finally:
        _ACTIVE = prev


def current() -> Optional[FaultScript]:
    return _ACTIVE


# -- hooks the runtime calls (all no-ops when no script is active) ---------


def corrupt_state(st, it: int):
    return _ACTIVE.corrupt_state(st, it) if _ACTIVE is not None else st


def maybe_preempt(it: int):
    if _ACTIVE is not None:
        _ACTIVE.maybe_preempt(it)


def check_kernel(family: str):
    if _ACTIVE is not None:
        _ACTIVE.check_kernel(family)


# --------------------------------------------------------------------------
# Smoke scenarios: the CI gate (`python -m repro.runtime.faults --smoke`)


def _smoke_setup(n=64, dim=6, backend="interpret", seed=0):
    import jax.numpy as jnp

    from repro.core import funcsne
    from repro.data.synthetic import blobs

    X, _ = blobs(n=n, dim=dim, n_centers=2, center_std=5.0, seed=seed)
    cfg = funcsne.FuncSNEConfig(n_points=n, dim_hd=dim, backend=backend,
                                n_negatives=4)
    return jnp.asarray(X), cfg


def scenario_nan_rollback(backend="interpret") -> dict:
    """Injected NaN chunk -> telemetry trip -> rollback + backoff ->
    finite final embedding."""
    import jax.numpy as jnp

    from repro.core import funcsne
    from repro.core.resilience import ResiliencePolicy

    X, cfg = _smoke_setup(backend=backend)
    policy = ResiliencePolicy(max_retries=2)
    with active(FaultScript(NaNChunk(at_step=8))):
        st, _ = funcsne.fit(X, cfg=cfg, n_iter=16, chunk_size=4,
                            resilience=policy)
    assert bool(jnp.isfinite(st.Y).all()), "embedding not finite"
    kinds = [e["kind"] for e in policy.events]
    assert "rollback" in kinds, kinds
    assert int(st.step) == 16, int(st.step)
    return {"events": len(policy.events), "retries": kinds.count("rollback")}


def scenario_kernel_fallback(backend="interpret") -> dict:
    """Injected Pallas launch failure -> sticky XLA demotion -> run
    completes, bit-identical to a run with the family pre-demoted."""
    import numpy as np

    from repro.core import funcsne
    from repro.core.resilience import ResiliencePolicy
    from repro.kernels import fallback

    X, cfg = _smoke_setup(backend=backend)

    fallback.reset()
    with active(FaultScript(KernelLaunchFault("knn_merge"))):
        policy = ResiliencePolicy()
        st_fault, _ = funcsne.fit(X, cfg=cfg, n_iter=8, chunk_size=4,
                                  resilience=policy)
    assert "knn_merge" in fallback.demotions(), fallback.demotions()

    fallback.reset()
    fallback.demote("knn_merge", "pre-demoted (smoke parity reference)")
    with fallback.enabled():
        st_ref, _ = funcsne.fit(X, cfg=cfg, n_iter=8, chunk_size=4,
                                resilience=ResiliencePolicy())
    fallback.reset()
    np.testing.assert_array_equal(np.asarray(st_fault.Y),
                                  np.asarray(st_ref.Y))
    return {"demoted": ["knn_merge"]}


def scenario_preempt_resume(backend="interpret", tmpdir=None) -> dict:
    """Kill between chunks, restore from disk: resumed run bit-identical
    to the uninterrupted one."""
    import tempfile

    import numpy as np

    from repro.core import funcsne
    from repro.core.resilience import ResiliencePolicy

    X, cfg = _smoke_setup(backend=backend)
    if tmpdir is None:
        tmpdir = tempfile.mkdtemp(prefix="funcsne-faults-")
    kw = dict(cfg=cfg, n_iter=16, chunk_size=4)

    st_ref, _ = funcsne.fit(X, resilience=ResiliencePolicy(), **kw)

    policy = ResiliencePolicy(checkpoint_dir=tmpdir, checkpoint_every=1)
    try:
        with active(FaultScript(Preemption(at_step=8))):
            funcsne.fit(X, resilience=policy, **kw)
        raise AssertionError("preemption did not fire")
    except Preempted as e:
        killed_at = e.step
    st_res, _ = funcsne.fit(X, resilience=ResiliencePolicy(
        checkpoint_dir=tmpdir, checkpoint_every=1),
        resume_from=tmpdir, **kw)
    np.testing.assert_array_equal(np.asarray(st_res.Y),
                                  np.asarray(st_ref.Y))
    assert int(st_res.step) == 16
    return {"killed_at": killed_at}


SCENARIOS = {
    "nan_rollback": scenario_nan_rollback,
    "kernel_fallback": scenario_kernel_fallback,
    "preempt_resume": scenario_preempt_resume,
}


def main() -> int:
    import argparse
    import time

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="run all recovery scenarios on tiny data")
    ap.add_argument("--backend", default="interpret",
                    choices=["interpret", "xla", "pallas"])
    ap.add_argument("--only", default=None,
                    help="comma-separated scenario names")
    args = ap.parse_args()
    names = list(SCENARIOS)
    if args.only:
        names = [n for n in names if n in args.only.split(",")]
    failed = 0
    for name in names:
        t0 = time.time()
        try:
            info = SCENARIOS[name](backend=args.backend)
            print(f"[faults] {name}: OK in {time.time() - t0:.1f}s {info}",
                  flush=True)
        except Exception as e:  # pragma: no cover - CI failure surface
            failed += 1
            print(f"[faults] {name}: FAILED: {e!r}", flush=True)
    return 1 if failed else 0


if __name__ == "__main__":
    # re-dispatch through the canonical import so the scenarios share the
    # one _ACTIVE cell funcsne.fit consults (running under `python -m`
    # loads this file as `__main__`, a *second* module object)
    from repro.runtime import faults as _canonical
    raise SystemExit(_canonical.main())

"""Deterministic fault injection for the resilient embedding runtime.

Every recovery path in ``funcsne.fit``'s resilience layer is exercised by
*scripted* faults rather than by hoping a real TPU misbehaves on cue:

  :class:`NaNChunk`          corrupts the state handed to one chunk
                             dispatch (the rollback copy stays clean), so
                             the in-scan health telemetry sees a chunk
                             whose optimisation blew up mid-flight;
  :class:`KernelLaunchFault` raises inside the guarded Pallas launch of
                             one kernel family (``repro.kernels.fallback``
                             consults this module right before calling the
                             Pallas builder), driving the sticky
                             demote-to-XLA path;
  :class:`Preemption`        raises :class:`Preempted` at a chunk
                             boundary -- the SIGTERM-between-dispatches
                             case; a subsequent ``fit(resume_from=dir)``
                             must reproduce the uninterrupted run
                             bit-for-bit.
  :class:`HostLoss`          raises :class:`HostLost` at a chunk
                             boundary -- one simulated host (its block
                             of devices) drops out of the pod; the
                             elastic coordinator
                             (``repro.runtime.coordinator.fit_elastic``)
                             quiesces the survivors, re-forms the mesh
                             over the remaining devices and resumes
                             from the last committed chunk boundary.
  :class:`ProcessKill`       SIGKILLs the worker process itself at a
                             chunk boundary -- the REAL death
                             :class:`HostLoss` only simulates; nothing
                             in-process survives it, so the test
                             payload is the supervisor/worker control
                             plane (``repro.runtime.control``): the
                             supervisor must detect the lost heartbeat,
                             kill the generation, re-form the pod over
                             the survivors and relaunch from the last
                             committed generation-tagged checkpoint.
  :class:`CorruptShard`      damages the newest COMMITTED checkpoint on
                             disk (truncate / bit-flip / delete one
                             shard file) at a chunk boundary -- the
                             torn-write / bad-disk case; the verified
                             restore chain must detect it and fall back
                             to the previous intact boundary.
  :class:`IndexCorruption`   poisons a state index table (``hd_idx`` /
                             ``rev_idx``) with out-of-range but
                             perfectly FINITE values -- corruption the
                             NaN health probes cannot see; only the
                             chunk-boundary state auditor
                             (``funcsne.audit_state`` via
                             ``ResiliencePolicy(audit_every=)``) trips.

Faults are one-shot by default (``fired`` latches), so a rolled-back
retry of the same steps does not re-trip: the script models a transient
fault, which is exactly what rollback-and-retry is for.  Persistent
faults (``once=False``) model real divergence and exhaust the retry
budget instead.

Usage::

    script = FaultScript(NaNChunk(at_step=40))
    with faults.active(script):
        st, _ = funcsne.fit(X, resilience=ResiliencePolicy(), ...)

``python -m repro.runtime.faults --smoke`` runs every recovery scenario
end-to-end on tiny data with the kernels in interpret mode -- the CI
gate that keeps every path green in minutes.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import List, Optional

_SENTINEL_NOT_ACTIVE = None


class Preempted(RuntimeError):
    """Simulated preemption: the run was killed between chunk dispatches."""

    def __init__(self, step: int):
        super().__init__(f"simulated preemption at step {step}")
        self.step = step


class InjectedKernelFault(RuntimeError):
    """Raised in place of a Pallas launch by :class:`KernelLaunchFault`."""


class HostLost(RuntimeError):
    """Simulated host loss: one host's devices dropped out of the mesh."""

    def __init__(self, step: int, host: int):
        super().__init__(f"simulated loss of host {host} at step {step}")
        self.step = step
        self.host = host


def _poison_one_replica(arr, shard: int, rows: int, value=None):
    """Rebuild a *replicated* mesh array with poison written into ONE
    device's buffer only -- rows ``[shard*n_loc, shard*n_loc+rows)`` of
    device ``shard``'s replica (its own row slice in the phase
    decomposition).  ``value=None`` writes NaN (float corruption);
    an int ``value`` poisons integer index tables.  This models a
    device-local corruption (bad HBM row, miscompiled kernel on one
    core): the replication invariant is broken but every collective
    still runs, which is exactly the fault a shard-blind health probe
    commits silently."""
    import numpy as np

    import jax

    sharding = arr.sharding
    mesh = getattr(sharding, "mesh", None)
    if mesh is None or mesh.devices.size < 2:
        raise ValueError(
            "per-shard poisoning needs a state replicated over a >=2 "
            "device mesh (NamedSharding); got " + repr(sharding))
    devs = list(mesh.devices.flat)
    if not (0 <= shard < len(devs)):
        raise ValueError(f"shard {shard} out of range for {len(devs)} "
                         f"devices")
    host = np.asarray(arr)
    n_loc = max(1, host.shape[0] // len(devs))
    lo = shard * n_loc
    bad = host.copy()
    bad[lo:lo + min(rows, n_loc)] = np.nan if value is None else value
    bufs = [jax.device_put(bad if i == shard else host, d)
            for i, d in enumerate(devs)]
    return jax.make_array_from_single_device_arrays(
        arr.shape, sharding, bufs)


@dataclasses.dataclass
class NaNChunk:
    """Poison the state entering the first chunk whose start step is
    ``>= at_step``: the first ``rows`` rows of ``field`` become NaN, as
    if the optimiser diverged mid-chunk.  The caller's rollback copy
    (taken before injection) stays clean, so rollback + retry recovers.

    ``shard=None`` (default) poisons the logical state -- every replica
    sees it.  ``shard=s`` poisons ONLY device ``s``'s replica (rows of
    that shard's own slice), breaking the replication invariant the way
    a device-local fault does; combined with ``field='vel'`` the NaN
    reaches that device's copy of ``Y`` through the purely local
    momentum update -- no collective touches it within the step -- so a
    shard-blind probe that reads shard 0's telemetry misses it entirely
    while the mesh-reduced probe trips.  (Poisoning ``Y`` directly
    propagates to every replica through the force psum within one step,
    which is why the shard-confined scenario pairs with ``vel``.)"""
    at_step: int
    rows: int = 8
    once: bool = True
    fired: bool = False
    shard: Optional[int] = None
    field: str = "Y"

    def apply(self, st, it: int):
        if (self.fired and self.once) or it < self.at_step:
            return st
        self.fired = True
        arr = getattr(st, self.field)
        if self.shard is None:
            import jax.numpy as jnp
            rows = min(self.rows, arr.shape[0])
            arr = arr.at[:rows].set(jnp.nan)
        else:
            arr = _poison_one_replica(arr, self.shard, self.rows)
        return st._replace(**{self.field: arr})


@dataclasses.dataclass
class IndexCorruption:
    """Poison an index table of the state entering the first chunk whose
    start step is ``>= at_step``: the first ``rows`` rows of ``field``
    (``hd_idx`` / ``ld_idx`` / ``rev_idx``) are overwritten with an
    out-of-range but perfectly FINITE value (``n + 12345`` -- in-range
    for int32, below the SENTINEL).  The finite-fraction / max-|Y|
    health probes cannot see it (nothing is NaN and the embedding drifts
    only slowly), which is exactly the corruption class
    ``funcsne.audit_state`` exists for.  ``shard=s`` confines the poison
    to device ``s``'s replica on a mesh (the audit reductions AllReduce,
    so the mesh-global audit still trips)."""
    at_step: int
    field: str = "hd_idx"
    rows: int = 8
    once: bool = True
    fired: bool = False
    shard: Optional[int] = None

    def apply(self, st, it: int):
        if (self.fired and self.once) or it < self.at_step:
            return st
        self.fired = True
        arr = getattr(st, self.field)
        bad_val = st.active.shape[0] + 12345
        if self.shard is None:
            rows = min(self.rows, arr.shape[0])
            arr = arr.at[:rows].set(bad_val)
        else:
            arr = _poison_one_replica(arr, self.shard, self.rows,
                                      value=bad_val)
        return st._replace(**{self.field: arr})


@dataclasses.dataclass
class CorruptShard:
    """Damage the NEWEST committed checkpoint on disk at the first chunk
    boundary ``>= at_step`` -- after the in-flight write lands, so the
    damage hits a fully committed step the way a torn write, a flipped
    bit in cold storage or a lost object does.  ``shard`` indexes the
    sorted ``shard*-of-*.npz`` set (default -1: the last shard;
    single-host checkpoints damage ``arrays.npz``).  ``damaged`` records
    the file actually hit, for assertions."""
    at_step: int
    mode: str = "bitflip"       # "truncate" | "bitflip" | "delete"
    shard: int = -1
    once: bool = True
    fired: bool = False
    damaged: Optional[str] = None

    def check(self, it: int, ck):
        if ck is None or (self.fired and self.once) or it < self.at_step:
            return
        ck.wait()       # the in-flight write must COMMIT before damage:
        #                 this models corruption of a good checkpoint,
        #                 not a crash mid-write (the tmp-dir rename
        #                 already covers that)
        step = ck.latest_step()
        if step is None:
            return
        self.fired = True
        d = ck.dir / f"step_{step:010d}"
        files = sorted(d.glob("shard*-of-*.npz")) or [d / "arrays.npz"]
        target = files[self.shard % len(files)]
        if self.mode == "delete":
            target.unlink()
        elif self.mode == "truncate":
            blob = target.read_bytes()
            target.write_bytes(blob[:max(1, len(blob) // 2)])
        elif self.mode == "bitflip":
            blob = bytearray(target.read_bytes())
            blob[len(blob) // 2] ^= 0x01
            target.write_bytes(bytes(blob))
        else:
            raise ValueError(f"unknown CorruptShard mode {self.mode!r}")
        self.damaged = str(target)


@dataclasses.dataclass
class KernelLaunchFault:
    """Raise :class:`InjectedKernelFault` in place of the ``at_launch``-th
    guarded Pallas launch of ``family`` (see ``repro.kernels.fallback``)."""
    family: str
    at_launch: int = 0
    once: bool = True
    fired: bool = False
    _count: int = 0

    def check(self, family: str):
        if family != self.family or (self.fired and self.once):
            return
        launch, self._count = self._count, self._count + 1
        if launch >= self.at_launch:
            self.fired = True
            raise InjectedKernelFault(
                f"injected launch failure: {self.family} "
                f"(launch {launch})")


@dataclasses.dataclass
class Preemption:
    """Raise :class:`Preempted` at the first chunk boundary ``>= at_step``
    -- AFTER the state advanced past the chunk, like a kill signal landing
    between dispatches."""
    at_step: int
    once: bool = True
    fired: bool = False

    def check(self, it: int):
        if (self.fired and self.once) or it < self.at_step:
            return
        self.fired = True
        raise Preempted(it)


@dataclasses.dataclass
class ProcessKill:
    """SIGKILL THIS process at the first chunk boundary ``>= at_chunk``,
    iff it is running as pod ``pod`` -- the real-death analogue of
    :class:`HostLoss`.  ``os.kill(getpid(), SIGKILL)`` is deliberate:
    no atexit, no flushes, no JAX teardown, exactly what ``kill -9`` on
    a worker looks like.  The in-process runtime cannot survive this by
    construction; recovery is the supervisor's job
    (``repro.runtime.control``: kill the generation, re-form the pod
    over the survivors, relaunch from the last committed boundary).
    Checked from the worker's ``on_boundary`` hook via
    :func:`maybe_process_kill` -- after the boundary's checkpoint save
    has been *dispatched*, so the kill races a possibly-in-flight write
    the way a real signal does (generation-tagged shards make the torn
    leftovers harmless)."""
    at_chunk: int
    pod: int = 1
    once: bool = True
    fired: bool = False

    def check(self, it: int, pod: int):
        if pod != self.pod or (self.fired and self.once) \
                or it < self.at_chunk:
            return
        self.fired = True
        import os
        import signal
        os.kill(os.getpid(), signal.SIGKILL)


@dataclasses.dataclass
class HostLoss:
    """Raise :class:`HostLost` at the first chunk boundary ``>= at_step``:
    simulated death of host ``host`` (its whole device block).  Unlike
    :class:`Preemption` the process survives -- the elastic coordinator
    catches it, drops the host's devices, remeshes and resumes from the
    last committed checkpoint on the shrunken mesh."""
    at_step: int
    host: int = 1
    once: bool = True
    fired: bool = False

    def check(self, it: int):
        if (self.fired and self.once) or it < self.at_step:
            return
        self.fired = True
        raise HostLost(it, self.host)


class FaultScript:
    """An ordered bag of fault objects consulted by the runtime hooks."""

    def __init__(self, *faults):
        self.faults: List = list(faults)

    def corrupt_state(self, st, it: int):
        for f in self.faults:
            if isinstance(f, (NaNChunk, IndexCorruption)):
                st = f.apply(st, it)
        return st

    def maybe_preempt(self, it: int):
        for f in self.faults:
            if isinstance(f, Preemption):
                f.check(it)

    def maybe_corrupt_checkpoint(self, it: int, ck):
        for f in self.faults:
            if isinstance(f, CorruptShard):
                f.check(it, ck)

    def maybe_host_loss(self, it: int):
        for f in self.faults:
            if isinstance(f, HostLoss):
                f.check(it)

    def maybe_process_kill(self, it: int, pod: int):
        for f in self.faults:
            if isinstance(f, ProcessKill):
                f.check(it, pod)

    def check_kernel(self, family: str):
        for f in self.faults:
            if isinstance(f, KernelLaunchFault):
                f.check(family)


_ACTIVE: Optional[FaultScript] = _SENTINEL_NOT_ACTIVE


@contextlib.contextmanager
def active(script: FaultScript):
    """Install ``script`` as the process-wide fault source."""
    global _ACTIVE
    prev, _ACTIVE = _ACTIVE, script
    try:
        yield script
    finally:
        _ACTIVE = prev


def current() -> Optional[FaultScript]:
    return _ACTIVE


# -- hooks the runtime calls (all no-ops when no script is active) ---------


def corrupt_state(st, it: int):
    return _ACTIVE.corrupt_state(st, it) if _ACTIVE is not None else st


def maybe_preempt(it: int):
    if _ACTIVE is not None:
        _ACTIVE.maybe_preempt(it)


def maybe_corrupt_checkpoint(it: int, ck):
    if _ACTIVE is not None and ck is not None:
        _ACTIVE.maybe_corrupt_checkpoint(it, ck)


def maybe_host_loss(it: int):
    if _ACTIVE is not None:
        _ACTIVE.maybe_host_loss(it)


def maybe_process_kill(it: int, pod: int):
    if _ACTIVE is not None:
        _ACTIVE.maybe_process_kill(it, pod)


def check_kernel(family: str):
    if _ACTIVE is not None:
        _ACTIVE.check_kernel(family)


# --------------------------------------------------------------------------
# Smoke scenarios: the CI gate (`python -m repro.runtime.faults --smoke`)


def _smoke_setup(n=64, dim=6, backend="interpret", seed=0):
    import jax.numpy as jnp

    from repro.core import funcsne
    from repro.data.synthetic import blobs

    X, _ = blobs(n=n, dim=dim, n_centers=2, center_std=5.0, seed=seed)
    cfg = funcsne.FuncSNEConfig(n_points=n, dim_hd=dim, backend=backend,
                                n_negatives=4)
    return jnp.asarray(X), cfg


def scenario_nan_rollback(backend="interpret") -> dict:
    """Injected NaN chunk -> telemetry trip -> rollback + backoff ->
    finite final embedding."""
    import jax.numpy as jnp

    from repro.core import funcsne
    from repro.core.resilience import ResiliencePolicy

    X, cfg = _smoke_setup(backend=backend)
    policy = ResiliencePolicy(max_retries=2)
    with active(FaultScript(NaNChunk(at_step=8))):
        st, _ = funcsne.fit(X, cfg=cfg, n_iter=16, chunk_size=4,
                            resilience=policy)
    assert bool(jnp.isfinite(st.Y).all()), "embedding not finite"
    kinds = [e["kind"] for e in policy.events]
    assert "rollback" in kinds, kinds
    assert int(st.step) == 16, int(st.step)
    return {"events": len(policy.events), "retries": kinds.count("rollback")}


def scenario_kernel_fallback(backend="interpret") -> dict:
    """Injected Pallas launch failure -> sticky XLA demotion -> run
    completes, bit-identical to a run with the family pre-demoted."""
    import numpy as np

    from repro.core import funcsne
    from repro.core.resilience import ResiliencePolicy
    from repro.kernels import fallback

    X, cfg = _smoke_setup(backend=backend)

    fallback.reset()
    with active(FaultScript(KernelLaunchFault("knn_merge"))):
        policy = ResiliencePolicy()
        st_fault, _ = funcsne.fit(X, cfg=cfg, n_iter=8, chunk_size=4,
                                  resilience=policy)
    assert "knn_merge" in fallback.demotions(), fallback.demotions()

    fallback.reset()
    fallback.demote("knn_merge", "pre-demoted (smoke parity reference)")
    with fallback.enabled():
        st_ref, _ = funcsne.fit(X, cfg=cfg, n_iter=8, chunk_size=4,
                                resilience=ResiliencePolicy())
    fallback.reset()
    np.testing.assert_array_equal(np.asarray(st_fault.Y),
                                  np.asarray(st_ref.Y))
    return {"demoted": ["knn_merge"]}


def scenario_preempt_resume(backend="interpret", tmpdir=None) -> dict:
    """Kill between chunks, restore from disk: resumed run bit-identical
    to the uninterrupted one."""
    import tempfile

    import numpy as np

    from repro.core import funcsne
    from repro.core.resilience import ResiliencePolicy

    X, cfg = _smoke_setup(backend=backend)
    if tmpdir is None:
        tmpdir = tempfile.mkdtemp(prefix="funcsne-faults-")
    kw = dict(cfg=cfg, n_iter=16, chunk_size=4)

    st_ref, _ = funcsne.fit(X, resilience=ResiliencePolicy(), **kw)

    policy = ResiliencePolicy(checkpoint_dir=tmpdir, checkpoint_every=1)
    try:
        with active(FaultScript(Preemption(at_step=8))):
            funcsne.fit(X, resilience=policy, **kw)
        raise AssertionError("preemption did not fire")
    except Preempted as e:
        killed_at = e.step
    st_res, _ = funcsne.fit(X, resilience=ResiliencePolicy(
        checkpoint_dir=tmpdir, checkpoint_every=1),
        resume_from=tmpdir, **kw)
    np.testing.assert_array_equal(np.asarray(st_res.Y),
                                  np.asarray(st_ref.Y))
    assert int(st_res.step) == 16
    return {"killed_at": killed_at}


def scenario_host_loss(backend="interpret", tmpdir=None) -> dict:
    """One simulated host's device block dies mid-run; the elastic
    coordinator quiesces, remeshes over the survivors and resumes from
    the last committed chunk boundary.  The run finishes every
    iteration on the shrunken mesh with an embedding whose spread
    matches the uninterrupted run (exact bitwise parity is not expected:
    the smaller mesh regroups the force psum)."""
    import jax

    if jax.device_count() < 2:
        # plain `--smoke` runs single-device; the dedicated CI gate sets
        # XLA_FLAGS=--xla_force_host_platform_device_count=8
        return {"skipped": f"needs >=2 devices, have {jax.device_count()}"}

    import tempfile

    import numpy as np

    from repro.core.resilience import ResiliencePolicy
    from repro.runtime.coordinator import fit_elastic

    X, cfg = _smoke_setup(backend=backend)
    kw = dict(cfg=cfg, n_iter=16, chunk_size=4, n_hosts=2)

    st_ref = fit_elastic(X, resilience=ResiliencePolicy(), **kw)

    if tmpdir is None:
        tmpdir = tempfile.mkdtemp(prefix="funcsne-hostloss-")
    policy = ResiliencePolicy(checkpoint_dir=tmpdir, checkpoint_every=1)
    with active(FaultScript(HostLoss(at_step=8, host=1))):
        st = fit_elastic(X, resilience=policy, **kw)

    assert int(st.step) == 16, int(st.step)
    Y = np.asarray(st.Y)
    assert bool(np.isfinite(Y).all()), "embedding not finite after remesh"
    kinds = [e["kind"] for e in policy.events]
    assert "host_lost" in kinds and "remesh" in kinds, kinds
    # quality proxy robust at smoke scale: the layout kept optimising
    # after the remesh instead of resetting/ freezing -- its spread is
    # within 2x of the uninterrupted run's
    ref = float(np.std(np.asarray(st_ref.Y)))
    got = float(np.std(Y))
    assert 0.5 * ref <= got <= 2.0 * ref, (ref, got)
    return {"host_lost": 1, "resumed_at": next(
        e["step"] for e in policy.events if e["kind"] == "remesh"),
        "spread_ratio": round(got / max(ref, 1e-9), 3)}


def scenario_corrupt_restore(backend="interpret", tmpdir=None) -> dict:
    """Damage the newest COMMITTED checkpoint (truncate / bit-flip /
    delete a shard file) right after it lands, then kill the run: resume
    detects the damage at restore time, falls back to the previous
    verified boundary with a ``checkpoint_fallback`` event, and still
    reproduces the uninterrupted run bit-for-bit (chunk boundaries are
    bit-neutral, so replaying from one further back is exact).  With >=2
    devices the same story runs through ``fit_elastic``'s host-loss
    path: the lost host's per-shard checkpoint file is deleted and the
    remesh resumes from the previous verified boundary."""
    import shutil
    import tempfile

    import numpy as np

    from repro.core import funcsne
    from repro.core.resilience import ResiliencePolicy

    X, cfg = _smoke_setup(backend=backend)
    kw = dict(cfg=cfg, n_iter=16, chunk_size=4)
    st_ref, _ = funcsne.fit(X, resilience=ResiliencePolicy(), **kw)

    out = {}
    for mode in ("truncate", "bitflip", "delete"):
        tdir = tempfile.mkdtemp(prefix=f"funcsne-corrupt-{mode}-")
        fault = CorruptShard(at_step=8, mode=mode)
        try:
            with active(FaultScript(fault, Preemption(at_step=8))):
                funcsne.fit(X, resilience=ResiliencePolicy(
                    checkpoint_dir=tdir, checkpoint_every=1), **kw)
            raise AssertionError("preemption did not fire")
        except Preempted:
            pass
        assert fault.damaged is not None, "CorruptShard never fired"
        policy = ResiliencePolicy(checkpoint_dir=tdir, checkpoint_every=1)
        st_res, _ = funcsne.fit(X, resilience=policy, resume_from=tdir,
                                **kw)
        fbs = [e for e in policy.events
               if e["kind"] == "checkpoint_fallback"]
        assert fbs and fbs[0]["step"] == 8, policy.events
        np.testing.assert_array_equal(np.asarray(st_res.Y),
                                      np.asarray(st_ref.Y))
        assert int(st_res.step) == 16
        out[mode] = {"fell_back_from": fbs[0]["step"]}
        shutil.rmtree(tdir, ignore_errors=True)

    import jax
    if jax.device_count() < 2:
        out["elastic"] = {"skipped":
                          f"needs >=2 devices, have {jax.device_count()}"}
        return out

    from repro.runtime.coordinator import fit_elastic

    ekw = dict(cfg=cfg, n_iter=16, chunk_size=4, n_hosts=2)
    st_eref = fit_elastic(X, resilience=ResiliencePolicy(), **ekw)
    tdir = tempfile.mkdtemp(prefix="funcsne-corrupt-elastic-")
    policy = ResiliencePolicy(checkpoint_dir=tdir, checkpoint_every=1)
    with active(FaultScript(CorruptShard(at_step=8, mode="delete"),
                            HostLoss(at_step=8, host=1))):
        st = fit_elastic(X, resilience=policy, **ekw)
    kinds = [e["kind"] for e in policy.events]
    assert "host_lost" in kinds and "remesh" in kinds, kinds
    fbs = [e for e in policy.events if e["kind"] == "checkpoint_fallback"]
    assert fbs and fbs[0]["step"] == 8, policy.events
    assert int(st.step) == 16, int(st.step)
    Y = np.asarray(st.Y)
    assert bool(np.isfinite(Y).all()), "embedding not finite"
    ref = float(np.std(np.asarray(st_eref.Y)))
    got = float(np.std(Y))
    assert 0.5 * ref <= got <= 2.0 * ref, (ref, got)
    shutil.rmtree(tdir, ignore_errors=True)
    out["elastic"] = {"fell_back_from": fbs[0]["step"],
                      "spread_ratio": round(got / max(ref, 1e-9), 3)}
    return out


def scenario_index_audit(backend="interpret") -> dict:
    """Poisoned ``hd_idx`` (out-of-range but FINITE values, invisible to
    the NaN probes) trips the chunk-boundary auditor and the existing
    rollback path, and the run finishes with a clean state.  Positive
    control: with ``audit_every=0`` the same fault sails through -- no
    rollback, and the final state fails an offline audit."""
    import jax

    from repro.core import funcsne
    from repro.core.resilience import ResiliencePolicy

    X, cfg = _smoke_setup(backend=backend)
    kw = dict(cfg=cfg, n_iter=16, chunk_size=4)

    policy = ResiliencePolicy(max_retries=2, audit_every=1)
    with active(FaultScript(IndexCorruption(at_step=8, field="hd_idx"))):
        st, _ = funcsne.fit(X, resilience=policy, **kw)
    kinds = [e["kind"] for e in policy.events]
    assert "audit_violation" in kinds and "rollback" in kinds, kinds
    assert int(st.step) == 16, int(st.step)
    final = policy.audit_check(
        jax.device_get(funcsne.audit_state(st, cfg, X)))
    assert final is None, f"final state dirty after rollback: {final}"
    viol = next(e for e in policy.events
                if e["kind"] == "audit_violation")

    # positive control: auditor off -> nothing notices, the corruption
    # survives to the end of the run (this is the blind spot the
    # auditor closes; a regression that quietly stops auditing fails
    # the first assert above, a regression that trips on CLEAN states
    # fails this one)
    ctrl = ResiliencePolicy(max_retries=2, audit_every=0)
    with active(FaultScript(IndexCorruption(at_step=8, field="hd_idx"))):
        st0, _ = funcsne.fit(X, resilience=ctrl, **kw)
    kinds0 = [e["kind"] for e in ctrl.events]
    assert "rollback" not in kinds0 and "audit_violation" not in kinds0, \
        kinds0
    missed = ctrl.audit_check(
        jax.device_get(funcsne.audit_state(st0, cfg, X)))
    assert missed is not None, \
        "control run: the corruption disappeared without an audit"
    return {"tripped": viol["reason"][:48],
            "control_missed": missed[:48]}


def scenario_process_kill(backend="interpret", tmpdir=None) -> dict:
    """THE real-death gate: a 2-process CPU pod (gloo collectives under
    ``jax.distributed``), one worker SIGKILLs itself mid-run, and the
    supervisor must finish the embedding anyway -- heartbeat-loss
    detection, generation kill, remesh over the survivor, resume from
    the last committed generation-tagged boundary.  Asserts the
    structured event trail, the final committed step, no orphaned
    worker processes and no stale-generation shards on disk."""
    import os

    if os.environ.get("FUNCSNE_NO_MULTIPROCESS") == "1":
        return {"skipped": "FUNCSNE_NO_MULTIPROCESS=1"}

    from repro.runtime import control

    if not control.gloo_available():
        return {"skipped": "no gloo CPU collectives in this jaxlib"}

    import shutil
    import tempfile

    if tmpdir is None:
        tmpdir = tempfile.mkdtemp(prefix="funcsne-prockill-")
    n_iter, chunk = 16, 4
    sup = control.Supervisor(
        tmpdir, n_pods=2, n_iter=n_iter, chunk_size=chunk, n=64, dim=6,
        backend=backend, kill_pod=1, kill_at_chunk=8,
        heartbeat_timeout=20.0, total_timeout=480.0,
        # pin workers to 1 local device each: the scenario may itself
        # run under --xla_force_host_platform_device_count
        extra_env={"XLA_FLAGS": ""})
    report = sup.run()

    # the survivor finished every iteration and committed the boundary
    assert report["result"]["step"] == n_iter, report["result"]
    assert report["result"]["finite"], report["result"]
    assert report["generations"] == 2, report["generations"]
    steps = control.committed_steps(sup.ckpt_dir)
    assert steps and steps[-1] == n_iter, steps

    # structured trail, in causal order:
    # heartbeat_lost -> generation_killed -> remesh -> restore
    kinds = [e["kind"] for e in report["trail"]]
    order = [kinds.index(k) for k in
             ("heartbeat_lost", "generation_killed", "remesh", "restore")]
    assert order == sorted(order), kinds
    lost = next(e for e in report["trail"]
                if e["kind"] == "heartbeat_lost")
    assert lost["pod"] == 1, lost
    rem = next(e for e in report["trail"] if e["kind"] == "remesh")
    assert rem["survivors"] == [0] and rem["n_processes"] == 1, rem
    restore = next(e for e in report["trail"] if e["kind"] == "restore")
    assert restore["generation"] == 1, restore
    assert 0 < restore["step"] < n_iter, restore

    # no orphaned processes: every pid the supervisor ever spawned is
    # gone (ESRCH) or at worst a reaped zombie of OUR process (none --
    # the supervisor wait()s everything it kills)
    import errno
    for pid in report["pids"]:
        try:
            os.kill(pid, 0)
            raise AssertionError(f"orphaned worker pid {pid}")
        except OSError as e:
            assert e.errno == errno.ESRCH, e

    # no stale-generation shards: every committed step dir holds ONLY
    # files named by its own manifest, and the final boundary belongs
    # to the surviving generation
    import json as _json
    for s in steps:
        d = sup.ckpt_dir / f"step_{s:010d}"
        meta = _json.loads((d / "meta.json").read_text())
        want = set(meta["manifest"]["files"])
        have = {p.name for p in d.glob("*.npz")}
        assert have == want, (s, have, want)
        gen = meta.get("generation")
        tag = f"-g{gen:06d}.npz"
        assert all(f.endswith(tag) for f in want), (s, gen, want)
    final_meta = _json.loads(
        (sup.ckpt_dir / f"step_{steps[-1]:010d}" / "meta.json")
        .read_text())
    assert final_meta.get("generation") == 1, final_meta
    shutil.rmtree(tmpdir, ignore_errors=True)
    return {"resumed_at": restore["step"],
            "final_step": report["result"]["step"],
            "generations": report["generations"]}


SCENARIOS = {
    "nan_rollback": scenario_nan_rollback,
    "kernel_fallback": scenario_kernel_fallback,
    "preempt_resume": scenario_preempt_resume,
    "host_loss": scenario_host_loss,
    "corrupt_restore": scenario_corrupt_restore,
    "index_audit": scenario_index_audit,
    "process_kill": scenario_process_kill,
}


def main() -> int:
    import argparse
    import time

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="run all recovery scenarios on tiny data")
    ap.add_argument("--backend", default="interpret",
                    choices=["interpret", "xla", "pallas"])
    ap.add_argument("--only", default=None,
                    help="comma-separated scenario names")
    ap.add_argument("--no-skip", action="store_true",
                    help="fail any scenario that reports itself skipped "
                         "(for CI gates that must not silently go "
                         "vacuous when a capability probe regresses)")
    args = ap.parse_args()
    names = list(SCENARIOS)
    if args.only:
        names = [n for n in names if n in args.only.split(",")]
    failed = 0
    for name in names:
        t0 = time.time()
        try:
            info = SCENARIOS[name](backend=args.backend)
            if isinstance(info, dict) and "skipped" in info:
                if args.no_skip:
                    failed += 1
                    print(f"[faults] {name}: FAILED: required scenario "
                          f"skipped: {info['skipped']}", flush=True)
                else:
                    print(f"[faults] {name}: skipped: {info['skipped']}",
                          flush=True)
                continue
            print(f"[faults] {name}: OK in {time.time() - t0:.1f}s {info}",
                  flush=True)
        except Exception as e:  # pragma: no cover - CI failure surface
            failed += 1
            print(f"[faults] {name}: FAILED: {e!r}", flush=True)
    return 1 if failed else 0


if __name__ == "__main__":
    # re-dispatch through the canonical import so the scenarios share the
    # one _ACTIVE cell funcsne.fit consults (running under `python -m`
    # loads this file as `__main__`, a *second* module object)
    from repro.runtime import faults as _canonical
    raise SystemExit(_canonical.main())

"""Elastic scaling: rebuild the mesh after membership changes and re-shard.

Flow on failure/resize (host granularity -- the coordinator in
``repro.runtime.coordinator`` drives this for the embedding workload):
  1. the coordinator detects a dead host (heartbeat / straggler alarm /
     an injected ``faults.HostLoss``),
  2. survivors quiesce, the last committed checkpoint is the truth,
  3. ``remesh()`` builds a mesh over the remaining devices (shrinking the
     data axis, and the model axis if it no longer fits),
  4. ``Checkpointer.restore(..., shardings=new)`` re-lays-out the state,
  5. the chunked schedule replays from the carried ``st.step`` (chunk
     boundaries are bit-neutral, so no iteration is lost or repeated).

Checkpoints store unsharded arrays (per-host row slices merge back to
unsharded on load), so any (old mesh -> new mesh) pair works; there is no
resharding converter to maintain.

Mesh-change events (``devices_dropped``, and anything a caller logs
through ``on_event``) are recorded in a module event log (:func:`events`)
-- the same structured-telemetry idiom as ``repro.kernels.fallback``.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import jax

from repro import compat

_EVENTS: List[dict] = []


def events(since: int = 0) -> List[dict]:
    """Structured mesh-change events recorded by :func:`remesh`."""
    return list(_EVENTS[since:])


def n_events() -> int:
    return len(_EVENTS)


def reset_events() -> None:
    _EVENTS.clear()


def _emit(event: dict, on_event=None) -> dict:
    _EVENTS.append(event)
    if on_event is not None:
        on_event(event)
    return event


def remesh(n_devices: int = None, *, model: int = 16,
           axis_names=("data", "model"), devices: Sequence = None,
           exact_model: bool = False, divides: Sequence[int] = (),
           on_event=None):
    """Largest (data, model) mesh over the surviving devices.

    ``model`` is the *requested* tensor-parallel width.  Unless
    ``exact_model``, the actual width is the largest feasible one
    ``<= model`` that divides the device count (and every extra
    constraint in ``divides``, e.g. the feature dim the model axis
    shards), so NO device is silently discarded: 24 devices at
    ``model=16`` build a (2, 12) mesh instead of using 16 chips and
    dropping 8 on the floor.  ``exact_model=True`` keeps the requested
    width and truncates -- any device left out is reported as a
    structured ``devices_dropped`` event (module log + ``on_event``)
    rather than vanishing.

    ``devices`` restricts the pool (the coordinator passes the
    survivors); default is all of ``jax.devices()``.
    """
    devices = list(jax.devices() if devices is None else devices)
    if n_devices is None:
        n_devices = len(devices)
    n_devices = min(int(n_devices), len(devices))
    if n_devices < 1:
        raise ValueError("remesh needs at least one surviving device")
    model = max(1, min(int(model), n_devices))
    if not exact_model:
        def feasible(m):
            return n_devices % m == 0 and all(d % m == 0 for d in divides)
        while model > 1 and not feasible(model):
            model -= 1
    data = n_devices // model
    used = data * model
    if used < n_devices:
        _emit({"kind": "devices_dropped", "requested_model": model,
               "n_devices": n_devices, "n_used": used,
               "n_dropped": n_devices - used,
               "dropped": [str(d) for d in devices[used:n_devices]]},
              on_event)
    return compat.make_mesh((data, model), axis_names,
                            devices=devices[:used])


def surviving_pods(heartbeats: dict, timeout_s: float, now: float) -> list:
    """Pod ids whose last heartbeat is fresh."""
    return [p for p, t in sorted(heartbeats.items()) if now - t <= timeout_s]

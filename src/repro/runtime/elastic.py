"""Elastic scaling: rebuild the mesh after membership changes and re-shard.

Flow on failure/resize (pod granularity -- the DCN axis is pure DP so pods
are the natural elasticity unit):
  1. the launcher detects a dead pod (heartbeat / straggler alarm),
  2. survivors quiesce, the last committed checkpoint is the truth,
  3. ``remesh()`` builds a mesh over the remaining devices (dropping the
     pod axis or shrinking it),
  4. ``Checkpointer.restore(..., shardings=new)`` re-lays-out the state,
  5. the data cursor advances with the *new* global batch mapping.

Checkpoints store unsharded arrays, so any (old mesh -> new mesh) pair
works; there is no resharding converter to maintain.
"""
from __future__ import annotations

from typing import Sequence

import jax

from repro import compat


def remesh(n_devices: int, *, model: int = 16, axis_names=("data", "model")):
    """Largest (data, model) mesh fitting n_devices with fixed TP width."""
    if n_devices < model:
        model = n_devices
    data = n_devices // model
    devices = jax.devices()[: data * model]
    return compat.make_mesh((data, model), axis_names, devices=devices)


def surviving_pods(heartbeats: dict, timeout_s: float, now: float) -> list:
    """Pod ids whose last heartbeat is fresh."""
    return [p for p, t in sorted(heartbeats.items()) if now - t <= timeout_s]

"""Elastic scaling: rebuild the mesh after membership changes and re-shard.

Flow on failure/resize (host granularity -- the coordinator in
``repro.runtime.coordinator`` drives this for the embedding workload):
  1. the coordinator detects a dead host (heartbeat / straggler alarm /
     an injected ``faults.HostLoss``),
  2. survivors quiesce, the last committed checkpoint is the truth,
  3. ``remesh()`` builds a mesh over the remaining devices (shrinking the
     data axis, and the model axis if it no longer fits),
  4. ``Checkpointer.restore(..., shardings=new)`` re-lays-out the state,
  5. the chunked schedule replays from the carried ``st.step`` (chunk
     boundaries are bit-neutral, so no iteration is lost or repeated).

Checkpoints store unsharded arrays (per-host row slices merge back to
unsharded on load), so any (old mesh -> new mesh) pair works; there is no
resharding converter to maintain.

Mesh-change events (``devices_dropped``, and anything a caller logs
through ``on_event``) are recorded in a module event log (:func:`events`)
-- the same structured-telemetry idiom as ``repro.kernels.fallback``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Hashable, List, Optional, Sequence

import jax

from repro import compat

_EVENTS: List[dict] = []


def events(since: int = 0) -> List[dict]:
    """Structured mesh-change events recorded by :func:`remesh`."""
    return list(_EVENTS[since:])


def n_events() -> int:
    return len(_EVENTS)


def reset_events() -> None:
    _EVENTS.clear()


def _emit(event: dict, on_event=None) -> dict:
    _EVENTS.append(event)
    if on_event is not None:
        on_event(event)
    return event


def remesh(n_devices: int = None, *, model: int = 16,
           axis_names=("data", "model"), devices: Sequence = None,
           exact_model: bool = False, divides: Sequence[int] = (),
           on_event=None):
    """Largest (data, model) mesh over the surviving devices.

    ``model`` is the *requested* tensor-parallel width.  Unless
    ``exact_model``, the actual width is the largest feasible one
    ``<= model`` that divides the device count (and every extra
    constraint in ``divides``, e.g. the feature dim the model axis
    shards), so NO device is silently discarded: 24 devices at
    ``model=16`` build a (2, 12) mesh instead of using 16 chips and
    dropping 8 on the floor.  ``exact_model=True`` keeps the requested
    width and truncates -- any device left out is reported as a
    structured ``devices_dropped`` event (module log + ``on_event``)
    rather than vanishing.

    ``devices`` restricts the pool (the coordinator passes the
    survivors); default is all of ``jax.devices()``.
    """
    devices = list(jax.devices() if devices is None else devices)
    if n_devices is None:
        n_devices = len(devices)
    n_devices = min(int(n_devices), len(devices))
    if n_devices < 1:
        raise ValueError("remesh needs at least one surviving device")
    model = max(1, min(int(model), n_devices))
    if not exact_model:
        def feasible(m):
            return n_devices % m == 0 and all(d % m == 0 for d in divides)
        while model > 1 and not feasible(model):
            model -= 1
    data = n_devices // model
    used = data * model
    if used < n_devices:
        _emit({"kind": "devices_dropped", "requested_model": model,
               "n_devices": n_devices, "n_used": used,
               "n_dropped": n_devices - used,
               "dropped": [str(d) for d in devices[used:n_devices]]},
              on_event)
    return compat.make_mesh((data, model), axis_names,
                            devices=devices[:used])


# --------------------------------------------------------------------------
# Heartbeat liveness: the observer-stamped beat-counter contract.
#
# Pods prove liveness by BUMPING A COUNTER (in a per-pod heartbeat file,
# at every chunk boundary), never by writing a timestamp: wall clocks on
# different hosts are not comparable, and even a "recent-looking" remote
# timestamp says nothing once the writer's clock skews.  The observer
# (the supervisor in ``repro.runtime.control``) stamps each counter
# *change* with its OWN ``time.monotonic()``; freshness is then a purely
# observer-local question -- "how long since I last saw this pod make
# progress" -- immune to skew, NTP steps and paused clocks on the pods.


@dataclasses.dataclass
class Beat:
    """One pod's liveness record, as seen by the observer.

    ``counter`` is the last beat value the pod published (opaque --
    equality is the only operation; tuples like ``(generation, k)``
    work).  ``stamped`` is the observer's ``time.monotonic()`` at the
    moment the counter last CHANGED (first observation included).
    ``changes`` counts observed changes since the first observation --
    0 means the pod has published but never been seen to progress.
    (Counter changes alone cannot prove a pod is past its slow startup
    -- workers may beat before runtime init and again on loop entry --
    so the supervisor gates its startup grace on beat *content*, the
    step a beat carries, not on this field.)"""
    counter: Hashable
    stamped: float
    changes: int = 0


class HeartbeatObserver:
    """Stamps beat-counter changes with the observer's monotonic clock.

    ``observe(pod, counter, now)`` records ``now`` as the pod's
    freshness time iff ``counter`` differs from the last one seen (or
    the pod is new); re-observing an unchanged counter never refreshes,
    so a wedged pod whose stale file keeps being re-read goes stale on
    schedule.  ``now`` must come from the observer's own clock
    (``time.monotonic()``) -- never from anything the pod wrote."""

    def __init__(self):
        self.beats: Dict[Hashable, Beat] = {}

    def observe(self, pod, counter, now: float) -> bool:
        """Record one reading; returns True when it counted as progress."""
        b = self.beats.get(pod)
        if b is None:
            self.beats[pod] = Beat(counter, float(now))
            return True
        if counter != b.counter:
            b.counter = counter
            b.stamped = float(now)
            b.changes += 1
            return True
        return False

    def forget(self, pod) -> None:
        self.beats.pop(pod, None)

    def survivors(self, timeout_s: float, now: float) -> list:
        return surviving_pods(self.beats, timeout_s, now)


def surviving_pods(beats: dict, timeout_s: float, now: float) -> list:
    """Pod ids whose beat counter changed within ``timeout_s`` of ``now``.

    ``beats`` maps pod id -> :class:`Beat` (or a ``(counter, stamped)``
    tuple), where ``stamped`` is the OBSERVER's monotonic time of the
    last counter change -- see :class:`HeartbeatObserver`.  A
    boundary-equal gap (``now - stamped == timeout_s``) counts fresh:
    the timeout is the first instant a pod may be declared dead, not the
    last instant it may be declared alive, so detection latency bounds
    stay closed under equality.  Pod wall clocks never enter the
    comparison."""
    out = []
    for pod, b in sorted(beats.items()):
        stamped = b.stamped if isinstance(b, Beat) else b[1]
        if now - float(stamped) <= timeout_s:
            out.append(pod)
    return out

"""LM model substrate: one composable decoder-LM covering all assigned archs.

Block types: dense GQA attention (llama/qwen/yi/chameleon/musicgen),
Gemma2 local/global alternating with logit softcaps, MLA (DeepSeek-V2),
token-choice MoE with EP argsort dispatch (OLMoE/DeepSeek-V2), Mamba2 SSD
(mamba2/zamba2), and the Zamba2 shared-attention hybrid.
"""

from repro.models.transformer import LMModel  # noqa: F401

"""Attention layers: GQA (chunked flash for XLA, Pallas kernel on TPU),
Gemma2 local/global, MLA (DeepSeek-V2 latent KV), and decode paths.

The training/prefill path uses a double-scan online-softmax implementation
(`flash_chunked`): O(S * chunk) live memory instead of O(S^2), numerically
identical to materialised softmax.  It lowers on any backend, which is what
the multi-pod dry-run compiles; on TPU runtime the Pallas flash kernel
(repro.kernels.flash_attention) is a drop-in for the inner loop.

Decode uses direct einsum over the KV cache: with the cache sequence dim
sharded over the `model` axis the max/sum reductions become XLA's
flash-decoding (split-K) pattern under GSPMD.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import (ShardCtx, apply_rope, dense_init, rms_norm,
                                 softcap)

_NEG = -1e30


# --------------------------------------------------------------------------
# Core chunked flash attention (XLA path; layout (B, S, H, D))


def flash_chunked(q, k, v, *, chunk_q: int = 0, chunk_k: int = 512,
                  scale: float, cap: float = 0.0, window: int = 0,
                  q_offset=0, score_budget_bytes: int = 192 * 2 ** 20,
                  seq_shards: int = 1):
    """seq_shards: how many ways the (B, S, H) score rows are sharded
    across chips (sequence- or head-parallel); sizes the chunk budget."""
    """Causal GQA attention: one online-softmax scan over KV chunks.

    Sequence-parallel design (DESIGN.md Sec. 5): q keeps its (sharded) S
    dim intact -- the scan iterates over KV chunks only, so no sharded
    dimension is ever sliced inside the loop and the layout works for ANY
    head count (28, 40, 56 q-heads on a 16-wide model axis included).
    KV is replicated over the model axis by the caller.

    chunk_k adapts downward so the live (B, S/seq_shards, H, ck) f32 score
    tile stays under ``score_budget_bytes`` per chip.

    q: (B, Sq, Hq, D); k: (B, Sk, Hkv, D); v: (B, Sk, Hkv, Dv) -- Dv may
    differ from D (MLA attends over the latent).  q_offset: global position
    of q[0].  Returns (B, Sq, Hq, Dv).  chunk_q is accepted for
    API compatibility and ignored.
    """
    del chunk_q
    B, Sq, Hq, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    group = Hq // Hkv

    col_bytes = 4 * B * max(Sq // seq_shards, 1) * Hq
    ck = min(chunk_k, Sk)
    while ck > 128 and col_bytes * ck > score_budget_bytes:
        ck //= 2
    while Sk % ck:
        ck //= 2
    nk = Sk // ck

    qg = q.reshape(B, Sq, Hkv, group, D)
    kg = k.reshape(B, nk, ck, Hkv, D).swapaxes(0, 1)
    vg = v.reshape(B, nk, ck, Hkv, Dv).swapaxes(0, 1)
    rows = q_offset + jnp.arange(Sq)

    def kv_block(carry, ki):
        m, l, acc = carry
        ik, kc, vc = ki                          # kc: (B, ck, Hkv, D)
        cols = ik * ck + jnp.arange(ck)
        s = jnp.einsum("bqhgd,bkhd->bqhgk", qg.astype(jnp.float32),
                       kc.astype(jnp.float32)) * scale
        if cap:
            s = cap * jnp.tanh(s / cap)
        mask = cols[None, :] <= rows[:, None]
        if window:
            mask &= cols[None, :] > rows[:, None] - window
        s = jnp.where(mask[None, :, None, None, :], s, _NEG)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(m_new[..., None] > _NEG / 2, p, 0.0)
        corr = jnp.where(m > _NEG / 2, jnp.exp(m - m_new), 0.0)
        l_new = corr * l + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bqhgk,bkhd->bqhgd", p, vc.astype(jnp.float32))
        return (m_new, l_new, acc), None

    m0 = jnp.full((B, Sq, Hkv, group), _NEG, jnp.float32)
    l0 = jnp.zeros((B, Sq, Hkv, group), jnp.float32)
    a0 = jnp.zeros((B, Sq, Hkv, group, Dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(kv_block, (m0, l0, a0),
                                  (jnp.arange(nk), kg, vg))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, Sq, Hq, Dv).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cur_len, *, scale: float,
                     cap: float = 0.0, window: int = 0):
    """One-token attention over a (B, Smax, Hkv, D) cache.

    q: (B, 1, Hq, D); cur_len: () current length *including* the new token.
    """
    B, Smax, Hkv, D = k_cache.shape
    Hq = q.shape[2]
    group = Hq // Hkv
    qg = q.reshape(B, Hkv, group, D)
    s = jnp.einsum("bhgd,bshd->bhgs", qg.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * scale
    if cap:
        s = cap * jnp.tanh(s / cap)
    pos = jnp.arange(Smax)
    mask = pos[None, :] < cur_len
    if window:
        mask &= pos[None, :] > cur_len - 1 - window
    s = jnp.where(mask[:, None, None, :] if mask.ndim == 2
                  else mask[None, None, None, :], s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, Hq, D).astype(q.dtype)


# --------------------------------------------------------------------------
# GQA attention layer


def init_gqa(rng, cfg):
    D, H, Hkv, Dh = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                     cfg.resolved_head_dim)
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(rng, 4)
    p = {
        "wq": dense_init(ks[0], (D, H, Dh), dt, fan_in=D),
        "wk": dense_init(ks[1], (D, Hkv, Dh), dt, fan_in=D),
        "wv": dense_init(ks[2], (D, Hkv, Dh), dt, fan_in=D),
        "wo": dense_init(ks[3], (H, Dh, D), dt, fan_in=H * Dh),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, Dh), dt)
        p["bk"] = jnp.zeros((Hkv, Dh), dt)
        p["bv"] = jnp.zeros((Hkv, Dh), dt)
    return p


def gqa_specs(cfg):
    from jax.sharding import PartitionSpec as P
    s = {"wq": P("data", "model", None), "wk": P("data", "model", None),
         "wv": P("data", "model", None), "wo": P("model", None, "data")}
    if cfg.qkv_bias:
        s.update({"bq": P("model", None), "bk": P("model", None),
                  "bv": P("model", None)})
    return s


def gqa_apply(p, h, cfg, ctx: ShardCtx, *, window: int = 0, positions=None,
              cache=None, cur_len=None):
    """h: (B, S, D).  cache: dict(k, v) -> updated in decode mode."""
    B, S, D = h.shape
    Dh = cfg.resolved_head_dim
    cd = jnp.dtype(cfg.compute_dtype)
    h = h.astype(cd)
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"].astype(cd))
    k = jnp.einsum("bsd,dhk->bshk", h, p["wk"].astype(cd))
    v = jnp.einsum("bsd,dhk->bshk", h, p["wv"].astype(cd))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(cd)
        k = k + p["bk"].astype(cd)
        v = v + p["bv"].astype(cd)
    if positions is None:
        positions = jnp.arange(S)[None, :] if cur_len is None \
            else (cur_len - 1) * jnp.ones((B, 1), jnp.int32)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    scale = Dh ** -0.5

    new_cache = None
    if cache is None:
        # sequence-parallel attention: q rows stay S-sharded over the model
        # axis (works for any head count); KV is gathered (replicated) once
        # per layer, so the KV scan never slices a sharded dim.
        q = ctx.constrain(q, ctx.batch_spec, ctx.model, None, None)
        k = ctx.constrain(k, ctx.batch_spec, None, None, None)
        v = ctx.constrain(v, ctx.batch_spec, None, None, None)
        out = flash_chunked(q, k, v, chunk_k=min(cfg.attn_chunk_k, S),
                            scale=scale, cap=cfg.attn_softcap, window=window,
                            seq_shards=ctx.model_size)
        out = ctx.constrain(out, ctx.batch_spec, ctx.model, None, None)
    else:
        # decode: append to cache at cur_len - 1, attend over prefix
        idx = (cur_len - 1).astype(jnp.int32)
        kc = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(
            cache["k"].dtype), idx, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(
            cache["v"].dtype), idx, axis=1)
        new_cache = {"k": kc, "v": vc}
        out = decode_attention(q, kc, vc, cur_len, scale=scale,
                               cap=cfg.attn_softcap, window=window)
    out = jnp.einsum("bshk,hkd->bsd", out.astype(cd), p["wo"].astype(cd))
    return out, new_cache


# --------------------------------------------------------------------------
# MLA (DeepSeek-V2): latent-compressed KV with decoupled RoPE head


def init_mla(rng, cfg):
    D, H = cfg.d_model, cfg.n_heads
    L, dn, dr, dv = (cfg.kv_lora_rank, cfg.q_nope_dim, cfg.q_rope_dim,
                     cfg.v_head_dim)
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(rng, 5)
    return {
        "wq": dense_init(ks[0], (D, H, dn + dr), dt, fan_in=D),
        "w_dkv": dense_init(ks[1], (D, L + dr), dt, fan_in=D),
        "kv_norm": jnp.zeros((L,), dt) + 1.0,
        "w_uk": dense_init(ks[2], (L, H, dn), dt, fan_in=L),
        "w_uv": dense_init(ks[3], (L, H, dv), dt, fan_in=L),
        "wo": dense_init(ks[4], (H, dv, D), dt, fan_in=H * dv),
    }


def mla_specs(cfg):
    from jax.sharding import PartitionSpec as P
    return {"wq": P("data", "model", None), "w_dkv": P("data", None),
            "kv_norm": P(None), "w_uk": P(None, "model", None),
            "w_uv": P(None, "model", None), "wo": P("model", None, "data")}


def mla_apply(p, h, cfg, ctx: ShardCtx, *, positions=None, cache=None,
              cur_len=None, window: int = 0):
    B, S, D = h.shape
    L, dn, dr = cfg.kv_lora_rank, cfg.q_nope_dim, cfg.q_rope_dim
    cd = jnp.dtype(cfg.compute_dtype)
    h = h.astype(cd)
    if positions is None:
        positions = jnp.arange(S)[None, :] if cur_len is None \
            else (cur_len - 1) * jnp.ones((B, 1), jnp.int32)

    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"].astype(cd))
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    ckv = jnp.einsum("bsd,dk->bsk", h, p["w_dkv"].astype(cd))
    latent = rms_norm(ckv[..., :L], p["kv_norm"])
    k_rope = apply_rope(ckv[..., L:], positions, cfg.rope_theta)  # (B,S,dr)
    scale = (dn + dr) ** -0.5

    new_cache = None
    if cache is not None:
        # decode keeps the ABSORBED form: the cache stores only the shared
        # latent; scores contract q_eff (H, L) against it (MQA-like)
        q_eff = jnp.einsum("bshn,lhn->bshl", q_nope, p["w_uk"].astype(cd))
        idx = (cur_len - 1).astype(jnp.int32)
        lat_c = jax.lax.dynamic_update_slice_in_dim(
            cache["latent"], latent.astype(cache["latent"].dtype), idx, axis=1)
        rope_c = jax.lax.dynamic_update_slice_in_dim(
            cache["k_rope"], k_rope.astype(cache["k_rope"].dtype), idx, axis=1)
        new_cache = {"latent": lat_c, "k_rope": rope_c}
        latent_all, k_rope_all = lat_c, rope_c
        Sk = latent_all.shape[1]
        s = (jnp.einsum("bshl,btl->bhst", q_eff.astype(jnp.float32),
                        latent_all.astype(jnp.float32))
             + jnp.einsum("bshr,btr->bhst", q_rope.astype(jnp.float32),
                          k_rope_all.astype(jnp.float32))) * scale
        mask = jnp.arange(Sk)[None, :] < cur_len
        s = jnp.where(mask[:, None, None, :], s, _NEG)
        w = jax.nn.softmax(s, axis=-1)
        o_lat = jnp.einsum("bhst,btl->bshl", w,
                           latent_all.astype(jnp.float32))   # (B,S,H,L)
        out = jnp.einsum("bshl,lhv->bshv", o_lat.astype(cd),
                         p["w_uv"].astype(cd))
        out = jnp.einsum("bshv,hvd->bsd", out, p["wo"].astype(cd))
        return out, new_cache

    # prefill/train: NON-absorbed form (H2, EXPERIMENTS.md §Perf): per-head
    # K/V are materialised so the score contraction is (dn+dr)=192 wide
    # instead of (L+dr)=576, and heads (128 = 16x8) shard over the model
    # axis -- classic TP, S stays unsharded inside this block.
    k_nope = jnp.einsum("bsl,lhn->bshn", latent, p["w_uk"].astype(cd))
    v = jnp.einsum("bsl,lhv->bshv", latent, p["w_uv"].astype(cd))
    kr = jnp.broadcast_to(k_rope[:, :, None, :],
                          (B, S, cfg.n_heads, dr))
    kcat = jnp.concatenate([k_nope, kr], axis=-1)            # (B,S,H,dn+dr)
    qcat = jnp.concatenate([q_nope, q_rope], axis=-1)
    qcat = ctx.constrain(qcat, ctx.batch_spec, None, ctx.model, None)
    kcat = ctx.constrain(kcat, ctx.batch_spec, None, ctx.model, None)
    v = ctx.constrain(v, ctx.batch_spec, None, ctx.model, None)
    shards = ctx.model_size
    o = flash_chunked(qcat, kcat, v, chunk_k=min(cfg.attn_chunk_k, S),
                      scale=scale, cap=0.0, window=window,
                      seq_shards=shards)
    o = ctx.constrain(o, ctx.batch_spec, None, ctx.model, None)
    out = jnp.einsum("bshv,hvd->bsd", o.astype(cd), p["wo"].astype(cd))
    return out, new_cache

"""Per-family transformer blocks: init / PartitionSpec / apply triples.

Every block apply has the signature
    apply(params, h, cfg, ctx, *, positions=None, cache=None, cur_len=None)
returning (h_new, new_cache, aux) so the layer scan in transformer.py is
family-agnostic.  ``aux`` carries MoE router losses (zeros elsewhere).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import attention as attn_lib
from repro.models import mamba2 as mamba_lib
from repro.models import moe as moe_lib
from repro.models.common import (ShardCtx, dense_init, gelu, rms_norm, swiglu)

ZERO_AUX = {"load_balance": 0.0, "router_z": 0.0, "dropped_frac": 0.0}


def _aux(d=None):
    out = {k: jnp.float32(v) for k, v in ZERO_AUX.items()}
    if d:
        out.update({k: jnp.float32(v) if not hasattr(v, "dtype") else v
                    for k, v in d.items()})
    return out


# --------------------------------------------------------------------------
# Dense MLP


def init_mlp(rng, cfg, d_ff=None):
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(rng, 3)
    return {"w_gate": dense_init(ks[0], (D, F), dt, fan_in=D),
            "w_up": dense_init(ks[1], (D, F), dt, fan_in=D),
            "w_down": dense_init(ks[2], (F, D), dt, fan_in=F)}


def mlp_specs(cfg):
    return {"w_gate": P("data", "model"), "w_up": P("data", "model"),
            "w_down": P("model", "data")}


def mlp_apply(p, x, cfg):
    cd = jnp.dtype(cfg.compute_dtype)
    x = x.astype(cd)
    g = x @ p["w_gate"].astype(cd)
    u = x @ p["w_up"].astype(cd)
    h = gelu(g) * u if cfg.mlp_act == "geglu" else swiglu(g, u)
    return h @ p["w_down"].astype(cd)


def _norm(p, x, cfg):
    return rms_norm(x, p, plus_one=cfg.norm_plus_one)


# --------------------------------------------------------------------------
# Dense GQA block (llama/qwen/yi/chameleon/musicgen)


def init_dense_block(rng, cfg):
    ks = jax.random.split(rng, 2)
    dt = jnp.dtype(cfg.param_dtype)
    z = jnp.zeros((cfg.d_model,), dt)
    return {"attn": attn_lib.init_gqa(ks[0], cfg), "mlp": init_mlp(ks[1], cfg),
            "ln_attn": z + 1.0, "ln_mlp": z + 1.0}


def dense_block_specs(cfg):
    return {"attn": attn_lib.gqa_specs(cfg), "mlp": mlp_specs(cfg),
            "ln_attn": P(None), "ln_mlp": P(None)}


def dense_block_apply(p, h, cfg, ctx: ShardCtx, *, positions=None, cache=None,
                      cur_len=None, window: int = 0):
    a, new_cache = attn_lib.gqa_apply(p["attn"], _norm(p["ln_attn"], h, cfg),
                                      cfg, ctx, window=window,
                                      positions=positions, cache=cache,
                                      cur_len=cur_len)
    h = h + a
    h = h + mlp_apply(p["mlp"], _norm(p["ln_mlp"], h, cfg), cfg)
    return h, new_cache, _aux()


# --------------------------------------------------------------------------
# Gemma2 pair (local sliding-window layer + global layer, sandwich norms)


def init_gemma_pair(rng, cfg):
    ks = jax.random.split(rng, 2)
    dt = jnp.dtype(cfg.param_dtype)
    z = jnp.zeros((cfg.d_model,), dt)

    def sub(r):
        k1, k2 = jax.random.split(r)
        return {"attn": attn_lib.init_gqa(k1, cfg),
                "mlp": init_mlp(k2, cfg),
                "ln_attn_pre": z + 0.0, "ln_attn_post": z + 0.0,
                "ln_mlp_pre": z + 0.0, "ln_mlp_post": z + 0.0}

    return {"local": sub(ks[0]), "global": sub(ks[1])}


def gemma_pair_specs(cfg):
    sub = {"attn": attn_lib.gqa_specs(cfg), "mlp": mlp_specs(cfg),
           "ln_attn_pre": P(None), "ln_attn_post": P(None),
           "ln_mlp_pre": P(None), "ln_mlp_post": P(None)}
    return {"local": sub, "global": dict(sub)}


def _gemma_sub_apply(p, h, cfg, ctx, *, window, positions, cache, cur_len):
    a, new_cache = attn_lib.gqa_apply(
        p["attn"], _norm(p["ln_attn_pre"], h, cfg), cfg, ctx, window=window,
        positions=positions, cache=cache, cur_len=cur_len)
    h = h + _norm(p["ln_attn_post"], a, cfg)
    m = mlp_apply(p["mlp"], _norm(p["ln_mlp_pre"], h, cfg), cfg)
    h = h + _norm(p["ln_mlp_post"], m, cfg)
    return h, new_cache


def gemma_pair_apply(p, h, cfg, ctx: ShardCtx, *, positions=None, cache=None,
                     cur_len=None, window: int = 0):
    del window
    c_l = cache["local"] if cache is not None else None
    c_g = cache["global"] if cache is not None else None
    h, nc_l = _gemma_sub_apply(p["local"], h, cfg, ctx,
                               window=cfg.local_window, positions=positions,
                               cache=c_l, cur_len=cur_len)
    h, nc_g = _gemma_sub_apply(p["global"], h, cfg, ctx, window=0,
                               positions=positions, cache=c_g,
                               cur_len=cur_len)
    new_cache = None if cache is None else {"local": nc_l, "global": nc_g}
    return h, new_cache, _aux()


# --------------------------------------------------------------------------
# MoE block (OLMoE: GQA + MoE; DeepSeek-V2: MLA + shared/routed MoE)


def init_moe_block(rng, cfg, *, dense_ffn: bool = False):
    ks = jax.random.split(rng, 2)
    dt = jnp.dtype(cfg.param_dtype)
    z = jnp.zeros((cfg.d_model,), dt)
    attn = (attn_lib.init_mla(ks[0], cfg) if cfg.is_mla
            else attn_lib.init_gqa(ks[0], cfg))
    ffn = (init_mlp(ks[1], cfg) if dense_ffn
           else moe_lib.init_moe(ks[1], cfg))
    return {"attn": attn, "ffn": ffn, "ln_attn": z + 1.0, "ln_mlp": z + 1.0}


def moe_block_specs(cfg, *, dense_ffn: bool = False):
    attn = attn_lib.mla_specs(cfg) if cfg.is_mla else attn_lib.gqa_specs(cfg)
    ffn = mlp_specs(cfg) if dense_ffn else moe_lib.moe_specs(cfg)
    return {"attn": attn, "ffn": ffn, "ln_attn": P(None), "ln_mlp": P(None)}


def moe_block_apply(p, h, cfg, ctx: ShardCtx, *, positions=None, cache=None,
                    cur_len=None, window: int = 0, dense_ffn: bool = False):
    B, S, D = h.shape
    apply_attn = attn_lib.mla_apply if cfg.is_mla else attn_lib.gqa_apply
    a, new_cache = apply_attn(p["attn"], _norm(p["ln_attn"], h, cfg), cfg,
                              ctx, positions=positions, cache=cache,
                              cur_len=cur_len, window=window)
    h = h + a
    x = _norm(p["ln_mlp"], h, cfg)
    if dense_ffn:
        out, aux = mlp_apply(p["ffn"], x, cfg), _aux()
    elif cfg.moe_impl == "a2a" and cache is None:
        out, aux_d = moe_lib.moe_apply_a2a(p["ffn"], x, cfg, ctx)
        aux = _aux(aux_d)
    else:
        out, aux_d = moe_lib.moe_apply(p["ffn"], x.reshape(B * S, D), cfg, ctx)
        out = out.reshape(B, S, D)
        aux = _aux(aux_d)
    return h + out, new_cache, aux


# --------------------------------------------------------------------------
# Mamba2 block


def init_mamba_block(rng, cfg):
    dt = jnp.dtype(cfg.param_dtype)
    z = jnp.zeros((cfg.d_model,), dt)
    return {"mixer": mamba_lib.init_mamba2(rng, cfg), "ln": z + 1.0}


def mamba_block_specs(cfg):
    return {"mixer": mamba_lib.mamba2_specs(cfg), "ln": P(None)}


def mamba_block_apply(p, h, cfg, ctx: ShardCtx, *, positions=None, cache=None,
                      cur_len=None, window: int = 0):
    del positions, cur_len, window
    m, new_cache = mamba_lib.mamba2_apply(p["mixer"], _norm(p["ln"], h, cfg),
                                          cfg, ctx, cache=cache)
    return h + m, new_cache, _aux()


# --------------------------------------------------------------------------
# Zamba2 super-block: `shared_attn_every` mamba layers + one application of
# the SHARED attention+MLP block (parameters reused across super-blocks).


def init_zamba_super(rng, cfg):
    e = cfg.shared_attn_every
    ks = jax.random.split(rng, e)
    return {"mamba": jax.vmap(lambda r: init_mamba_block(r, cfg))(
        jnp.stack(ks))}


def zamba_super_specs(cfg):
    inner = mamba_block_specs(cfg)
    return {"mamba": jax.tree.map(lambda s: P(None, *s), inner,
                                  is_leaf=lambda x: isinstance(x, P))}


def zamba_super_apply(p, shared_p, h, cfg, ctx: ShardCtx, *, positions=None,
                      cache=None, cur_len=None):
    """cache: {'mamba': stacked(e), 'attn': one-layer kv cache}."""
    def inner(carry, xs):
        hh = carry
        bp, bc = xs
        hh, nc, _ = mamba_block_apply(bp, hh, cfg, ctx, cache=bc,
                                      cur_len=cur_len)
        return hh, nc

    m_cache = cache["mamba"] if cache is not None else None
    h, new_m = jax.lax.scan(inner, h, (p["mamba"], m_cache))
    a_cache = cache["attn"] if cache is not None else None
    h, new_a, _ = dense_block_apply(shared_p, h, cfg, ctx,
                                    positions=positions, cache=a_cache,
                                    cur_len=cur_len)
    new_cache = None if cache is None else {"mamba": new_m, "attn": new_a}
    return h, new_cache, _aux()

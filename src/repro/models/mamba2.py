"""Mamba2 mixer via SSD (state-space duality, arXiv:2405.21060).

Training/prefill runs the chunked SSD algorithm as a single lax.scan over
sequence chunks: within a chunk the recurrence is the quadratic masked-decay
form (MXU-friendly (Q x Q) matmuls); across chunks only the (B, H, N, P)
state is carried.  Memory is O(S·d + Q^2) instead of O(S^2); FLOPs are
linear in S -- this is why mamba2/zamba2 are the archs that run the
``long_500k`` shape.

Decode carries (conv_state, ssm_state) and is O(1) per token.

Sharding design (DESIGN.md Sec. 5): every d_inner tensor is kept natively
in (H, P) head-feature form -- projections are (D, H, P), the causal conv
runs per (H, P) channel -- and the *feature* dim P (64 for every assigned
ssm arch) is sharded over the model axis.  There is therefore no
(B,S,d_inner) <-> (B,S,H,P) reshape across incompatible shardings, which
would otherwise force a full activation all-gather per layer; H never needs
to divide the mesh (mamba2-130m has 24 heads on a 16-wide axis).  S stays
unsharded inside ssm streams so the chunk scan slices an unsharded dim.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import compat

from repro.models.common import ShardCtx, dense_init


def init_mamba2(rng, cfg):
    D = cfg.d_model
    N, H, Pd = cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_headdim
    G = cfg.ssm_ngroups
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(rng, 8)
    dt_init = jnp.exp(jax.random.uniform(ks[6], (H,), jnp.float32,
                                         jnp.log(0.001), jnp.log(0.1)))
    return {
        "w_z": dense_init(ks[0], (D, H, Pd), dt, fan_in=D),
        "w_x": dense_init(ks[1], (D, H, Pd), dt, fan_in=D),
        "w_B": dense_init(ks[2], (D, G * N), dt, fan_in=D),
        "w_C": dense_init(ks[3], (D, G * N), dt, fan_in=D),
        "w_dt": dense_init(ks[4], (D, H), dt, fan_in=D),
        "conv_x": dense_init(ks[5], (cfg.ssm_conv, H, Pd), dt,
                             fan_in=cfg.ssm_conv),
        "A_log": jnp.log(jax.random.uniform(ks[7], (H,), jnp.float32,
                                            1.0, 16.0)).astype(jnp.float32),
        "dt_bias": (dt_init + jnp.log(-jnp.expm1(-dt_init))).astype(
            jnp.float32),
        "D_skip": jnp.ones((H,), jnp.float32),
        "norm": jnp.ones((H, Pd), dt),
        "w_out": dense_init(jax.random.fold_in(ks[0], 9), (H, Pd, D), dt,
                            fan_in=H * Pd),
    }


def mamba2_specs(cfg):
    from jax.sharding import PartitionSpec as P
    return {"w_z": P("data", None, "model"), "w_x": P("data", None, "model"),
            "w_B": P("data", None), "w_C": P("data", None),
            "w_dt": P("data", None), "conv_x": P(None, None, "model"),
            "A_log": P(None), "dt_bias": P(None), "D_skip": P(None),
            "norm": P(None, "model"), "w_out": P(None, "model", "data")}


def _causal_conv_hp(x, w, state=None):
    """Depthwise causal conv along S on (B, S, H, P) channels; w: (K, H, P).

    state: (B, K-1, H, P) previous inputs for decode.
    """
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], K - 1) + x.shape[2:], x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)          # (B, S+K-1, H, P)
    y = sum(xp[:, i:i + x.shape[1]] * w[i][None, None] for i in range(K))
    return y, xp[:, -(K - 1):]


def _ssd_chunk_scan(xh, dt, A, Bm, Cm, Dsk, chunk: int, pin=lambda x: x):
    """Chunked SSD. xh: (B,S,H,P); dt: (B,S,H); Bm/Cm: (B,S,N) (G=1).

    ``pin`` pins the (B,Q,Q,H) decay/mask intermediates to a known sharding
    (replicated over the model axis) so GSPMD never re-shards inside the
    scan.  Single lax.scan over S/chunk chunks carrying the (B,H,N,P) state.
    """
    Bsz, S, H, Pd = xh.shape
    N = Bm.shape[-1]
    nc = S // chunk
    Q = chunk
    xc = xh.reshape(Bsz, nc, Q, H, Pd).swapaxes(0, 1)     # (nc,B,Q,H,P)
    dtc = dt.reshape(Bsz, nc, Q, H).swapaxes(0, 1)
    Bc = Bm.reshape(Bsz, nc, Q, N).swapaxes(0, 1)
    Cc = Cm.reshape(Bsz, nc, Q, N).swapaxes(0, 1)

    def body(h, inputs):
        x, d, b, c = inputs                # (B,Q,H,P),(B,Q,H),(B,Q,N),(B,Q,N)
        a = d * A[None, None, :]           # (B,Q,H) negative
        cums = jnp.cumsum(a, axis=1)       # inclusive
        # intra-chunk: masked decay matrix per head
        dec = cums[:, :, None, :] - cums[:, None, :, :]    # (B,Q,Q,H) i,j
        tri = jnp.tril(jnp.ones((Q, Q), bool))
        dec = jnp.where(tri[None, :, :, None], dec, -jnp.inf)
        L = pin(jnp.exp(dec))
        cb = jnp.einsum("bqn,bkn->bqk", c, b)              # (B,Q,Q)
        M = pin(cb[..., None] * L)                         # (B,Q,Q,H)
        xdt = x * d[..., None]                             # (B,Q,H,P)
        y = jnp.einsum("bqkh,bkhp->bqhp", M, xdt)
        # inter-chunk: contribution of the incoming state
        y = y + jnp.einsum("bqn,bhnp->bqhp", c, h) \
            * jnp.exp(cums)[..., None]
        # new state
        decay_to_end = jnp.exp(cums[:, -1:, :] - cums)     # (B,Q,H)
        s_new = jnp.einsum("bkn,bkhp->bhnp", b,
                           xdt * decay_to_end[..., None])
        h = h * jnp.exp(cums[:, -1, :])[:, :, None, None] + s_new
        return h, y

    h0 = jnp.zeros((Bsz, H, N, Pd), jnp.float32)
    _, ys = jax.lax.scan(body, h0, (xc.astype(jnp.float32),
                                    dtc.astype(jnp.float32),
                                    Bc.astype(jnp.float32),
                                    Cc.astype(jnp.float32)))
    y = ys.swapaxes(0, 1).reshape(Bsz, S, H, Pd)
    return y + xh.astype(jnp.float32) * Dsk[None, None, :, None]


def ssd_reference(xh, dt, A, Bm, Cm, Dsk):
    """Naive O(S) recurrence oracle (tests): same inputs as _ssd_chunk_scan."""
    Bsz, S, H, Pd = xh.shape

    def body(h, inp):
        x, d, b, c = inp
        da = jnp.exp(d * A)                                # (B,H)
        h = h * da[:, :, None, None] + jnp.einsum(
            "bn,bhp->bhnp", b, x * d[..., None])
        y = jnp.einsum("bn,bhnp->bhp", c, h)
        return h, y

    h0 = jnp.zeros((Bsz, H, Bm.shape[-1], Pd), jnp.float32)
    _, ys = jax.lax.scan(body, h0, (xh.swapaxes(0, 1).astype(jnp.float32),
                                    dt.swapaxes(0, 1).astype(jnp.float32),
                                    Bm.swapaxes(0, 1).astype(jnp.float32),
                                    Cm.swapaxes(0, 1).astype(jnp.float32)))
    y = ys.swapaxes(0, 1)
    return y + xh.astype(jnp.float32) * Dsk[None, None, :, None]


def _gated_norm(y, z, scale, eps: float = 1e-6):
    """RMSNormGated over the flattened (H, P) feature dims."""
    y32 = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(y32 * y32, axis=(-2, -1), keepdims=True)
    return (y32 * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)


def mamba2_apply(p, h, cfg, ctx: ShardCtx, *, cache=None, use_reference=False):
    """h: (B, S, D) -> (out, new_cache).  cache: dict(conv, ssm) for decode."""
    B, S, D = h.shape
    N, H, Pd = cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_headdim
    cd = jnp.dtype(cfg.compute_dtype)
    h = h.astype(cd)

    hp = lambda t: ctx.constrain(t, ctx.batch_spec, None, None, ctx.model)
    z = hp(jnp.einsum("bsd,dhp->bshp", h, p["w_z"].astype(cd)))
    x = hp(jnp.einsum("bsd,dhp->bshp", h, p["w_x"].astype(cd)))
    Bm = ctx.constrain(h @ p["w_B"].astype(cd), ctx.batch_spec, None, None)
    Cm = ctx.constrain(h @ p["w_C"].astype(cd), ctx.batch_spec, None, None)
    dt_raw = h @ p["w_dt"].astype(cd)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"][None, None, :])
    # §Perf H8: cross the layer boundary in compute dtype (the SSD scan
    # upcasts internally); keeps the stream-grad all-reduce out of f32
    dt = ctx.constrain(dt.astype(cd), ctx.batch_spec, None, None)
    A = -jnp.exp(p["A_log"])

    new_cache = None
    if cache is None:
        x, _ = _causal_conv_hp(x, p["conv_x"].astype(cd))
        xh = hp(jax.nn.silu(x.astype(jnp.float32)).astype(cd))
        if use_reference:
            fn = ssd_reference
        elif ctx.mesh is not None:
            # §Perf H6: shard_map makes the P-sharding explicit, so the
            # backward's dM = gy . xdt partial products stay LOCAL and the
            # psum lands on the small (B,Q,Q)/(B,Q,H) grads after the head
            # contraction (GSPMD AR'd the full (B,Q,Q,H) tensor per chunk).
            from jax.sharding import PartitionSpec as P
            b = ctx.batch_spec
            m = ctx.model

            def fn(xh_, dt_, A_, Bm_, Cm_, Dsk_):
                inner = lambda *a: _ssd_chunk_scan(
                    *a, chunk=min(cfg.ssm_chunk, S))
                return compat.shard_map(
                    inner, mesh=ctx.mesh,
                    in_specs=(P(b, None, None, m), P(b, None, None), P(None),
                              P(b, None, None), P(b, None, None), P(None)),
                    out_specs=P(b, None, None, m), check_vma=False)(
                        xh_, dt_, A_, Bm_, Cm_, Dsk_)
        else:
            fn = lambda *a: _ssd_chunk_scan(*a, chunk=min(cfg.ssm_chunk, S))
        y = hp(fn(xh, dt, A, Bm, Cm, p["D_skip"]))
    else:
        xconv, conv_state = _causal_conv_hp(x, p["conv_x"].astype(cd),
                                            state=cache["conv"])
        xh = jax.nn.silu(xconv.astype(jnp.float32)).astype(cd)
        da = jnp.exp(dt[:, 0] * A[None, :])                # (B,H)
        ssm = cache["ssm"] * da[:, :, None, None] + jnp.einsum(
            "bn,bhp->bhnp", Bm[:, 0].astype(jnp.float32),
            xh[:, 0].astype(jnp.float32) * dt[:, 0, :, None])
        y = jnp.einsum("bn,bhnp->bhp", Cm[:, 0].astype(jnp.float32), ssm)
        y = (y + xh[:, 0].astype(jnp.float32)
             * p["D_skip"][None, :, None])[:, None]
        new_cache = {"conv": conv_state.astype(cache["conv"].dtype),
                     "ssm": ssm}

    y = _gated_norm(y, z, p["norm"]).astype(cd)            # (B,S,H,P)
    return jnp.einsum("bshp,hpd->bsd", y, p["w_out"].astype(cd)), new_cache

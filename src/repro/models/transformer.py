"""LMModel: embedding + scanned layer stack + loss / decode plumbing.

One class covers all 10 assigned architectures, dispatching on
``cfg.family``:

  dense / vlm / audio : scan over dense GQA blocks
  gemma2              : scan over (local, global) pairs, sandwich norms
  moe                 : scan over MoE blocks (+ optional dense first layer)
  ssm                 : scan over Mamba2 blocks
  hybrid              : scan over Zamba2 super-blocks with a shared attn block

Layers are stacked (leading L dim) and applied with ``lax.scan`` so the HLO
stays one-layer-sized; ``cfg.remat`` wraps the scan body in
``jax.checkpoint`` (nothing saved but the carry).  The residual-stream carry
is sharding-constrained to (batch, model-on-S, None) -- Megatron-style
sequence parallelism for saved activations.

Modality stubs per assignment: ``input_mode='embeds'`` (musicgen) consumes
precomputed frame embeddings; chameleon's VQ image tokens live inside its
65536-entry vocab so it stays token-mode.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import blocks as B
from repro.models.common import (NO_SHARD, ShardCtx, cross_entropy_chunked,
                                 embed_init, rms_norm)


def _stack_specs(spec_tree, n_lead: int = 1):
    return jax.tree.map(lambda s: P(*([None] * n_lead), *s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


class LMModel:
    def __init__(self, cfg: ArchConfig, ctx: ShardCtx = NO_SHARD):
        self.cfg = cfg
        self.ctx = ctx
        fam = cfg.family
        if fam == "gemma2":
            assert cfg.n_layers % 2 == 0
            self.n_stack = cfg.n_layers // 2
            self._init_block = B.init_gemma_pair
            self._block_specs = B.gemma_pair_specs
            self._apply_block = B.gemma_pair_apply
        elif fam == "moe":
            self.n_stack = cfg.n_layers - (1 if cfg.moe_dense_first else 0)
            self._init_block = B.init_moe_block
            self._block_specs = B.moe_block_specs
            self._apply_block = B.moe_block_apply
        elif fam == "ssm":
            self.n_stack = cfg.n_layers
            self._init_block = B.init_mamba_block
            self._block_specs = B.mamba_block_specs
            self._apply_block = B.mamba_block_apply
        elif fam == "hybrid":
            assert cfg.n_layers % cfg.shared_attn_every == 0
            self.n_stack = cfg.n_layers // cfg.shared_attn_every
            self._init_block = B.init_zamba_super
            self._block_specs = B.zamba_super_specs
            self._apply_block = None      # special-cased (shared params)
        else:                             # dense / vlm / audio
            self.n_stack = cfg.n_layers
            self._init_block = B.init_dense_block
            self._block_specs = B.dense_block_specs
            self._apply_block = B.dense_block_apply

    # ------------------------------------------------------------------
    # Parameters

    def init_params(self, rng) -> Dict[str, Any]:
        cfg = self.cfg
        dt = jnp.dtype(cfg.param_dtype)
        ks = jax.random.split(rng, 8)
        p: Dict[str, Any] = {}
        if cfg.input_mode == "tokens":
            p["embed"] = embed_init(ks[0], (cfg.vocab_size, cfg.d_model), dt)
        layer_rngs = jnp.stack(jax.random.split(ks[1], self.n_stack))
        p["blocks"] = jax.vmap(lambda r: self._init_block(r, cfg))(layer_rngs)
        if cfg.family == "hybrid":
            p["shared"] = B.init_dense_block(ks[2], cfg)
        if cfg.family == "moe" and cfg.moe_dense_first:
            p["first"] = B.init_moe_block(ks[3], cfg, dense_ffn=True)
        p["final_norm"] = jnp.zeros((cfg.d_model,), dt) + (
            0.0 if cfg.norm_plus_one else 1.0)
        if not cfg.tie_embeddings or cfg.input_mode == "embeds":
            p["lm_head"] = embed_init(ks[4], (cfg.d_model, cfg.vocab_size),
                                      dt) * cfg.d_model ** -0.5
        return p

    def param_specs(self) -> Dict[str, Any]:
        cfg = self.cfg
        s: Dict[str, Any] = {}
        if cfg.input_mode == "tokens":
            s["embed"] = P("model", "data")
        s["blocks"] = _stack_specs(self._block_specs(cfg))
        if cfg.family == "hybrid":
            s["shared"] = B.dense_block_specs(cfg)
        if cfg.family == "moe" and cfg.moe_dense_first:
            s["first"] = B.moe_block_specs(cfg, dense_ffn=True)
        s["final_norm"] = P(None)
        if not cfg.tie_embeddings or cfg.input_mode == "embeds":
            s["lm_head"] = P("data", "model")
        return s

    # ------------------------------------------------------------------
    # Forward

    def _embed_in(self, p, inputs):
        cfg = self.cfg
        cd = jnp.dtype(cfg.compute_dtype)
        if cfg.input_mode == "tokens":
            h = p["embed"][inputs].astype(cd)
        else:
            h = inputs.astype(cd)
        if cfg.scale_embeddings:
            h = h * jnp.asarray(cfg.d_model ** 0.5, cd)
        return h

    def _logits_fn(self, p):
        cfg = self.cfg
        cd = jnp.dtype(cfg.compute_dtype)
        head = (p["embed"].T if (cfg.tie_embeddings
                                 and cfg.input_mode == "tokens"
                                 and "lm_head" not in p)
                else p["lm_head"])
        return lambda h: h.astype(cd) @ head.astype(cd)

    def _constrain_stream(self, h):
        # sequence parallelism: saved residual stream is model-sharded on S.
        # ssm/hybrid streams keep S unsharded (the SSD chunk scan slices S;
        # the mixer shards its head-feature dim over the model axis instead).
        if h.shape[1] >= 2 and self.cfg.family not in ("ssm", "hybrid"):
            return self.ctx.constrain(h, self.ctx.batch_spec, self.ctx.model,
                                      None)
        return self.ctx.constrain(h, self.ctx.batch_spec, None, None)

    def _fsdp_gather(self, bp, specs):
        """ZeRO-3: transiently all-gather block weights over the data/pod
        axes (storage stays fully sharded); the model-axis TP sharding is
        kept.  Pinning this stops GSPMD from turning data-sharded
        contractions into huge activation all-reduces."""
        ctx = self.ctx
        if ctx.mesh is None:
            return bp

        drop = {"data", "pod"}
        if ctx.model is None:            # pure-DP: weights gather fully
            drop = drop | {"model"}

        def drop_data(entry):
            if entry is None:
                return None
            axes = entry if isinstance(entry, tuple) else (entry,)
            keep = tuple(a for a in axes if a not in drop)
            return keep if len(keep) > 1 else (keep[0] if keep else None)

        def one(spec, w):
            return ctx.constrain(w, *[drop_data(e) for e in spec])

        from jax.sharding import PartitionSpec as PS
        return jax.tree.map(one, specs, bp,
                            is_leaf=lambda x: isinstance(x, PS))

    def _run_stack(self, p, h, *, positions=None, cache=None, cur_len=None):
        cfg, ctx = self.cfg, self.ctx
        decode = cache is not None
        block_specs = self._block_specs(cfg)

        if cfg.family == "hybrid":
            shared_gathered = self._fsdp_gather(
                p["shared"], B.dense_block_specs(cfg))

            def body(carry, xs):
                hh = self._constrain_stream(carry)
                bp, bc = xs
                bp = self._fsdp_gather(bp, block_specs)
                hh, nc, aux = B.zamba_super_apply(
                    bp, shared_gathered, hh, cfg, ctx, positions=positions,
                    cache=bc, cur_len=cur_len)
                return hh, (nc, aux)
        elif cfg.family == "moe":
            def body(carry, xs):
                hh = self._constrain_stream(carry)
                bp, bc = xs
                bp = self._fsdp_gather(bp, block_specs)
                hh, nc, aux = B.moe_block_apply(
                    bp, hh, cfg, ctx, positions=positions, cache=bc,
                    cur_len=cur_len)
                return hh, (nc, aux)
        else:
            apply_block = self._apply_block

            def body(carry, xs):
                hh = self._constrain_stream(carry)
                bp, bc = xs
                bp = self._fsdp_gather(bp, block_specs)
                hh, nc, aux = apply_block(bp, hh, cfg, ctx,
                                          positions=positions, cache=bc,
                                          cur_len=cur_len)
                return hh, (nc, aux)

        if cfg.remat and not decode and cfg.remat_policy != "none":
            policy = {
                "nothing": jax.checkpoint_policies.nothing_saveable,
                "dots_no_batch":
                    jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            }[cfg.remat_policy]
            body = jax.checkpoint(body, policy=policy)

        aux0 = None
        new_first = None
        if cfg.family == "moe" and cfg.moe_dense_first:
            fc = cache["first"] if decode else None
            h, new_first, aux0 = B.moe_block_apply(
                p["first"], h, cfg, ctx, positions=positions, cache=fc,
                cur_len=cur_len, dense_ffn=True)

        blocks_cache = (cache["blocks"] if decode else None)
        h, (new_blocks, auxs) = jax.lax.scan(body, h,
                                             (p["blocks"], blocks_cache))
        aux = jax.tree.map(lambda a: jnp.mean(a), auxs)
        if aux0 is not None:
            pass  # dense first layer has zero aux
        new_cache = None
        if decode:
            new_cache = dict(cache)
            new_cache["blocks"] = new_blocks
            if new_first is not None:
                new_cache["first"] = new_first
        return h, new_cache, aux

    def hidden_states(self, p, inputs):
        """Final (pre-head) hidden states -- used by embed_latents."""
        h = self._embed_in(p, inputs)
        S = h.shape[1]
        positions = jnp.arange(S)[None, :]
        h, _, _ = self._run_stack(p, h, positions=positions)
        return rms_norm(h, p["final_norm"], plus_one=self.cfg.norm_plus_one)

    def apply_train(self, p, inputs, labels, valid=None):
        """Causal-LM loss (alias of loss_and_aux)."""
        return self.loss_and_aux(p, inputs, labels, valid=valid)

    def loss_and_aux(self, p, inputs, labels, valid=None):
        """Train loss including MoE aux terms (the train_step entry point)."""
        cfg = self.cfg
        h = self._embed_in(p, inputs)
        S = h.shape[1]
        positions = jnp.arange(S)[None, :]
        h, _, aux = self._run_stack(p, h, positions=positions)
        h = rms_norm(h, p["final_norm"], plus_one=cfg.norm_plus_one)
        h = self._constrain_stream(h)
        nll, n_tok = cross_entropy_chunked(
            self._logits_fn(p), h, labels, n_chunks=cfg.logits_chunks,
            final_softcap=cfg.final_softcap, valid=valid)
        total = nll
        if cfg.is_moe:
            total = total + cfg.router_aux_weight * aux["load_balance"] \
                + cfg.router_z_weight * aux["router_z"]
        metrics = {"nll": nll, "tokens": n_tok, **aux}
        return total, metrics

    # ------------------------------------------------------------------
    # Serving

    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        cfg = self.cfg

        def kv(n_lead=()):
            shape = (*n_lead, batch, max_len, cfg.n_kv_heads,
                     cfg.resolved_head_dim)
            return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}

        def mamba(n_lead=()):
            return {"conv": jnp.zeros((*n_lead, batch, cfg.ssm_conv - 1,
                                       cfg.ssm_nheads, cfg.ssm_headdim),
                                      dtype),
                    "ssm": jnp.zeros((*n_lead, batch, cfg.ssm_nheads,
                                      cfg.ssm_state, cfg.ssm_headdim),
                                     jnp.float32)}

        L = self.n_stack
        if cfg.family == "gemma2":
            blocks = {"local": kv((L,)), "global": kv((L,))}
        elif cfg.family == "moe" and cfg.is_mla:
            blocks = {"latent": jnp.zeros((L, batch, max_len,
                                           cfg.kv_lora_rank), dtype),
                      "k_rope": jnp.zeros((L, batch, max_len,
                                           cfg.q_rope_dim), dtype)}
        elif cfg.family == "moe":
            blocks = kv((L,))
        elif cfg.family == "ssm":
            blocks = mamba((L,))
        elif cfg.family == "hybrid":
            blocks = {"mamba": mamba((L, cfg.shared_attn_every)),
                      "attn": kv((L,))}
        else:
            blocks = kv((L,))
        cache = {"blocks": blocks}
        if cfg.family == "moe" and cfg.moe_dense_first:
            if cfg.is_mla:
                cache["first"] = {
                    "latent": jnp.zeros((batch, max_len, cfg.kv_lora_rank),
                                        dtype),
                    "k_rope": jnp.zeros((batch, max_len, cfg.q_rope_dim),
                                        dtype)}
            else:
                cache["first"] = kv()
        return cache

    def cache_specs(self):
        """PartitionSpec tree matching init_cache: batch->data axes, cache
        sequence dim -> model axis (flash-decoding split-K under GSPMD)."""
        cfg = self.cfg
        b = self.ctx.batch_spec
        m = self.ctx.model

        def kv(n_lead: int):
            lead = (None,) * n_lead
            s = P(*lead, b, m, None, None)
            return {"k": s, "v": s}

        def mamba(n_lead: int):
            # SSD state shards over the head-feature dim P (= 64: divides
            # the model axis for every assigned ssm arch; H need not)
            lead = (None,) * n_lead
            return {"conv": P(*lead, b, None, None, m),
                    "ssm": P(*lead, b, None, None, m)}

        if cfg.family == "gemma2":
            blocks = {"local": kv(1), "global": kv(1)}
        elif cfg.family == "moe" and cfg.is_mla:
            blocks = {"latent": P(None, b, m, None),
                      "k_rope": P(None, b, m, None)}
        elif cfg.family == "ssm":
            blocks = mamba(1)
        elif cfg.family == "hybrid":
            blocks = {"mamba": mamba(2), "attn": kv(1)}
        else:
            blocks = kv(1)
        cache = {"blocks": blocks}
        if cfg.family == "moe" and cfg.moe_dense_first:
            cache["first"] = ({"latent": P(b, m, None),
                               "k_rope": P(b, m, None)} if cfg.is_mla
                              else {"k": P(b, m, None, None),
                                    "v": P(b, m, None, None)})
        return cache

    def serve_step(self, p, cache, inputs, cur_len):
        """One decode step.  inputs: (B, 1) tokens or (B, 1, D) embeds;
        cur_len: () int32 length including the new token."""
        h = self._embed_in(p, inputs)
        h, new_cache, _ = self._run_stack(p, h, cache=cache, cur_len=cur_len)
        h = rms_norm(h, p["final_norm"], plus_one=self.cfg.norm_plus_one)
        logits = self._logits_fn(p)(h)
        if self.cfg.final_softcap:
            logits = self.cfg.final_softcap * jnp.tanh(
                logits.astype(jnp.float32) / self.cfg.final_softcap)
        return logits, new_cache

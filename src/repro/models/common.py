"""Shared model primitives: norms, RoPE, init, sharding, chunked CE."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P


# --------------------------------------------------------------------------
# Sharding helper: no-op without a mesh so single-device tests stay clean.


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """Carries the mesh + axis names used for sharding constraints.

    batch axes: data-parallel axes for the batch dim ('pod','data' multi-pod);
    model axis: tensor/expert/sequence-parallel axis.
    """
    mesh: Optional[object] = None
    batch: tuple = ("data",)
    model: Optional[str] = "model"   # None => pure-DP (model axis in batch)

    def constrain(self, x, *spec):
        """with_sharding_constraint with divisibility sanitisation: spec
        entries whose mesh-axis product does not divide the dim are dropped
        (e.g. 4 KV heads on a 16-wide model axis -> replicated heads)."""
        if self.mesh is None:
            return x
        entries = list(spec) + [None] * (x.ndim - len(spec))
        clean = []
        for dim, entry in zip(x.shape, entries):
            if entry is None:
                clean.append(None)
                continue
            axes = entry if isinstance(entry, (tuple, list)) else (entry,)
            size = 1
            for a in axes:
                size *= self.mesh.shape[a]
            clean.append(entry if size > 1 and dim % size == 0 else None)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*clean)))

    @property
    def batch_spec(self):
        return self.batch if len(self.batch) > 1 else self.batch[0]

    @property
    def model_size(self) -> int:
        if self.mesh is None or self.model is None:
            return 1
        return self.mesh.shape[self.model]


NO_SHARD = ShardCtx()


# --------------------------------------------------------------------------
# Norms / activations


def rms_norm(x, scale, eps: float = 1e-6, *, plus_one: bool = False):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    s = (1.0 + scale.astype(jnp.float32)) if plus_one \
        else scale.astype(jnp.float32)
    return (y * s).astype(dtype)


def swiglu(gate, up):
    return jax.nn.silu(gate.astype(jnp.float32)).astype(gate.dtype) * up


def gelu(x):
    return jax.nn.gelu(x.astype(jnp.float32), approximate=True).astype(x.dtype)


def softcap(x, cap: float):
    """Gemma2 logit soft-capping: cap * tanh(x / cap)."""
    if not cap:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


# --------------------------------------------------------------------------
# RoPE


def rope_freqs(head_dim: int, theta: float = 10000.0):
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponents)                 # (head_dim/2,)


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., S, H, D) or (..., S, D); positions: (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                      # (d/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, d/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    if x.ndim == ang.ndim + 1:                        # (..., S, H, D)
        cos, sin = cos[..., None, :], sin[..., None, :]
    x32 = x.astype(jnp.float32)
    x1, x2 = x32[..., ::2], x32[..., 1::2]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Initialisers


def dense_init(rng, shape, dtype, fan_in: Optional[int] = None):
    fan_in = fan_in if fan_in is not None else shape[0]
    std = fan_in ** -0.5
    return (jax.random.normal(rng, shape, jnp.float32) * std).astype(dtype)


def embed_init(rng, shape, dtype):
    return (jax.random.normal(rng, shape, jnp.float32)).astype(dtype)


# --------------------------------------------------------------------------
# Loss


def cross_entropy_chunked(logits_fn, hidden, labels, *, n_chunks: int = 1,
                          final_softcap: float = 0.0, valid=None):
    """Causal-LM CE computed over sequence chunks.

    logits_fn: hidden chunk (B, s, D) -> logits (B, s, V).  Chunking bounds
    the peak (B, s, V) activation (256k-vocab archs) and keeps the matmul
    sharded over the model axis.
    Returns (mean_nll, n_tokens).
    """
    B, S, _ = hidden.shape
    assert S % n_chunks == 0
    s = S // n_chunks
    if valid is None:
        valid = jnp.ones((B, S), bool)

    def one(h, y, v):
        logits = logits_fn(h).astype(jnp.float32)
        if final_softcap:
            logits = final_softcap * jnp.tanh(logits / final_softcap)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * v
        return jnp.sum(nll), jnp.sum(v)

    if n_chunks == 1:
        tot, cnt = one(hidden, labels, valid.astype(jnp.float32))
    else:
        hs = hidden.reshape(B, n_chunks, s, -1).swapaxes(0, 1)
        ys = labels.reshape(B, n_chunks, s).swapaxes(0, 1)
        vs = valid.reshape(B, n_chunks, s).swapaxes(0, 1).astype(jnp.float32)

        def body(carry, xs):
            h, y, v = xs
            t, c = one(h, y, v)
            return (carry[0] + t, carry[1] + c), None

        (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)),
                                     (hs, ys, vs))
    return tot / jnp.maximum(cnt, 1.0), cnt

"""Token-choice MoE with argsort dispatch and expert parallelism.

TPU adaptation: instead of GShard's (T, E, C) one-hot dispatch einsum
(O(T*E*C) memory) we sort token->expert assignments once per layer
(argsort over T*k elements), bucket them into an (E, C, D) buffer with
capacity C = ceil(T*k/E * capacity_factor), run a batched per-expert GEMM,
and scatter-add the results back weighted by router probs.  The (E, ...)
dims are sharded over the `model` axis (EP); under GSPMD the gather/scatter
between token-sharded and expert-sharded layouts lowers to all-to-alls.

Aux losses: Switch-style load-balance + router z-loss, returned to the
caller for weighting into the train loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import compat

from repro.models.common import ShardCtx, dense_init, swiglu


def moe_capacity(n_tokens: int, n_experts: int, top_k: int,
                 capacity_factor: float, round_to: int = 128) -> int:
    """round_to=128 for the GSPMD path (the (E, C, D) buffer shards C over
    the batch axes); the shard_map a2a path uses per-chip-local buffers and
    rounds to 8 only."""
    c = int(n_tokens * top_k / n_experts * capacity_factor) + 1
    return max(round_to, -(-c // round_to) * round_to)


def init_moe(rng, cfg):
    D, E, F = cfg.d_model, cfg.n_experts, cfg.d_ff_expert or cfg.d_ff
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(rng, 6)
    p = {
        "router": dense_init(ks[0], (D, E), jnp.float32, fan_in=D),
        "w_gate": dense_init(ks[1], (E, D, F), dt, fan_in=D),
        "w_up": dense_init(ks[2], (E, D, F), dt, fan_in=D),
        "w_down": dense_init(ks[3], (E, F, D), dt, fan_in=F),
    }
    if cfg.n_shared_experts:
        Fs = F * cfg.n_shared_experts
        p["shared_gate"] = dense_init(ks[4], (D, Fs), dt, fan_in=D)
        p["shared_up"] = dense_init(ks[5], (D, Fs), dt, fan_in=D)
        p["shared_down"] = dense_init(
            jax.random.fold_in(ks[4], 7), (Fs, D), dt, fan_in=Fs)
    return p


def moe_specs(cfg):
    from jax.sharding import PartitionSpec as P
    s = {"router": P(None, None),
         "w_gate": P("model", "data", None),
         "w_up": P("model", "data", None),
         "w_down": P("model", None, "data")}
    if cfg.n_shared_experts:
        s.update({"shared_gate": P("data", "model"),
                  "shared_up": P("data", "model"),
                  "shared_down": P("model", "data")})
    return s


def moe_apply(p, x, cfg, ctx: ShardCtx):
    """x: (T, D) flat tokens -> (out (T, D), aux dict)."""
    T, D = x.shape
    E, k = cfg.n_experts, cfg.moe_top_k
    cd = jnp.dtype(cfg.compute_dtype)
    C = moe_capacity(T, E, k, cfg.capacity_factor)

    logits = (x.astype(jnp.float32) @ p["router"])          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                  # (T, k)

    flat_e = top_e.reshape(-1).astype(jnp.int32)            # (T*k,)
    flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    flat_p = top_p.reshape(-1)
    order = jnp.argsort(flat_e)
    se, stok, sp = flat_e[order], flat_t[order], flat_p[order]
    starts = jnp.searchsorted(se, jnp.arange(E, dtype=jnp.int32))
    pos = jnp.arange(T * k, dtype=jnp.int32) - starts[se]
    keep = pos < C
    posc = jnp.clip(pos, 0, C - 1)

    gathered = x[stok].astype(cd) * keep[:, None].astype(cd)
    buf = jnp.zeros((E, C, D), cd).at[se, posc].add(gathered)
    # EP layout: experts over the model axis, per-expert token slots over
    # the batch axes -- the buffer holds T*k*cf token slots and must not
    # be replicated within a data shard.
    buf = ctx.constrain(buf, ctx.model, ctx.batch_spec, None)

    g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"].astype(cd))
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"].astype(cd))
    hmid = ctx.constrain(swiglu(g, u), ctx.model, ctx.batch_spec, None)
    out_e = jnp.einsum("ecf,efd->ecd", hmid, p["w_down"].astype(cd))
    out_e = ctx.constrain(out_e, ctx.model, ctx.batch_spec, None)

    contrib = out_e[se, posc] * (sp * keep)[:, None].astype(cd)
    out = jnp.zeros((T, D), cd).at[stok].add(contrib)

    if cfg.n_shared_experts:
        sh = swiglu(x.astype(cd) @ p["shared_gate"].astype(cd),
                    x.astype(cd) @ p["shared_up"].astype(cd))
        out = out + sh @ p["shared_down"].astype(cd)

    # aux: Switch load-balance (f_e * P_e) + z-loss
    me = jnp.mean(probs, axis=0)                            # mean router prob
    one_hot_counts = jnp.zeros((E,)).at[flat_e].add(1.0)
    fe = one_hot_counts / (T * k)
    aux = {
        "load_balance": E * jnp.sum(fe * me),
        "router_z": jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2),
        "dropped_frac": 1.0 - jnp.mean(keep.astype(jnp.float32)),
    }
    return out, aux


# ---------------------------------------------------------------------------
# shard_map all-to-all expert parallelism (§Perf hillclimb H1)
#
# The GSPMD path above lets the compiler mediate between token-sharded and
# expert-sharded layouts; measured on the production mesh it replicates the
# dispatch buffers (EXPERIMENTS.md §Perf).  This path makes the EP pipeline
# explicit: each chip routes its LOCAL tokens, packs an (E, C_loc, D) send
# buffer, all-to-alls expert slices across the model axis, runs the local
# expert GEMMs, and all-to-alls results back -- the only cross-chip traffic
# is 2x the routed token payload.


def _local_dispatch(x_loc, top_e, top_p, E, C_loc, cd):
    """Pack local tokens into per-expert slots; returns (buf, se, posc,
    keep, stok, sp)."""
    Tl, D = x_loc.shape
    k = top_e.shape[-1]
    flat_e = top_e.reshape(-1).astype(jnp.int32)
    flat_t = jnp.repeat(jnp.arange(Tl, dtype=jnp.int32), k)
    flat_p = top_p.reshape(-1)
    order = jnp.argsort(flat_e)
    se, stok, sp = flat_e[order], flat_t[order], flat_p[order]
    starts = jnp.searchsorted(se, jnp.arange(E, dtype=jnp.int32))
    pos = jnp.arange(Tl * k, dtype=jnp.int32) - starts[se]
    keep = pos < C_loc
    posc = jnp.clip(pos, 0, C_loc - 1)
    gathered = x_loc[stok].astype(cd) * keep[:, None].astype(cd)
    buf = jnp.zeros((E, C_loc, D), cd).at[se, posc].add(gathered)
    return buf, se, posc, keep, stok, sp


def moe_apply_a2a(p, x, cfg, ctx: ShardCtx):
    """x: (B, S, D) -> (out, aux).  Requires ctx.mesh; falls back to the
    GSPMD path on a single device."""
    if ctx.mesh is None:
        B, S, D = x.shape
        out, aux = moe_apply(p, x.reshape(B * S, D), cfg, ctx)
        return out.reshape(B, S, D), aux

    from jax.sharding import PartitionSpec as P
    cd = jnp.dtype(cfg.compute_dtype)
    E, k = cfg.n_experts, cfg.moe_top_k
    msize = ctx.model_size
    E_loc = E // msize
    mesh = ctx.mesh
    n_chips = mesh.size
    B, S, D = x.shape
    T_loc = max(1, (B * S) // n_chips)
    C_loc = moe_capacity(T_loc, E, k, cfg.capacity_factor, round_to=8)

    def local_fn(router_w, w_gate, w_up, w_down, x_bsd):
        Bl, Sl, _ = x_bsd.shape
        x_loc = x_bsd.reshape(Bl * Sl, D)
        logits = x_loc.astype(jnp.float32) @ router_w      # (Tl, E)
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_e = jax.lax.top_k(probs, k)
        send, se, posc, keep, stok, sp = _local_dispatch(
            x_loc, top_e, top_p, E, C_loc, cd)
        # (E, C, D) -> (msize, E_loc, C, D) -> exchange over the model axis
        send = send.reshape(msize, E_loc, C_loc, D)
        recv = jax.lax.all_to_all(send, ctx.model, split_axis=0,
                                  concat_axis=0, tiled=False)
        # recv: (msize, E_loc, C, D), dim0 = source shard
        hbuf = recv.transpose(1, 0, 2, 3).reshape(E_loc, msize * C_loc, D)
        g = jnp.einsum("ecd,edf->ecf", hbuf, w_gate.astype(cd))
        u = jnp.einsum("ecd,edf->ecf", hbuf, w_up.astype(cd))
        oe = jnp.einsum("ecf,efd->ecd", swiglu(g, u), w_down.astype(cd))
        back = oe.reshape(E_loc, msize, C_loc, D).transpose(1, 0, 2, 3)
        ret = jax.lax.all_to_all(back, ctx.model, split_axis=0,
                                 concat_axis=0, tiled=False)
        ret = ret.reshape(E, C_loc, D)                     # local slots again
        contrib = ret[se, posc] * (sp * keep)[:, None].astype(cd)
        out = jnp.zeros((Bl * Sl, D), cd).at[stok].add(contrib)

        axes = tuple(a for a in mesh.axis_names)
        me = jnp.mean(probs, axis=0)
        counts = jnp.zeros((E,)).at[top_e.reshape(-1)].add(1.0)
        fe = counts / (Bl * Sl * k)
        lb = E * jnp.sum(jax.lax.pmean(fe, axes) * jax.lax.pmean(me, axes))
        aux = {"load_balance": lb,
               "router_z": jax.lax.pmean(
                   jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2), axes),
               "dropped_frac": 1.0 - jax.lax.pmean(
                   jnp.mean(keep.astype(jnp.float32)), axes)}
        return out.reshape(Bl, Sl, D), aux

    baxes = ctx.batch_spec
    fn = compat.shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(), P(ctx.model, None, None), P(ctx.model, None, None),
                  P(ctx.model, None, None), P(baxes, ctx.model, None)),
        out_specs=(P(baxes, ctx.model, None),
                   {"load_balance": P(), "router_z": P(),
                    "dropped_frac": P()}),
        check_vma=False)
    out, aux = fn(p["router"], p["w_gate"], p["w_up"], p["w_down"], x)

    if cfg.n_shared_experts:
        sh = swiglu(x.astype(cd) @ p["shared_gate"].astype(cd),
                    x.astype(cd) @ p["shared_up"].astype(cd))
        out = out + sh @ p["shared_down"].astype(cd)
    return out, aux

from repro.optim.optimizers import adamw, sgdm, clip_by_global_norm  # noqa: F401
from repro.optim.schedules import warmup_cosine, constant  # noqa: F401

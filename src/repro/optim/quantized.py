"""Blockwise int8 quantisation for optimiser moments (8-bit Adam style).

Large assigned archs (deepseek-v2-236b, yi-34b, chameleon-34b) cannot hold
fp32 Adam moments in 16 GiB/chip; per-block absmax int8 moments cut the
optimiser-state footprint ~4x at negligible quality cost (Dettmers et al.).

Layout (H3 in EXPERIMENTS.md §Perf): the int8 payload keeps the PARAM'S
OWN SHAPE and blocks run along the last axis (block = largest divisor of
the last dim <= 256).  A flat (n_blocks, 256) layout forces a reshape
between incompatible shardings inside the optimiser -- measured as ~300 GB
f32 all-gathers per step on deepseek-v2 -- whereas the shape-preserving
layout lets q/scale inherit the parameter PartitionSpec verbatim.

``QTensor`` is a registered pytree with (q, scale) as children and the
original shape/block as static aux data.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256


def _block_for(last_dim: int) -> int:
    b = min(BLOCK, max(last_dim, 1))
    while last_dim % b:
        b -= 1
    return max(b, 1)


@jax.tree_util.register_pytree_node_class
class QTensor:
    def __init__(self, q, scale, shape, block=None):
        self.q = q            # int8, same shape as the source tensor
        self.scale = scale    # f32 (*shape[:-1], last/block)
        self.shape = tuple(shape)
        self.block = block if block is not None else (
            _block_for(self.shape[-1]) if self.shape else 1)

    def tree_flatten(self):
        return (self.q, self.scale), (self.shape, self.block)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux[0], aux[1])

    def __repr__(self):
        return f"QTensor(shape={self.shape}, block={self.block})"


def quantize(x) -> QTensor:
    x = jnp.asarray(x)
    shape = x.shape
    if x.ndim == 0:
        x = x.reshape(1)
    b = _block_for(x.shape[-1])
    blocks = x.astype(jnp.float32).reshape(*x.shape[:-1], -1, b)
    absmax = jnp.max(jnp.abs(blocks), axis=-1)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(blocks / scale[..., None]), -127, 127)
    q = q.astype(jnp.int8).reshape(x.shape)
    return QTensor(q=q, scale=scale, shape=shape, block=b)


def dequantize(t: QTensor) -> jnp.ndarray:
    q = t.q.astype(jnp.float32)
    blocks = q.reshape(*q.shape[:-1], -1, t.block)
    out = (blocks * t.scale[..., None]).reshape(q.shape)
    return out.reshape(t.shape)

"""Gradient compression for the DCN-crossing (pod) axis.

Top-k sparsification with error feedback (Deep Gradient Compression):
only the k largest-|g| entries participate in the cross-pod reduction;
the residual is carried into the next step, so the compression is unbiased
over time.  The compressed tensor is materialised as a masked dense array
before the psum -- on real hardware the wire format would be (values,
indices); the dry-run therefore reports the *uncompressed* collective
bytes and the compression ratio is recorded separately (EXPERIMENTS.md).

int8 gradient quantisation (stochastic rounding) is also provided for the
pure-DP pod axis where a 4x wire reduction matters more than exact top-k.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    residual: Any          # same structure as grads


def init_ef(grads_shape) -> EFState:
    return EFState(residual=jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_shape))


def topk_sparsify(g, k_frac: float):
    """Keep the k largest-magnitude entries; returns (sparse_dense, mask)."""
    flat = g.reshape(-1).astype(jnp.float32)
    k = max(1, int(flat.size * k_frac))
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    mask = jnp.abs(flat) >= thresh
    return (flat * mask).reshape(g.shape), mask.reshape(g.shape)


def compress_with_error_feedback(grads, ef: EFState, k_frac: float):
    """Returns (sparse grads to all-reduce, new EF state, mean density)."""
    def one(g, r):
        acc = g.astype(jnp.float32) + r
        sparse, mask = topk_sparsify(acc, k_frac)
        return sparse, acc - sparse, jnp.mean(mask.astype(jnp.float32))

    out = jax.tree.map(one, grads, ef.residual)
    leaves = lambda i: jax.tree.map(lambda t: t[i], out,
                                    is_leaf=lambda x: isinstance(x, tuple))
    sparse = leaves(0)
    new_ef = EFState(residual=leaves(1))
    dens = jnp.mean(jnp.stack(jax.tree.leaves(leaves(2))))
    return sparse, new_ef, dens


def quantize_int8_stochastic(g, rng):
    """Stochastic-rounding int8 quantisation of a gradient tensor."""
    g32 = g.astype(jnp.float32)
    absmax = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12)
    scale = absmax / 127.0
    scaled = g32 / scale
    noise = jax.random.uniform(rng, g.shape) - 0.5
    q = jnp.clip(jnp.round(scaled + noise), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale

"""Learning-rate schedules (count -> lr, 1-indexed step count)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda count: jnp.asarray(lr, jnp.float32)


def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1):
    def fn(count):
        c = count.astype(jnp.float32)
        warm = peak_lr * c / max(warmup_steps, 1)
        t = jnp.clip((c - warmup_steps) / max(total_steps - warmup_steps, 1),
                     0.0, 1.0)
        cos = peak_lr * (final_frac + (1 - final_frac)
                         * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(c < warmup_steps, warm, cos)
    return fn


def linear_decay(peak_lr: float, total_steps: int):
    def fn(count):
        t = jnp.clip(count.astype(jnp.float32) / total_steps, 0.0, 1.0)
        return peak_lr * (1.0 - t)
    return fn

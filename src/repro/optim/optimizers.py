"""Optimisers as (init, update) pairs over arbitrary param pytrees.

- ``adamw``: decoupled weight decay; ``moment_dtype='int8'`` stores m/v as
  blockwise-quantised QTensors (8-bit Adam) for the >30B assigned archs.
- ``sgdm``: momentum SGD (ablations / NE experiments).
- ``clip_by_global_norm``: standard pre-update gradient clip.

All state leaves are plain arrays / QTensors so the checkpointer and the
dry-run sharding logic treat them uniformly.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Union

import jax
import jax.numpy as jnp

from repro.optim.quantized import QTensor, dequantize, quantize

ScheduleOrFloat = Union[float, Callable[[jnp.ndarray], jnp.ndarray]]


def _lr_at(lr: ScheduleOrFloat, count):
    return lr(count) if callable(lr) else jnp.asarray(lr, jnp.float32)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(
        g.dtype), grads), gn


class AdamWState(NamedTuple):
    count: jnp.ndarray
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable


def adamw(lr: ScheduleOrFloat, *, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1,
          moment_dtype: str = "float32") -> Optimizer:
    quant = moment_dtype == "int8"

    def enc(x):
        return quantize(x) if quant else x

    def dec(x):
        return dequantize(x) if quant else x.astype(jnp.float32)

    def init(params):
        zeros = jax.tree.map(
            lambda p: enc(jnp.zeros(p.shape, jnp.float32)), params)
        zeros2 = jax.tree.map(
            lambda p: enc(jnp.zeros(p.shape, jnp.float32)), params)
        return AdamWState(count=jnp.zeros((), jnp.int32), m=zeros, v=zeros2)

    def update(grads, state: AdamWState, params):
        count = state.count + 1
        lr_t = _lr_at(lr, count)
        c1 = 1.0 - b1 ** count.astype(jnp.float32)
        c2 = 1.0 - b2 ** count.astype(jnp.float32)

        is_q = lambda x: isinstance(x, QTensor)

        def upd(g, m_old, v_old, p):
            g32 = g.astype(jnp.float32)
            m = b1 * dec(m_old) + (1.0 - b1) * g32
            v = b2 * dec(v_old) + (1.0 - b2) * g32 * g32
            step = (m / c1) / (jnp.sqrt(v / c2) + eps)
            step = step + weight_decay * p.astype(jnp.float32)
            newp = (p.astype(jnp.float32) - lr_t * step).astype(p.dtype)
            return newp, enc(m), enc(v)

        out = jax.tree.map(upd, grads, state.m, state.v, params,
                           is_leaf=lambda x: is_q(x) or x is None)
        newp = jax.tree.map(lambda t: t[0], out,
                            is_leaf=lambda x: isinstance(x, tuple)
                            and len(x) == 3 and not is_q(x))
        newm = jax.tree.map(lambda t: t[1], out,
                            is_leaf=lambda x: isinstance(x, tuple)
                            and len(x) == 3 and not is_q(x))
        newv = jax.tree.map(lambda t: t[2], out,
                            is_leaf=lambda x: isinstance(x, tuple)
                            and len(x) == 3 and not is_q(x))
        return newp, AdamWState(count=count, m=newm, v=newv)

    return Optimizer(init=init, update=update)


class SGDMState(NamedTuple):
    count: jnp.ndarray
    mom: Any


def sgdm(lr: ScheduleOrFloat, *, momentum: float = 0.9,
         nesterov: bool = False) -> Optimizer:
    def init(params):
        return SGDMState(count=jnp.zeros((), jnp.int32),
                         mom=jax.tree.map(
                             lambda p: jnp.zeros(p.shape, jnp.float32),
                             params))

    def update(grads, state: SGDMState, params):
        count = state.count + 1
        lr_t = _lr_at(lr, count)

        def upd(g, m, p):
            g32 = g.astype(jnp.float32)
            m = momentum * m + g32
            step = g32 + momentum * m if nesterov else m
            return (p.astype(jnp.float32) - lr_t * step).astype(p.dtype), m

        out = jax.tree.map(upd, grads, state.mom, params)
        newp = jax.tree.map(lambda t: t[0], out,
                            is_leaf=lambda x: isinstance(x, tuple))
        newm = jax.tree.map(lambda t: t[1], out,
                            is_leaf=lambda x: isinstance(x, tuple))
        return newp, SGDMState(count=count, mom=newm)

    return Optimizer(init=init, update=update)

from repro.kernels.ne_forces.ops import ne_forces  # noqa: F401

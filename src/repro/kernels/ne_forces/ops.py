"""Public jit'd wrapper for the fused NE force kernel."""
from __future__ import annotations

import jax

from repro.kernels.ne_forces.kernel import ne_forces_pallas
from repro.kernels.ne_forces.ref import ne_forces_ref


def _default_backend() -> str:
    try:
        platform = jax.devices()[0].platform
    except Exception:  # pragma: no cover
        platform = "cpu"
    return "pallas" if platform == "tpu" else "xla"


def ne_forces(y, nbr, coef, alpha, *, mode: str, backend: str = "auto"):
    """Fused variable-tail force evaluation; see ref.py for semantics."""
    if backend == "auto":
        backend = _default_backend()
    if backend == "pallas":
        return ne_forces_pallas(y, nbr, coef, alpha, mode=mode)
    if backend == "interpret":
        return ne_forces_pallas(y, nbr, coef, alpha, mode=mode, interpret=True)
    if backend == "xla":
        return ne_forces_ref(y, nbr, coef, alpha, mode=mode)
    raise ValueError(f"unknown backend {backend!r}")

"""Public jit'd wrapper for the fused NE force kernel."""
from __future__ import annotations

import jax

from repro.kernels.ne_forces.kernel import (ne_forces_gather_pallas,
                                            ne_forces_pallas)
from repro.kernels.ne_forces.ref import ne_forces_gather_ref, ne_forces_ref


def _default_backend() -> str:
    try:
        platform = jax.devices()[0].platform
    except Exception:  # pragma: no cover
        platform = "cpu"
    return "pallas" if platform == "tpu" else "xla"


def ne_forces(y, nbr, coef, alpha, *, mode: str, backend: str = "auto"):
    """Fused variable-tail force evaluation; see ref.py for semantics."""
    if backend == "auto":
        backend = _default_backend()
    if backend == "pallas":
        return ne_forces_pallas(y, nbr, coef, alpha, mode=mode)
    if backend == "interpret":
        return ne_forces_pallas(y, nbr, coef, alpha, mode=mode, interpret=True)
    if backend == "xla":
        return ne_forces_ref(y, nbr, coef, alpha, mode=mode)
    raise ValueError(f"unknown backend {backend!r}")


def ne_forces_gather(x, qid, nbr_idx, coef, alpha, *, segments,
                     emit_edges=None, backend: str = "auto"):
    """Index-taking, segmented force evaluation in ONE launch.

    Unlike :func:`ne_forces` the (B, K, d) gathered neighbour buffer is
    never materialised in HBM, and several neighbour segments (e.g. HD
    attraction + LD repulsion + negative samples) are evaluated over the
    concatenated neighbour axis in a single kernel launch: one read of the
    embedding instead of three.  ``segments`` is a static tuple of
    ``(mode, size)`` pairs; returns per-segment tuples (aggs, edges,
    wsums) -- see ref.py for semantics.
    """
    segments = tuple((str(m), int(s)) for m, s in segments)
    if emit_edges is not None:
        emit_edges = tuple(bool(e) for e in emit_edges)
    if backend == "auto":
        backend = _default_backend()
    if backend == "pallas":
        return ne_forces_gather_pallas(x, qid, nbr_idx, coef, alpha,
                                       segments=segments,
                                       emit_edges=emit_edges)
    if backend == "interpret":
        return ne_forces_gather_pallas(x, qid, nbr_idx, coef, alpha,
                                       segments=segments,
                                       emit_edges=emit_edges,
                                       interpret=True)
    if backend == "xla":
        return ne_forces_gather_ref(x, qid, nbr_idx, coef, alpha,
                                    segments=segments,
                                    emit_edges=emit_edges)
    raise ValueError(f"unknown backend {backend!r}")

"""Public jit'd wrapper for the fused NE force kernel."""
from __future__ import annotations

import jax

from repro.kernels import fallback
from repro.kernels.ne_forces.kernel import (ne_forces_gather_pallas,
                                            ne_forces_pallas,
                                            ne_forces_scatter_pallas)
from repro.kernels.ne_forces.ref import (ne_forces_gather_ref, ne_forces_ref,
                                         ne_forces_scatter_ref)


def _default_backend() -> str:
    try:
        platform = jax.devices()[0].platform
    except Exception:  # pragma: no cover
        platform = "cpu"
    return "pallas" if platform == "tpu" else "xla"


# VMEM budget for the scatter kernel's resident per-segment (chunk_n, d)
# slabs.  Mosaic pads the trailing dim to the 128-lane tile and all S
# segment slabs stay resident for a whole grid step, so S * chunk_n *
# 512B at d<=128 must leave room for the neighbour scratch.  Unlike the
# pre-chunking kernel (whole (N, d) resident -> hard N cap, XLA fallback
# past ~6.8k rows at d=2/S=3) the budget now sizes the *chunk*: N only
# raises the chunk count.  The XLA segment-sum ref remains as a guard
# for degenerate plans (chunk counts so high the staged-row reuse stops
# paying for the replayed per-chunk sweep).
_SCATTER_VMEM_BUDGET = 10 * 2 ** 20
_SCATTER_MAX_CHUNKS = 64


def scatter_chunk_plan(n: int, d: int, n_segments: int):
    """Rows binned per grid step so the S resident slabs fit VMEM.

    Returns ``chunk_n`` (== n when everything fits in one chunk), or
    ``None`` when even a degenerate chunking can't make the kernel
    worthwhile -> caller falls back to the XLA segment-sum ref.
    """
    lane_padded = -(-d // 128) * 128
    bytes_per_row = n_segments * lane_padded * 4
    max_rows = _SCATTER_VMEM_BUDGET // max(bytes_per_row, 1)
    if max_rows >= n:
        return n
    chunk_n = (max_rows // 8) * 8          # keep sublane-tile alignment
    if chunk_n < 8:
        return None
    if -(-n // chunk_n) > _SCATTER_MAX_CHUNKS:
        return None
    return chunk_n


def ne_forces(y, nbr, coef, alpha, *, mode: str, backend: str = "auto"):
    """Fused variable-tail force evaluation; see ref.py for semantics."""
    if backend == "auto":
        backend = _default_backend()
    if backend in ("pallas", "interpret"):
        return fallback.guarded(
            "ne_forces",
            lambda: ne_forces_pallas(y, nbr, coef, alpha, mode=mode,
                                     interpret=backend == "interpret"),
            lambda: ne_forces_ref(y, nbr, coef, alpha, mode=mode))
    if backend == "xla":
        return ne_forces_ref(y, nbr, coef, alpha, mode=mode)
    raise ValueError(f"unknown backend {backend!r}")


def ne_forces_gather(x, qid, nbr_idx, coef, alpha, *, segments,
                     emit_edges=None, scatter_fused: bool = False,
                     scatter_back=None, backend: str = "auto"):
    """Index-taking, segmented force evaluation in ONE launch.

    Unlike :func:`ne_forces` the (B, K, d) gathered neighbour buffer is
    never materialised in HBM, and several neighbour segments (e.g. HD
    attraction + LD repulsion + negative samples) are evaluated over the
    concatenated neighbour axis in a single kernel launch: one read of the
    embedding instead of three.  ``segments`` is a static tuple of
    ``(mode, size)`` pairs.

    Two output modes:
      * edge-emitting (default): returns per-segment tuples
        (aggs, edges, wsums) -- see ref.py for semantics; ``emit_edges``
        elides the (B, K_s, d) edge output of segments whose symmetric
        contribution the caller discards.
      * ``scatter_fused=True``: the symmetrisation itself moves into the
        op -- per-edge forces are accumulated in-kernel into per-segment
        (N, d) displacement-field partials (+edge at the query row,
        -edge at the neighbour row where ``scatter_back[s]``), so no
        per-edge tensor round-trips through HBM at all.  Returns
        (scats, wsums); ``emit_edges`` must be left None.
    """
    segments = tuple((str(m), int(s)) for m, s in segments)
    if backend == "auto":
        backend = _default_backend()
    if scatter_fused:
        assert emit_edges is None, "emit_edges is an edge-mode option"
        if scatter_back is not None:
            scatter_back = tuple(bool(b) for b in scatter_back)
        chunk_n = scatter_chunk_plan(x.shape[0], x.shape[1], len(segments))
        if backend in ("pallas", "interpret") and chunk_n is None:
            # degenerate VMEM plan: the XLA segment-sum ref answers this
            # shape; logged once on the telemetry channel (non-sticky --
            # other shapes may still plan fine)
            fallback.note("ne_forces",
                          f"scatter chunk plan degenerate at n={x.shape[0]} "
                          f"d={x.shape[1]} S={len(segments)}; XLA ref")
            backend = "xla"

        def run_scatter_ref():
            return ne_forces_scatter_ref(x, qid, nbr_idx, coef, alpha,
                                         segments=segments,
                                         scatter_back=scatter_back)

        if backend in ("pallas", "interpret"):
            return fallback.guarded(
                "ne_forces",
                lambda: ne_forces_scatter_pallas(
                    x, qid, nbr_idx, coef, alpha, segments=segments,
                    scatter_back=scatter_back, chunk_n=chunk_n,
                    interpret=backend == "interpret"),
                run_scatter_ref)
        if backend == "xla":
            return run_scatter_ref()
        raise ValueError(f"unknown backend {backend!r}")
    assert scatter_back is None, "scatter_back is a scatter_fused option"
    if emit_edges is not None:
        emit_edges = tuple(bool(e) for e in emit_edges)
    if backend in ("pallas", "interpret"):
        return fallback.guarded(
            "ne_forces",
            lambda: ne_forces_gather_pallas(x, qid, nbr_idx, coef, alpha,
                                            segments=segments,
                                            emit_edges=emit_edges,
                                            interpret=backend == "interpret"),
            lambda: ne_forces_gather_ref(x, qid, nbr_idx, coef, alpha,
                                         segments=segments,
                                         emit_edges=emit_edges))
    if backend == "xla":
        return ne_forces_gather_ref(x, qid, nbr_idx, coef, alpha,
                                    segments=segments,
                                    emit_edges=emit_edges)
    raise ValueError(f"unknown backend {backend!r}")

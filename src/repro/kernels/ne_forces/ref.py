"""Pure-jnp oracle for the fused variable-tail NE force kernel.

Variable-tail LD kernel (paper Eq. 4):  w(d2) = (1 + d2/alpha)^(-alpha)

Closed forms used throughout (avoid fractional powers of w):
  w^(1/alpha)       = (1 + d2/alpha)^(-1)
  w^(1 + 1/alpha)   = (1 + d2/alpha)^(-(alpha+1))

mode='attraction'   (first term of paper Eq. 6, re-distributed per Sec. 3):
  edge[b,k] = coef[b,k] * w^(1/alpha) * (nbr[b,k] - y[b])     # pull toward nbr
  wsum[b]   = sum_k coef[b,k] * w^(1/alpha)

mode='repulsion'    (second+third terms; coef carries mask / NS rescale):
  edge[b,k] = coef[b,k] * w^(1+1/alpha) * (y[b] - nbr[b,k])   # push away
  wsum[b]   = sum_k coef[b,k] * w          # partial sums for the Z estimator

Returns (agg, edge, wsum): agg[b] = sum_k edge[b,k] is the force on point b;
edge is kept so the symmetric contribution (-edge) can be scattered to the
neighbour side outside the kernel (scatter-free symmetrisation, DESIGN.md #3).
"""
from __future__ import annotations

import jax.numpy as jnp


def ne_forces_ref(y, nbr, coef, alpha, *, mode: str):
    assert mode in ("attraction", "repulsion"), mode
    y32 = y.astype(jnp.float32)                # (B, d)
    n32 = nbr.astype(jnp.float32)              # (B, K, d)
    c32 = coef.astype(jnp.float32)             # (B, K)
    alpha = jnp.asarray(alpha, jnp.float32)

    delta = n32 - y32[:, None, :]              # (B, K, d)
    d2 = jnp.sum(delta * delta, axis=-1)       # (B, K)
    base = 1.0 + d2 / alpha                    # (B, K)

    if mode == "attraction":
        wexp = 1.0 / base                      # w^(1/alpha)
        edge = (c32 * wexp)[..., None] * delta
        wsum = jnp.sum(c32 * wexp, axis=-1)
    else:
        wexp = jnp.exp(-(alpha + 1.0) * jnp.log(base))   # w^(1+1/alpha)
        w = jnp.exp(-alpha * jnp.log(base))              # w
        edge = (c32 * wexp)[..., None] * (-delta)
        wsum = jnp.sum(c32 * w, axis=-1)
    agg = jnp.sum(edge, axis=1)                # (B, d)
    return agg, edge, wsum

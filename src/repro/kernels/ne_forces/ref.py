"""Pure-jnp oracle for the fused variable-tail NE force kernel.

Variable-tail LD kernel (paper Eq. 4):  w(d2) = (1 + d2/alpha)^(-alpha)

Closed forms used throughout (avoid fractional powers of w):
  w^(1/alpha)       = (1 + d2/alpha)^(-1)
  w^(1 + 1/alpha)   = (1 + d2/alpha)^(-(alpha+1))

mode='attraction'   (first term of paper Eq. 6, re-distributed per Sec. 3):
  edge[b,k] = coef[b,k] * w^(1/alpha) * (nbr[b,k] - y[b])     # pull toward nbr
  wsum[b]   = sum_k coef[b,k] * w^(1/alpha)

mode='repulsion'    (second+third terms; coef carries mask / NS rescale):
  edge[b,k] = coef[b,k] * w^(1+1/alpha) * (y[b] - nbr[b,k])   # push away
  wsum[b]   = sum_k coef[b,k] * w          # partial sums for the Z estimator

Returns (agg, edge, wsum): agg[b] = sum_k edge[b,k] is the force on point b;
edge is kept so the symmetric contribution (-edge) can be scattered to the
neighbour side outside the kernel (scatter-free symmetrisation, DESIGN.md #3).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ne_forces_ref(y, nbr, coef, alpha, *, mode: str):
    assert mode in ("attraction", "repulsion"), mode
    y32 = y.astype(jnp.float32)                # (B, d)
    n32 = nbr.astype(jnp.float32)              # (B, K, d)
    c32 = coef.astype(jnp.float32)             # (B, K)
    alpha = jnp.asarray(alpha, jnp.float32)

    delta = n32 - y32[:, None, :]              # (B, K, d)
    d2 = jnp.sum(delta * delta, axis=-1)       # (B, K)
    base = 1.0 + d2 / alpha                    # (B, K)

    if mode == "attraction":
        wexp = 1.0 / base                      # w^(1/alpha)
        edge = (c32 * wexp)[..., None] * delta
        wsum = jnp.sum(c32 * wexp, axis=-1)
    else:
        wexp = jnp.exp(-(alpha + 1.0) * jnp.log(base))   # w^(1+1/alpha)
        w = jnp.exp(-alpha * jnp.log(base))              # w
        edge = (c32 * wexp)[..., None] * (-delta)
        wsum = jnp.sum(c32 * w, axis=-1)
    agg = jnp.sum(edge, axis=1)                # (B, d)
    return agg, edge, wsum


def ne_forces_gather_ref(x, qid, nbr_idx, coef, alpha, *, segments: tuple,
                         emit_edges: tuple = None):
    """Index-taking, segmented oracle (see kernel.py for the TPU version).

    ``segments`` is a static tuple of (mode, size) pairs partitioning the
    neighbour axis; each segment is evaluated with :func:`ne_forces_ref`
    semantics.  Returns per-segment tuples (aggs, edges, wsums) -- never
    packed, so the XLA fallback pays no concat/re-slice round-trip.  The
    (cheap, int32) *index* array is sliced per segment and each segment
    gathered separately: slicing a big gathered f32 buffer would cost a
    copy per segment on the XLA path.  ``edges[s]`` is None where
    ``emit_edges[s]`` is False (kernel.py skips those HBM writes; here we
    just don't return the buffer, letting XLA dead-code it).
    """
    if emit_edges is None:
        emit_edges = (True,) * len(segments)
    n = x.shape[0]
    y = x[jnp.clip(qid, 0, n - 1)]
    aggs, edges, wsums = [], [], []
    k0 = 0
    for (mode, size), em in zip(segments, emit_edges):
        sl = slice(k0, k0 + size)
        nbr_s = x[jnp.clip(nbr_idx[:, sl], 0, n - 1)]
        agg, edge, wsum = ne_forces_ref(y, nbr_s, coef[:, sl], alpha,
                                        mode=mode)
        aggs.append(agg)
        edges.append(edge if em else None)
        wsums.append(wsum)
        k0 += size
    return tuple(aggs), tuple(edges), tuple(wsums)


def ne_forces_scatter_ref(x, qid, nbr_idx, coef, alpha, *, segments: tuple,
                          scatter_back: tuple = None):
    """Scatter-fused oracle on ``jax.ops.segment_sum``.

    Instead of returning per-edge forces for the caller to scatter, each
    segment's edges are accumulated into an (N, d) displacement-field
    partial:

        scat_s[qid[b]]        += sum_k edge_s[b, k]    (query-side agg)
        scat_s[nbr_idx[b, k]] -= edge_s[b, k]          (symmetric reaction,
                                                        iff scatter_back[s])

    so the scatter-free symmetrisation of DESIGN.md #3 happens inside the
    op and the (B, K_s, d) edge tensor is a transient XLA value, never
    part of the contract.  Per-segment scale factors stay with the caller
    (the repulsion scale needs this launch's wsums via the Z estimator).
    Returns (scats, wsums): tuples of (N, d) fields and (B,) w sums.
    """
    if scatter_back is None:
        scatter_back = (True,) * len(segments)
    n, d = x.shape
    qc = jnp.clip(qid, 0, n - 1)
    y = x[qc]
    scats, wsums = [], []
    k0 = 0
    for (mode, size), back in zip(segments, scatter_back):
        sl = slice(k0, k0 + size)
        tgt = jnp.clip(nbr_idx[:, sl], 0, n - 1)
        agg, edge, wsum = ne_forces_ref(y, x[tgt], coef[:, sl], alpha,
                                        mode=mode)
        scat = jax.ops.segment_sum(agg, qc, num_segments=n)
        if back:
            scat = scat + jax.ops.segment_sum(-edge.reshape(-1, d),
                                              tgt.reshape(-1),
                                              num_segments=n)
        scats.append(scat)
        wsums.append(wsum)
        k0 += size
    return tuple(scats), tuple(wsums)

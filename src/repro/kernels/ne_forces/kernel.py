"""Pallas TPU kernel: fused variable-tail NE force evaluation.

The paper's GPU implementation evaluates the LD kernel w_ij, the force
vector, and the Z-estimator partial sums in separate passes with atomics.
On TPU we fuse them: one VMEM-resident pass over a (block_b, K, d) tile
computes LD squared distances, the closed-form tail powers

    w^(1/alpha)     = (1 + d2/alpha)^(-1)          (attraction weight)
    w^(1+1/alpha)   = (1 + d2/alpha)^(-(alpha+1))  (repulsion weight)

and emits the per-point aggregate force, the per-edge forces (for the
scatter-free symmetrisation outside the kernel), and the w partial sums
(Z-hat estimator).  alpha is a *traced* (1,1) scalar so interactive
hyperparameter changes never recompile (paper Sec. 3).

Grid: (B/block_b,) -- one parallel sweep; K and d live fully in VMEM
(K <= ~128 neighbours, d <= ~64 embedding dims by design).  On TPU the
(K, d) trailing dims map to (sublane, lane); Mosaic pads d to the 128-lane
tile.  For visualisation-scale d (2..8) the arithmetic is lane-sparse but
the kernel stays bandwidth-bound on the (B, K, d) neighbour gather, which
is the term that matters.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params


def _edge_wsum(delta, coef, alpha, mode: str):
    """Closed-form tail powers -> (edge, wsum); the single in-kernel copy
    of the force math shared by the pre-gather and gather-fused kernels
    (semantics in ref.py)."""
    d2 = jnp.sum(delta * delta, axis=-1)            # (bb, K)
    base = 1.0 + d2 / alpha
    if mode == "attraction":
        wexp = 1.0 / base
        edge = (coef * wexp)[..., None] * delta
        wsum = jnp.sum(coef * wexp, axis=-1)
    else:
        logb = jnp.log(base)
        wexp = jnp.exp(-(alpha + 1.0) * logb)
        w = jnp.exp(-alpha * logb)
        edge = (coef * wexp)[..., None] * (-delta)
        wsum = jnp.sum(coef * w, axis=-1)
    return edge, wsum


def _ne_forces_kernel(alpha_ref, y_ref, nbr_ref, coef_ref, agg_ref, edge_ref,
                      wsum_ref, *, mode: str):
    alpha = alpha_ref[0, 0]
    y = y_ref[...].astype(jnp.float32)              # (bb, d)
    nbr = nbr_ref[...].astype(jnp.float32)          # (bb, K, d)
    coef = coef_ref[...].astype(jnp.float32)        # (bb, K)

    edge, wsum = _edge_wsum(nbr - y[:, None, :], coef, alpha, mode)
    agg_ref[...] = jnp.sum(edge, axis=1)
    edge_ref[...] = edge
    wsum_ref[...] = wsum[:, None]


@functools.partial(
    jax.jit, static_argnames=("mode", "block_b", "interpret"))
def ne_forces_pallas(y, nbr, coef, alpha, *, mode: str, block_b: int = 128,
                     interpret: bool = False):
    """(B,d), (B,K,d), (B,K), scalar -> (agg (B,d), edge (B,K,d), wsum (B,))."""
    B, d = y.shape
    _, K, _ = nbr.shape
    block_b = min(block_b, _round_up(B, 8))
    Bp = _round_up(B, block_b)
    if Bp != B:
        y = jnp.pad(y, ((0, Bp - B), (0, 0)))
        nbr = jnp.pad(nbr, ((0, Bp - B), (0, 0), (0, 0)))
        coef = jnp.pad(coef, ((0, Bp - B), (0, 0)))
    alpha_arr = jnp.asarray(alpha, jnp.float32).reshape(1, 1)

    grid = (Bp // block_b,)
    agg, edge, wsum = pl.pallas_call(
        functools.partial(_ne_forces_kernel, mode=mode),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),
            pl.BlockSpec((block_b, K, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_b, K), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),
            pl.BlockSpec((block_b, K, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bp, d), jnp.float32),
            jax.ShapeDtypeStruct((Bp, K, d), jnp.float32),
            jax.ShapeDtypeStruct((Bp, 1), jnp.float32),
        ],
        interpret=interpret,
    )(alpha_arr, y, nbr, coef)
    return agg[:B], edge[:B], wsum[:B, 0]


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


# --------------------------------------------------------------------------
# Gather-fused, segmented variant.
#
# The pre-gather kernel above receives Y[idx] as a dense (B, K, d) operand,
# which XLA materialises in HBM before the launch -- and FUnc-SNE launches
# it three times per step (HD attraction, LD repulsion, negatives), reading
# the embedding three times.  This variant
#   * takes *indices* and DMAs only the needed embedding rows per block
#     (Y stays in HBM/ANY memory; the (B, K, d) buffer never exists), and
#   * evaluates several neighbour *segments* with independent modes in one
#     launch over the concatenated neighbour axis, so one gather of y_l and
#     one kernel launch replace all three per-step force launches.
# Segment boundaries are static config, so each segment's closed-form tail
# power is compiled straight-line -- no per-edge mode mask is evaluated.
#
# Index slabs are staged into SMEM by the pipeline (O(block_b * K), never
# O(B)).  The b loop is double-buffered: rows are processed in ``sub_b``
# sub-blocks through 2-slot VMEM staging with sub-block p+1's row DMAs
# issued before sub-block p is computed, so the row-gather latency hides
# behind the tail-power math instead of preceding it.


def _dma_query_and_neighbour_rows(x_ref, qid_ref, nbr_ref, q_scr, n_scr, sem):
    """Stage x[qid[r]] -> q_scr[r] and x[nbr[r, k]] -> n_scr[r, k] row DMAs.

    Issued back-to-back on one semaphore and drained in issue order
    (distinct destination slots -> no WAR hazard).  Used by the
    scatter-fused kernel, whose whole block stays resident across its
    N-chunk sweep.
    """
    block_b, K, _ = n_scr.shape

    def q_dma(r):
        return pltpu.make_async_copy(x_ref.at[qid_ref[r]], q_scr.at[r], sem)

    def n_dma(r, k):
        return pltpu.make_async_copy(x_ref.at[nbr_ref[r, k]], n_scr.at[r, k],
                                     sem)

    def issue(r, _):
        q_dma(r).start()
        jax.lax.fori_loop(0, K, lambda k, x: (n_dma(r, k).start(), x)[1],
                          None)
        return _

    def drain(r, _):
        q_dma(r).wait()
        jax.lax.fori_loop(0, K, lambda k, x: (n_dma(r, k).wait(), x)[1],
                          None)
        return _

    jax.lax.fori_loop(0, block_b, issue, None)
    jax.lax.fori_loop(0, block_b, drain, None)


def _ne_forces_gather_kernel(qid_ref, nbr_ref, alpha_ref, coef_ref, x_ref,
                             *refs, segments: tuple, emit_edges: tuple,
                             sub_b: int):
    """qid (bb,) SMEM; nbr (bb, K) SMEM; alpha (1,1) SMEM; coef (bb, K) VMEM;
    x (N, d) ANY -> per segment s: agg (bb, d), edge (bb, K_s, d) for
    segments with emit_edges[s], wsum (bb, 1); then scratch
    (q_scr (2, sub_b, d), n_scr (2, sub_b, K, d), sem (2,))."""
    S = len(segments)
    E = sum(emit_edges)
    agg_refs = refs[:S]
    edge_refs = refs[S:S + E]
    wsum_refs = refs[S + E:2 * S + E]
    q_scr, n_scr, sem = refs[2 * S + E:]
    block_b, K = coef_ref.shape
    n_sub = block_b // sub_b
    alpha = alpha_ref[0, 0]

    def sub_copies(p, op):
        """Start/wait the 2-slot staged row DMAs of sub-block ``p``."""
        slot = p % 2

        def row(lr, _):
            r = p * sub_b + lr
            op(pltpu.make_async_copy(x_ref.at[qid_ref[r]],
                                     q_scr.at[slot, lr], sem.at[slot]))
            jax.lax.fori_loop(
                0, K, lambda k, x: (op(pltpu.make_async_copy(
                    x_ref.at[nbr_ref[r, k]], n_scr.at[slot, lr, k],
                    sem.at[slot])), x)[1], None)
            return _

        jax.lax.fori_loop(0, sub_b, row, None)

    sub_copies(0, lambda cp: cp.start())

    def body(p, _):
        slot = p % 2

        @pl.when(p + 1 < n_sub)
        def _prefetch():                     # overlap: copy p+1, compute p
            sub_copies(p + 1, lambda cp: cp.start())

        sub_copies(p, lambda cp: cp.wait())

        base = p * sub_b
        y = q_scr[slot].astype(jnp.float32)         # (sub_b, d)
        nbr = n_scr[slot].astype(jnp.float32)       # (sub_b, K, d)
        coef = coef_ref[pl.ds(base, sub_b)].astype(jnp.float32)

        k0, e_i = 0, 0
        for s, (mode, size) in enumerate(segments):
            sl = slice(k0, k0 + size)
            delta = nbr[:, sl] - y[:, None, :]      # (sub_b, size, d)
            edge, wsum = _edge_wsum(delta, coef[:, sl], alpha, mode)
            if emit_edges[s]:
                edge_refs[e_i][pl.ds(base, sub_b)] = edge
                e_i += 1
            agg_refs[s][pl.ds(base, sub_b)] = jnp.sum(edge, axis=1)
            wsum_refs[s][pl.ds(base, sub_b)] = wsum[:, None]
            k0 += size
        return _

    jax.lax.fori_loop(0, n_sub, body, None)


def _pick_sub_b(block_b: int) -> int:
    """Double-buffer sub-block: small blocks stay monolithic (nothing to
    overlap), bigger ones pipeline in 8-row (one f32 sublane) sub-blocks."""
    if block_b <= 16 or block_b % 8:
        return block_b
    return 8


@functools.partial(
    jax.jit, static_argnames=("segments", "emit_edges", "block_b", "sub_b",
                              "interpret"))
def ne_forces_gather_pallas(x, qid, nbr_idx, coef, alpha, *,
                            segments: tuple, emit_edges: tuple = None,
                            block_b: int = 128, sub_b: int = None,
                            interpret: bool = False):
    """Index-taking segmented force kernel.

    Args:
      x: (N, d) embedding, kept in HBM/ANY memory space.
      qid: (B,) int32 row ids of the points the forces act on.
      nbr_idx: (B, K) int32 neighbour ids, K = sum of segment sizes;
        clipped to [0, N) (callers zero invalid slots via ``coef``).
      coef: (B, K) f32 per-edge coefficients.
      alpha: traced scalar tail parameter.
      segments: static tuple of ``(mode, size)`` pairs partitioning the
        neighbour axis, mode in {'attraction', 'repulsion'}.
      emit_edges: static per-segment bools (default: all True); a False
        segment skips its (B, K_s, d) edge output entirely -- no HBM
        write for edges the caller would discard (e.g. negative samples,
        whose symmetric contribution is never scattered).
      sub_b: double-buffer sub-block size (must divide ``block_b``);
        default: 8-row sub-blocks for blocks > 16 rows.
    Returns (one entry per segment -- no packed buffers, so consumers
    never pay a concat/re-slice round-trip):
      aggs: tuple of (B, d) per-point aggregate forces,
      edges: tuple of (B, K_s, d) per-edge forces (for the scatter-free
        symmetrisation outside the kernel); ``None`` where
        ``emit_edges[s]`` is False,
      wsums: tuple of (B,) w partial sums (Z-hat estimator terms).
    """
    N, d = x.shape
    B, K = nbr_idx.shape
    S = len(segments)
    if emit_edges is None:
        emit_edges = (True,) * S
    assert len(emit_edges) == S, (emit_edges, segments)
    assert K == sum(size for _, size in segments), (K, segments)
    assert all(mode in ("attraction", "repulsion") for mode, _ in segments)
    assert all(size > 0 for _, size in segments), segments

    qid = jnp.clip(qid.astype(jnp.int32), 0, N - 1)
    nbr_idx = jnp.clip(nbr_idx.astype(jnp.int32), 0, N - 1)
    coef = coef.astype(jnp.float32)

    block_b = min(block_b, _round_up(B, 8))
    if sub_b is None:
        sub_b = _pick_sub_b(block_b)
    assert block_b % sub_b == 0, (block_b, sub_b)
    while block_b > 8 and 2 * (K + 1) * min(sub_b, block_b) * d \
            * x.dtype.itemsize > 8 * 2 ** 20:
        block_b //= 2
        # a halved block_b may no longer be a multiple of sub_b: every row
        # of a block must land in some sub-block, so re-derive a divisor
        sub_b = math.gcd(sub_b, block_b)
    Bp = _round_up(B, block_b)
    if Bp != B:
        qid = jnp.pad(qid, (0, Bp - B))
        nbr_idx = jnp.pad(nbr_idx, ((0, Bp - B), (0, 0)))
        coef = jnp.pad(coef, ((0, Bp - B), (0, 0)))
    alpha_arr = jnp.asarray(alpha, jnp.float32).reshape(1, 1)

    grid = (Bp // block_b,)
    emitted_sizes = [size for (_, size), em in zip(segments, emit_edges)
                     if em]
    E = len(emitted_sizes)
    outs = pl.pallas_call(
        functools.partial(_ne_forces_gather_kernel, segments=segments,
                          emit_edges=emit_edges, sub_b=sub_b),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b,), lambda i: (i,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((block_b, K), lambda i: (i, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1), lambda i: (0, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((block_b, K), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=(
            [pl.BlockSpec((block_b, d), lambda i: (i, 0))] * S
            + [pl.BlockSpec((block_b, size, d), lambda i: (i, 0, 0))
               for size in emitted_sizes]
            + [pl.BlockSpec((block_b, 1), lambda i: (i, 0))] * S
        ),
        out_shape=(
            [jax.ShapeDtypeStruct((Bp, d), jnp.float32)] * S
            + [jax.ShapeDtypeStruct((Bp, size, d), jnp.float32)
               for size in emitted_sizes]
            + [jax.ShapeDtypeStruct((Bp, 1), jnp.float32)] * S
        ),
        scratch_shapes=[
            pltpu.VMEM((2, sub_b, d), x.dtype),
            pltpu.VMEM((2, sub_b, K, d), x.dtype),
            pltpu.SemaphoreType.DMA((2,)),
        ],
        # one independent row block per grid step: Mosaic may split the
        # sweep across TensorCores (each core double-buffers its own
        # scratch slots)
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(qid, nbr_idx, alpha_arr, coef, x)
    aggs = tuple(o[:B] for o in outs[:S])
    edge_iter = iter(outs[S:S + E])
    edges = tuple(next(edge_iter)[:B] if em else None for em in emit_edges)
    wsums = tuple(o[:B, 0] for o in outs[S + E:])
    return aggs, edges, wsums


# --------------------------------------------------------------------------
# Scatter-fused epilogue.
#
# The gather-fused kernel above still *returns* per-edge forces so the
# caller can symmetrise them (buf.at[nbr].add(-edge)) -- two (B, K, d)
# HBM round-trips per step that exist only to feed an XLA scatter.  This
# variant folds the symmetrisation into the kernel: each edge's force is
# accumulated straight into a per-segment (N, d) displacement-field
# partial (+edge at the query row, -edge at the neighbour row for
# symmetrised segments), binned by index with the VMEM accumulate
# pattern.  Each grid block writes its own (1, N, d) partial slab; the
# partials are reduced across the grid with one cheap XLA sum, so the
# only HBM traffic the epilogue pays is G * N * d per segment instead of
# write+scatter-read of B * K_s * d edges.
#
# Segment scale factors (attraction/repulsion/negative-sampling weights)
# stay *outside*: the repulsion scale depends on the Z estimator, which
# is computed from this very launch's wsums, so the kernel returns raw
# per-segment fields and the caller combines them with traced scalars.
#
# VMEM note: only the current *N-chunk* of each per-segment partial is
# resident during a grid step -- a second grid axis sweeps the target
# rows in ``chunk_n`` slabs of (1, chunk_n, d), so the resident footprint
# is S * chunk_n * 512B at d<=128 regardless of N.  The staged query /
# neighbour rows are DMA'd once per block (at chunk 0) and stay resident
# across that block's chunk sweep; each chunk replays the (cheap,
# vectorised) tail-power math and bins only the edges whose target falls
# inside the chunk.  ops.py picks ``chunk_n`` so the slabs fit the VMEM
# budget (see ``scatter_chunk_plan``), which is what lifts the old
# whole-(N, d)-resident cap that forced large-N runs back to the XLA
# segment-sum ref.


def _ne_forces_scatter_kernel(qid_ref, nbr_ref, alpha_ref, coef_ref, x_ref,
                              *refs, segments: tuple, scatter_back: tuple,
                              chunk_n: int):
    """qid (bb,) SMEM; nbr (bb, K) SMEM; alpha (1,1) SMEM; coef (bb, K) VMEM;
    x (N, d) ANY -> per segment s: scat (1, chunk_n, d) grid-block x
    N-chunk partial, wsum (bb, 1); then scratch (q_scr, n_scr, sem)."""
    S = len(segments)
    scat_refs = refs[:S]
    wsum_refs = refs[S:2 * S]
    q_scr, n_scr, sem = refs[2 * S:]
    block_b, K, _ = n_scr.shape
    c = pl.program_id(1)
    off = c * chunk_n

    @pl.when(c == 0)
    def _stage():        # rows stay resident across this block's chunk sweep
        _dma_query_and_neighbour_rows(x_ref, qid_ref, nbr_ref, q_scr, n_scr,
                                      sem)

    alpha = alpha_ref[0, 0]
    y = q_scr[...].astype(jnp.float32)              # (bb, d)
    nbr = n_scr[...].astype(jnp.float32)            # (bb, K, d)
    coef = coef_ref[...].astype(jnp.float32)        # (bb, K)

    def accumulate(scat_ref, agg, edge, k0, size, back):
        # Index-binned accumulation: serialised read-modify-writes handle
        # duplicate targets (negatives / shared neighbours) exactly; the
        # chunk guard keeps every write inside this step's (chunk_n, d)
        # slab.
        def nbr_body(r):
            def body(k, _):
                t = nbr_ref[r, k0 + k]

                @pl.when((t >= off) & (t < off + chunk_n))
                def _in_chunk():
                    scat_ref[0, t - off] += -edge[r, k]
                return _
            jax.lax.fori_loop(0, size, body, None)

        def row_body(r, _):
            q = qid_ref[r]

            @pl.when((q >= off) & (q < off + chunk_n))
            def _in_chunk():
                scat_ref[0, q - off] += agg[r]
            if back:
                nbr_body(r)
            return _

        jax.lax.fori_loop(0, block_b, row_body, None)

    k0 = 0
    for s, (mode, size) in enumerate(segments):
        sl = slice(k0, k0 + size)
        edge, wsum = _edge_wsum(nbr[:, sl] - y[:, None, :], coef[:, sl],
                                alpha, mode)
        wsum_refs[s][...] = wsum[:, None]
        scat_refs[s][...] = jnp.zeros_like(scat_refs[s])
        accumulate(scat_refs[s], jnp.sum(edge, axis=1), edge, k0, size,
                   scatter_back[s])
        k0 += size


@functools.partial(
    jax.jit, static_argnames=("segments", "scatter_back", "block_b",
                              "chunk_n", "interpret"))
def ne_forces_scatter_pallas(x, qid, nbr_idx, coef, alpha, *,
                             segments: tuple, scatter_back: tuple = None,
                             block_b: int = None, chunk_n: int = None,
                             interpret: bool = False):
    """Scatter-fused segmented force kernel (see block comment above).

    Args match :func:`ne_forces_gather_pallas` except:
      scatter_back: static per-segment bools (default: all True); True
        segments accumulate each edge's reaction force (-edge) into the
        neighbour's row (the symmetrisation); False segments (e.g.
        negative samples) contribute only the query-side aggregate.
      chunk_n: target rows binned per grid step (default: all N in one
        chunk).  The resident per-segment slab is (chunk_n, d), so
        ``chunk_n`` bounds VMEM regardless of N; each block's staged rows
        are reused across its chunk sweep (one DMA round per block).
    Returns:
      scats: tuple of (N, d) f32 per-segment displacement-field partials,
        already reduced over grid blocks -- scats[s][i] carries every
        force this launch exerts on point i through segment s.  No
        (B, K_s, d) edge tensor is ever written to HBM.
      wsums: tuple of (B,) w partial sums (Z-hat estimator terms).
    """
    N, d = x.shape
    B, K = nbr_idx.shape
    S = len(segments)
    if scatter_back is None:
        scatter_back = (True,) * S
    assert len(scatter_back) == S, (scatter_back, segments)
    assert K == sum(size for _, size in segments), (K, segments)
    assert all(mode in ("attraction", "repulsion") for mode, _ in segments)
    assert all(size > 0 for _, size in segments), segments
    if chunk_n is None:
        chunk_n = N
    chunk_n = min(chunk_n, N)
    assert chunk_n >= 1, chunk_n

    qid = jnp.clip(qid.astype(jnp.int32), 0, N - 1)
    nbr_idx = jnp.clip(nbr_idx.astype(jnp.int32), 0, N - 1)
    coef = coef.astype(jnp.float32)

    if block_b is None:
        # Unlike the edge-emitting kernel, each grid block here writes
        # S * N * d of partials, so the epilogue's HBM traffic is
        # G * S * N * d: cap the number of grid blocks (G <= 8) instead
        # of fixing block_b, keeping the partial traffic below the edge
        # write+scatter-read it replaces at any B.
        block_b = max(128, _round_up(-(-B // 8), 8))
    block_b = min(block_b, _round_up(B, 8))
    while block_b > 8 and (K + 1) * block_b * d * x.dtype.itemsize \
            > 8 * 2 ** 20:
        block_b //= 2
    Bp = _round_up(B, block_b)
    if Bp != B:
        # padded rows carry coef 0 -> exact-zero contributions to row qid[0]
        qid = jnp.pad(qid, (0, Bp - B))
        nbr_idx = jnp.pad(nbr_idx, ((0, Bp - B), (0, 0)))
        coef = jnp.pad(coef, ((0, Bp - B), (0, 0)))
    alpha_arr = jnp.asarray(alpha, jnp.float32).reshape(1, 1)

    G = Bp // block_b
    Np = _round_up(N, chunk_n)
    n_chunks = Np // chunk_n
    outs = pl.pallas_call(
        functools.partial(_ne_forces_scatter_kernel, segments=segments,
                          scatter_back=scatter_back, chunk_n=chunk_n),
        grid=(G, n_chunks),
        in_specs=[
            pl.BlockSpec((block_b,), lambda i, c: (i,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((block_b, K), lambda i, c: (i, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1), lambda i, c: (0, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((block_b, K), lambda i, c: (i, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=(
            [pl.BlockSpec((1, chunk_n, d), lambda i, c: (i, c, 0))] * S
            + [pl.BlockSpec((block_b, 1), lambda i, c: (i, 0))] * S
        ),
        out_shape=(
            [jax.ShapeDtypeStruct((G, Np, d), jnp.float32)] * S
            + [jax.ShapeDtypeStruct((Bp, 1), jnp.float32)] * S
        ),
        scratch_shapes=[
            pltpu.VMEM((block_b, d), x.dtype),
            pltpu.VMEM((block_b, K, d), x.dtype),
            pltpu.SemaphoreType.DMA(()),
        ],
        interpret=interpret,
    )(qid, nbr_idx, alpha_arr, coef, x)
    # the final cheap XLA reduction of the per-grid-block partials
    scats = tuple(jnp.sum(o, axis=0)[:N] for o in outs[:S])
    wsums = tuple(o[:B, 0] for o in outs[S:])
    return scats, wsums

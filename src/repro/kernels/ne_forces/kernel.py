"""Pallas TPU kernel: fused variable-tail NE force evaluation.

The paper's GPU implementation evaluates the LD kernel w_ij, the force
vector, and the Z-estimator partial sums in separate passes with atomics.
On TPU we fuse them: one VMEM-resident pass over a (block_b, K, d) tile
computes LD squared distances, the closed-form tail powers

    w^(1/alpha)     = (1 + d2/alpha)^(-1)          (attraction weight)
    w^(1+1/alpha)   = (1 + d2/alpha)^(-(alpha+1))  (repulsion weight)

and emits the per-point aggregate force, the per-edge forces (for the
scatter-free symmetrisation outside the kernel), and the w partial sums
(Z-hat estimator).  alpha is a *traced* (1,1) scalar so interactive
hyperparameter changes never recompile (paper Sec. 3).

Grid: (B/block_b,) -- one parallel sweep; K and d live fully in VMEM
(K <= ~128 neighbours, d <= ~64 embedding dims by design).  On TPU the
(K, d) trailing dims map to (sublane, lane); Mosaic pads d to the 128-lane
tile.  For visualisation-scale d (2..8) the arithmetic is lane-sparse but
the kernel stays bandwidth-bound on the (B, K, d) neighbour gather, which
is the term that matters.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ne_forces_kernel(alpha_ref, y_ref, nbr_ref, coef_ref, agg_ref, edge_ref,
                      wsum_ref, *, mode: str):
    alpha = alpha_ref[0, 0]
    y = y_ref[...].astype(jnp.float32)              # (bb, d)
    nbr = nbr_ref[...].astype(jnp.float32)          # (bb, K, d)
    coef = coef_ref[...].astype(jnp.float32)        # (bb, K)

    delta = nbr - y[:, None, :]
    d2 = jnp.sum(delta * delta, axis=-1)            # (bb, K)
    base = 1.0 + d2 / alpha

    if mode == "attraction":
        wexp = 1.0 / base
        edge = (coef * wexp)[..., None] * delta
        wsum = jnp.sum(coef * wexp, axis=-1)
    else:
        logb = jnp.log(base)
        wexp = jnp.exp(-(alpha + 1.0) * logb)
        w = jnp.exp(-alpha * logb)
        edge = (coef * wexp)[..., None] * (-delta)
        wsum = jnp.sum(coef * w, axis=-1)

    agg_ref[...] = jnp.sum(edge, axis=1)
    edge_ref[...] = edge
    wsum_ref[...] = wsum[:, None]


@functools.partial(
    jax.jit, static_argnames=("mode", "block_b", "interpret"))
def ne_forces_pallas(y, nbr, coef, alpha, *, mode: str, block_b: int = 128,
                     interpret: bool = False):
    """(B,d), (B,K,d), (B,K), scalar -> (agg (B,d), edge (B,K,d), wsum (B,))."""
    B, d = y.shape
    _, K, _ = nbr.shape
    block_b = min(block_b, _round_up(B, 8))
    Bp = _round_up(B, block_b)
    if Bp != B:
        y = jnp.pad(y, ((0, Bp - B), (0, 0)))
        nbr = jnp.pad(nbr, ((0, Bp - B), (0, 0), (0, 0)))
        coef = jnp.pad(coef, ((0, Bp - B), (0, 0)))
    alpha_arr = jnp.asarray(alpha, jnp.float32).reshape(1, 1)

    grid = (Bp // block_b,)
    agg, edge, wsum = pl.pallas_call(
        functools.partial(_ne_forces_kernel, mode=mode),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),
            pl.BlockSpec((block_b, K, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_b, K), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),
            pl.BlockSpec((block_b, K, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bp, d), jnp.float32),
            jax.ShapeDtypeStruct((Bp, K, d), jnp.float32),
            jax.ShapeDtypeStruct((Bp, 1), jnp.float32),
        ],
        interpret=interpret,
    )(alpha_arr, y, nbr, coef)
    return agg[:B], edge[:B], wsum[:B, 0]


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult

"""Merge-fused neighbour refinement: score + dedup + top-K merge in-kernel."""

"""Public jit'd wrapper for the merge-fused neighbour refinement kernel.

Backend selection matches the other kernel packages:
  'pallas'    -- compiled Pallas kernel (TPU runtime)
  'interpret' -- Pallas interpret mode (CPU validation of the kernel body)
  'xla'       -- legacy selection pipeline (dedup_candidates + gather-ref
                 distances + merge_knn): flipping ``cfg.merge_fused`` is
                 bit-neutral on this path
  'auto'      -- 'pallas' when a TPU is present, else 'xla'
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import fallback
from repro.kernels.knn_merge.kernel import (knn_merge_cand_pallas,
                                            knn_merge_pallas)
from repro.kernels.knn_merge.ref import knn_merge_cand_ref, knn_merge_ref


def _default_backend() -> str:
    try:
        platform = jax.devices()[0].platform
    except Exception:  # pragma: no cover - device init failure
        platform = "cpu"
    return "pallas" if platform == "tpu" else "xla"


def knn_merge(x, qid, cur_idx, cur_d, cand=None, *, cand_active=None,
              cur_valid=None, backend: str = "auto", sources=None,
              salt=None, first_tables=(), second_tables=(), active=None):
    """Score C candidates, dedup, and top-K merge -- ONE fused operation.

    Replaces the per-iteration selection epilogue ``dedup_candidates`` ->
    ``pairwise_sqdist_gather`` -> ``merge_knn``: the Pallas path performs
    the dedup and the (stable, top_k-tie-identical) merge in-register per
    row block, so no (B, C) distance buffer, no (B, C, K)/(B, C, C) dedup
    broadcast tensor and no sort exist in the step HLO.

    Candidate-fused mode (§Perf H17): pass ``sources``/``salt`` instead
    of a precomputed ``cand`` and the candidates themselves are *derived*
    from the counter-based hash RNG plus chained gathers through the
    neighbour tables -- in-kernel on the Pallas path (no (B, C) candidate
    tensor, no threefry, no (B, s, K2) two-hop broadcast in the HLO), or
    via the bit-identical jnp reference sampler on the 'xla' path.

    Args:
      x: (N, M) source matrix (X for HD refinement, Y for LD).
      qid: (B,) int32 query row ids.
      cur_idx: (B, K) int32 resident neighbour list; SENTINEL = invalid.
      cur_d: (B, K) f32 stored squared distances (+inf = invalid), or
        ``None`` to re-score the current neighbours in-kernel (LD mode:
        the embedding moved since the list was merged).  ``None`` requires
        ``cur_valid``.
      cand: (B, C) int32 candidate ids (SENTINEL / out-of-range allowed);
        in candidate-fused mode, the optional (B, C_extra) slab backing
        the ``("extra", c)`` source slots (e.g. cached reverse edges).
      cand_active: optional (B, C) bool extra validity mask (active-row
        membership); structural dedup (self / current / earlier-duplicate
        / SENTINEL) always happens inside.  Candidate-fused mode computes
        this internally from ``active`` instead.
      cur_valid: (B, K) bool validity of current slots, rescore mode only.
      sources: static candidate layout (see ``knn_lib.counter_candidates``)
        -- presence selects candidate-fused mode.
      salt: int32 counter-RNG salt (candidate-fused mode).
      first_tables: tuple of (B, Kf) resident first-table slabs.
      second_tables: tuple of (N2, K2) global tables for two-hop chains.
      active: (N,) bool global row membership, or None == all active.
    Returns:
      (new_idx (B, K) int32, new_d (B, K) f32, improved (B,) bool) --
      the ``merge_knn`` contract: sorted ascending, stable ties,
      ``improved`` true iff a candidate beat the pre-merge worst slot.
    """
    rescore = cur_d is None
    if rescore:
        assert cur_valid is not None, "rescore mode requires cur_valid"
    else:
        assert cur_valid is None, "cur_valid is a rescore-mode option"
    if backend == "auto":
        backend = _default_backend()

    if sources is not None:
        assert salt is not None, "candidate-fused mode requires a salt"
        assert cand_active is None, \
            "candidate-fused mode derives cand_active from `active`"
        # zero-width sources are dropped up front so the static layout the
        # kernel specialises on matches the ref's concatenation exactly
        sources = tuple(s for s in sources if s[-1] > 0)

        def run_ref():
            return knn_merge_cand_ref(
                x, qid, cur_idx, cur_d, salt=salt, sources=sources,
                first_tables=first_tables, second_tables=second_tables,
                extra=cand, active=active, cur_valid=cur_valid)

        if backend == "xla":
            return run_ref()
        if backend in ("pallas", "interpret"):
            cur_w = cur_valid if rescore else cur_d
            return fallback.guarded(
                "knn_merge",
                lambda: knn_merge_cand_pallas(
                    x, qid, cur_idx, cur_w, salt, first_tables,
                    second_tables, cand, active, sources=sources,
                    rescore=rescore, interpret=(backend == "interpret")),
                run_ref)
        raise ValueError(f"unknown backend {backend!r}")

    def run_ref():
        return knn_merge_ref(x, qid, cur_idx, cur_d, cand,
                             cand_active=cand_active, cur_valid=cur_valid)

    if backend == "xla":
        return run_ref()
    if backend in ("pallas", "interpret"):
        ca = cand_active if cand_active is not None \
            else jnp.ones(cand.shape, bool)
        cur_w = cur_valid if rescore else cur_d
        return fallback.guarded(
            "knn_merge",
            lambda: knn_merge_pallas(x, qid, cur_idx, cur_w, cand, ca,
                                     rescore=rescore,
                                     interpret=(backend == "interpret")),
            run_ref)
    raise ValueError(f"unknown backend {backend!r}")

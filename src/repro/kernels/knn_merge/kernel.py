"""Pallas TPU kernel: merge-fused neighbour-list refinement.

The per-iteration KNN refinement has three phases: score C candidate rows
against each query (``pairwise_sqdist_gather``), invalidate duplicates
(``knn_lib.dedup_candidates``), and merge the survivors into the resident
sorted (K,) neighbour list (``knn_lib.merge_knn``).  After PRs 1-3 fused
the scoring, the *selection* still ran as plain XLA: the dedup
materialises (n, C, K) and (n, C, C) broadcast-compare bool tensors in
HBM, the (n, C) candidate distances round-trip through HBM between the
kernel and the merge, and ``merge_knn`` pays a full ``lax.top_k`` sort
over (n, K+C) even though the resident side is already sorted.

This kernel extends the gather-fused scoring loop so each row block,
after accumulating candidate distances in VMEM, performs the dedup and
the top-K merge *in-register* and emits only the new (n, K) idx/d arrays
plus a per-row ``improved`` flag: no candidate-distance buffer, no dedup
broadcast tensor, and no sort anywhere in the step HLO.

The merge is a *stable-rank* selection (``merge_select``): every element
of the virtual [current, candidate] concatenation gets its output rank
from O((K+C)^2) vectorised compares (ties broken by concatenation index,
exactly ``lax.top_k``'s stable order -- and exactly what a sorted
insertion of the C candidates would produce), and rank-k elements are
gathered into slot k by one-hot masked sums.  This is the dense,
branch-free equivalent of NN-descent's per-candidate sorted-insertion
update (Dong et al.); on the 8x128 VPU the quadratic compare block
(<= (block_b, 42, 42) at config defaults) is register-resident noise next
to the row-gather DMAs the loop already pays.

Two modes share the kernel:
  * HD refinement: the stored sorted ``cur_d`` rides in as an operand and
    only the C candidate rows are gathered and scored.
  * LD refinement (``rescore=True``): the embedding moved since the list
    was built, so the kernel gathers and re-scores current *and*
    candidate rows in one sweep (the fused current+candidate split the
    XLA path used to do) and masks invalid current slots to +inf via
    ``cur_valid``.

Scoring IS the ``pairwise_sqdist_gather`` pipeline: ``score_gather_block``
and ``plan_row_gather`` are imported from that package (ONE copy of the
SMEM index slabs, 2-slot double-buffered sub-block row DMAs, persistent-q
slab and clamped+masked final M chunk), with the accumulator landing in a
(block_b, G) scratch instead of an output block.  Grid is
(B/block_b, M/block_m) with ``dimension_semantics=("parallel",
"arbitrary")``: row blocks are independent, the M axis sequentially
revisits the block's accumulator and runs the merge on its final chunk.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params
from repro.kernels.pairwise_sqdist.kernel import (_round_up, plan_row_gather,
                                                  score_gather_block)

_SENTINEL = jnp.iinfo(jnp.int32).max


def merge_select(qid_col, cur_idx, cur_d, cand, cand_d, ext_valid):
    """In-register dedup + stable-rank top-K merge of one row block.

    Bit-reproduces ``knn_lib.dedup_candidates`` followed by
    ``knn_lib.merge_knn`` (whose ``lax.top_k`` breaks distance ties by
    concatenation index) as flat compare/select arithmetic: no sort, no
    dynamic gather, no (B, C, K) HBM tensor.  Shared by the Pallas kernel
    body and the ``knn_merge_rank_ref`` XLA implementation.

    Args:
      qid_col: (B, 1) int32 query row ids.
      cur_idx: (B, K) int32 resident neighbour ids (SENTINEL = invalid).
      cur_d: (B, K) f32 resident squared distances (+inf = invalid).
      cand: (B, C) int32 candidate ids (unclipped; SENTINEL = invalid).
      cand_d: (B, C) f32 candidate squared distances.
      ext_valid: (B, C) bool extra validity (e.g. active-row membership).
    Returns:
      (new_idx (B, K) int32, new_d (B, K) f32, improved (B,) bool).
    """
    _, k = cur_idx.shape
    c = cand.shape[1]
    i32 = jnp.int32

    def count(mask):                    # bool any() via i32 sum: TPU-safe
        return jnp.sum(mask.astype(i32), axis=-1)

    # ---- dedup (knn_lib.dedup_candidates semantics) ----
    self_dup = cand == qid_col
    in_cur = count(cand[:, :, None] == cur_idx[:, None, :]) > 0
    ci = jax.lax.broadcasted_iota(i32, (1, c, c), 1)
    cj = jax.lax.broadcasted_iota(i32, (1, c, c), 2)
    within = count((cand[:, :, None] == cand[:, None, :]) & (cj < ci)) > 0
    valid = ext_valid & ~(self_dup | in_cur | within | (cand == _SENTINEL))
    cand_d = jnp.where(valid, cand_d, jnp.inf)
    improved = count(cand_d < cur_d[:, k - 1:k]) > 0

    # ---- stable ranks over the virtual [cur, cand] concatenation ----
    # rank(e) = #{e': d[e'] < d[e]  or  (d[e'] == d[e] and e' before e)};
    # "before" is concatenation order, so cur always precedes cand and
    # within each side the original index decides -- lax.top_k's tie rule.
    cur_e = cur_d[:, :, None]           # element being ranked
    cand_e = cand_d[:, :, None]
    kk = jax.lax.broadcasted_iota(i32, (1, k, k), 1)
    kp = jax.lax.broadcasted_iota(i32, (1, k, k), 2)
    cur_vs_cur = (cur_d[:, None, :] < cur_e) \
        | ((cur_d[:, None, :] == cur_e) & (kp < kk))
    cand_vs_cur = cand_d[:, None, :] < cur_e          # cand never ties-first
    rank_cur = count(cur_vs_cur) + count(cand_vs_cur)
    cur_vs_cand = cur_d[:, None, :] <= cand_e         # cur always ties-first
    cand_vs_cand = (cand_d[:, None, :] < cand_e) \
        | ((cand_d[:, None, :] == cand_e) & (cj < ci))
    rank_cand = count(cur_vs_cand) + count(cand_vs_cand)

    # ---- one-hot rank -> slot selection (ranks >= K fall off the list) ----
    slot = jax.lax.broadcasted_iota(i32, (1, 1, k), 2)
    hit_cur = rank_cur[:, :, None] == slot            # (B, K, K)
    hit_cand = rank_cand[:, :, None] == slot          # (B, C, K)
    new_d = jnp.sum(jnp.where(hit_cur, cur_d[:, :, None], 0.0), axis=1) \
        + jnp.sum(jnp.where(hit_cand, cand_d[:, :, None], 0.0), axis=1)
    new_idx = jnp.sum(jnp.where(hit_cur, cur_idx[:, :, None], 0), axis=1) \
        + jnp.sum(jnp.where(hit_cand, cand[:, :, None], 0), axis=1)
    return new_idx.astype(i32), new_d, improved


def _knn_merge_kernel(qid_ref, gat_ref, cur_idx_ref, cand_ref, qid_v_ref,
                      curw_ref, candval_ref, x_ref, idx_out, d_out, imp_out,
                      acc, q_scr, c_scr, q_sem, c_sem, *, m_size: int,
                      block_m: int, sub_b: int, persistent_q: bool,
                      k_cur: int, rescore: bool):
    """One (block_b, block_m) tile: gather+score rows, merge on last chunk.

    qid_ref: (block_b,) SMEM        query row ids (DMA addresses)
    gat_ref: (block_b, G) SMEM      clipped gather ids (G = C, or K+C when
                                    ``rescore``: [cur, cand] order)
    cur_idx_ref: (block_b, K) VMEM  unclipped resident ids (dedup compares)
    cand_ref: (block_b, C) VMEM     unclipped candidate ids
    qid_v_ref: (block_b, 1) VMEM    query ids (self-dedup compares)
    curw_ref: (block_b, K) VMEM     f32 cur_d (HD) / i32 cur_valid (rescore)
    candval_ref: (block_b, C) VMEM  i32 external candidate validity
    x_ref: (N, M) ANY               source matrix (stays in HBM)
    idx_out/d_out: (block_b, K)     merged neighbour list
    imp_out: (block_b, 1) i32       per-row improved flag
    acc: (block_b, G) VMEM          squared-distance accumulator scratch
    q_scr/c_scr/q_sem/c_sem         score_gather_block staging (G rows)
    """
    score_gather_block(qid_ref, gat_ref, x_ref, acc, q_scr, c_scr, q_sem,
                       c_sem, m_size=m_size, block_m=block_m, sub_b=sub_b,
                       persistent_q=persistent_q)
    j = pl.program_id(1)

    @pl.when(j == pl.num_programs(1) - 1)
    def _merge():
        if rescore:
            cur_d = jnp.where(curw_ref[...] != 0, acc[:, :k_cur], jnp.inf)
            cand_d = acc[:, k_cur:]
        else:
            cur_d = curw_ref[...]
            cand_d = acc[...]
        new_idx, new_d, improved = merge_select(
            qid_v_ref[...], cur_idx_ref[...], cur_d, cand_ref[...], cand_d,
            candval_ref[...] != 0)
        idx_out[...] = new_idx
        d_out[...] = new_d
        imp_out[...] = improved.astype(jnp.int32)[:, None]


@functools.partial(
    jax.jit, static_argnames=("rescore", "block_b", "block_m", "sub_b",
                              "persistent_q", "interpret"))
def knn_merge_pallas(
    x: jnp.ndarray,
    qid: jnp.ndarray,
    cur_idx: jnp.ndarray,
    cur_w: jnp.ndarray,
    cand: jnp.ndarray,
    cand_valid: jnp.ndarray,
    *,
    rescore: bool,
    block_b: int = 128,
    block_m: int = 512,
    sub_b: int = None,
    persistent_q: bool = None,
    interpret: bool = False,
):
    """Merge-fused refinement: score, dedup and top-K merge in one launch.

    Args:
      x: (N, M) source matrix, kept in HBM/ANY memory space.
      qid: (B,) int32 query row ids (assumed in-range).
      cur_idx: (B, K) int32 resident neighbour ids; SENTINEL = invalid.
      cur_w: (B, K) -- the stored sorted squared distances (f32) in HD
        mode, or the current-slot validity mask (bool) when ``rescore``.
      cand: (B, C) int32 candidate ids (out-of-range ids are gathered
        clipped, exactly like the ref, and deduped on their raw value).
      cand_valid: (B, C) bool external validity (active-row membership).
      rescore: gather + re-score the current neighbours too (LD mode: the
        embedding moved since ``cur_idx`` was merged).
    Returns:
      (new_idx (B, K) int32, new_d (B, K) f32, improved (B,) bool).
    """
    N, M = x.shape
    B, K = cur_idx.shape
    Bc, C = cand.shape
    assert Bc == B and qid.shape == (B,), (x.shape, qid.shape, cand.shape)
    assert cur_w.shape == (B, K), (cur_w.shape, cur_idx.shape)

    qid = qid.astype(jnp.int32)
    cur_idx = cur_idx.astype(jnp.int32)
    cand = cand.astype(jnp.int32)
    gat = jnp.clip(cand, 0, N - 1)
    if rescore:
        gat = jnp.concatenate([jnp.clip(cur_idx, 0, N - 1), gat], axis=1)
        cur_w = cur_w.astype(jnp.int32)       # validity mask travels as i32
    else:
        cur_w = cur_w.astype(jnp.float32)
    cand_valid = cand_valid.astype(jnp.int32)
    G = gat.shape[1]

    block_b, block_m, sub_b, persistent_q, n_mchunks, q_scr_shape = \
        plan_row_gather(B, M, G, x.dtype.itemsize, block_b=block_b,
                        block_m=block_m, sub_b=sub_b,
                        persistent_q=persistent_q)
    Bp = _round_up(B, block_b)
    if Bp != B:
        pad = Bp - B
        qid = jnp.pad(qid, (0, pad))
        cur_idx = jnp.pad(cur_idx, ((0, pad), (0, 0)))
        cand = jnp.pad(cand, ((0, pad), (0, 0)))
        gat = jnp.pad(gat, ((0, pad), (0, 0)))
        cur_w = jnp.pad(cur_w, ((0, pad), (0, 0)))
        cand_valid = jnp.pad(cand_valid, ((0, pad), (0, 0)))

    grid = (Bp // block_b, n_mchunks)
    outs = pl.pallas_call(
        functools.partial(_knn_merge_kernel, m_size=M, block_m=block_m,
                          sub_b=sub_b, persistent_q=persistent_q, k_cur=K,
                          rescore=rescore),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b,), lambda i, j: (i,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((block_b, G), lambda i, j: (i, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((block_b, K), lambda i, j: (i, 0)),
            pl.BlockSpec((block_b, C), lambda i, j: (i, 0)),
            pl.BlockSpec((block_b, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((block_b, K), lambda i, j: (i, 0)),
            pl.BlockSpec((block_b, C), lambda i, j: (i, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=[
            pl.BlockSpec((block_b, K), lambda i, j: (i, 0)),
            pl.BlockSpec((block_b, K), lambda i, j: (i, 0)),
            pl.BlockSpec((block_b, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bp, K), jnp.int32),
            jax.ShapeDtypeStruct((Bp, K), jnp.float32),
            jax.ShapeDtypeStruct((Bp, 1), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_b, G), jnp.float32),
            pltpu.VMEM(q_scr_shape, x.dtype),
            pltpu.VMEM((2, sub_b, G, block_m), x.dtype),
            pltpu.SemaphoreType.DMA((n_mchunks,)),
            pltpu.SemaphoreType.DMA((2,)),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(qid, gat, cur_idx, cand, qid[:, None], cur_w, cand_valid, x)
    new_idx, new_d, imp = outs
    return new_idx[:B], new_d[:B], imp[:B, 0] != 0


# --------------------------------------------------------------------------
# Candidate-fused sampling (§Perf H17): the kernel *generates* the
# candidate slots it scores.
#
# After PR 4 the selection epilogue lived in-kernel but candidate
# *generation* still ran as plain XLA: per step, `sample_hops`
# materialised an (n, s, K2) two-hop gather broadcast in HBM, the
# threefry split/randint chain re-ran, and the resulting (n, C) candidate
# tensor round-tripped HBM just to be re-read by this kernel's SMEM
# slabs.  Here the candidates are *derived* inside the kernel from state
# it already stages:
#
#   * draws come from the counter-based hash RNG in ``repro.core.knn``
#     (``hash3(salt, row, draw)``): the identical int32 arithmetic runs
#     scalar-side (SMEM values -> DMA addresses) and vector-side (VPU
#     lanes -> the merge's dedup operands), and the pure-jnp reference
#     sampler (``knn_lib.counter_candidates``) is bit-exact against both;
#   * one-hop picks read the row's resident first-table slab
#     (SMEM for addresses, VMEM one-hot for the vector value);
#   * two-hop picks chain through the second-table channel: the kernel
#     computes ``mid = first[r, a]`` from SMEM, DMAs the single element
#     ``second[mid, b]`` from HBM into paired SMEM/VMEM chain staging
#     (``plan_row_gather(chain_slots=...)``), and only then issues the
#     ``X[cand]`` row DMA through the shared double-buffered pipeline;
#   * uniform probes are pure hash arithmetic;
#   * precomputed "extra" slots (e.g. the cached reverse-edge table) ride
#     in as classic SMEM/VMEM operand slabs.
#
# Per-candidate ``active``-row flags are fetched by element DMAs issued at
# generation time and awaited just before the merge, so the whole
# activity gather overlaps the scoring sweep.


def _slot_plan(sources):
    """Static per-slot layout of a ``sources`` tuple (see
    ``knn_lib.counter_candidates`` for the grammar).  Slot ``g`` draws
    the hash counters ``2g`` (a) and ``2g + 1`` (b)."""
    slots = []
    n_chain = n_extra = 0
    for src in sources:
        kind, c = src[0], src[-1]
        for _ in range(c):
            ent = {"kind": kind, "g": len(slots)}
            if kind == "one_hop":
                ent["f"] = src[1]
            elif kind == "two_hop":
                ent["f"], ent["s"] = src[1], src[2]
                ent["t"] = n_chain
                n_chain += 1
            elif kind == "extra":
                ent["e"] = n_extra
                n_extra += 1
            elif kind != "uniform":
                raise ValueError(f"unknown candidate source {kind!r}")
            slots.append(ent)
    return slots, n_chain, n_extra


def _make_cand_kernel(*, sources, n_first, first_widths, second_shapes,
                      have_extra, have_active, rescore, k_cur, n_rows,
                      m_size, block_m, sub_b, persistent_q):
    """Build the kernel body for one static candidate-fused config."""
    from repro.core import knn as knn_lib   # deferred: core imports kernels

    slots, n_chain, _ = _slot_plan(sources)
    c_total = len(slots)
    koff = k_cur if rescore else 0
    chains = [e for e in slots if e["kind"] == "two_hop"]

    def kernel(*refs):
        it = iter(refs)
        qid_ref = next(it)                          # (block_b,) SMEM
        salt_ref = next(it)                         # (1, 1) SMEM
        first_s = [next(it) for _ in range(n_first)]
        extra_s = next(it) if have_extra else None
        curs_ref = next(it) if rescore else None    # clipped cur ids, SMEM
        cur_idx_ref = next(it)                      # (block_b, K) VMEM
        qid_v_ref = next(it)                        # (block_b, 1) VMEM
        curw_ref = next(it)                         # (block_b, K) VMEM
        first_v = [next(it) for _ in range(n_first)]
        extra_v = next(it) if have_extra else None
        second = [next(it) for _ in range(len(second_shapes))]
        act_ref = next(it) if have_active else None  # (N, 1) i32 ANY
        x_ref = next(it)                            # (N, M) ANY
        idx_out, d_out, imp_out = next(it), next(it), next(it)
        acc, q_scr, c_scr, q_sem, c_sem = (next(it), next(it), next(it),
                                           next(it), next(it))
        gat_smem = next(it)                         # (block_b, G) SMEM
        cand_vmem = next(it)                        # (block_b, C) VMEM
        if n_chain:
            chain_smem, chain_vmem, chain_sem = next(it), next(it), next(it)
        if have_active:
            actv, act_sem = next(it), next(it)

        j = pl.program_id(1)
        block_b = acc.shape[0]
        salt = salt_ref[0, 0]

        def sdraw(row, draw, bound):
            """Scalar counter draw (bit-identical to the vector path)."""
            h = knn_lib.hash3(salt, row, jnp.int32(draw))
            return (h & knn_lib._POS_MASK) % bound

        def chain_ends(r, ent):
            """(second table ref, mid, b) of one two-hop chain element."""
            row = qid_ref[r]
            sec = second[ent["s"]]
            n2, k2 = second_shapes[ent["s"]]
            a = sdraw(row, 2 * ent["g"], first_widths[ent["f"]])
            mid = first_s[ent["f"]][r, a]
            mid = jnp.where(mid == _SENTINEL, row % n2, mid)
            mid = jnp.clip(mid, 0, n2 - 1)
            return sec, mid, sdraw(row, 2 * ent["g"] + 1, k2)

        def chain_copies(op):
            def per_row(r, _):
                for ent in chains:            # static unroll (C is small)
                    sec, mid, b = chain_ends(r, ent)
                    op(pltpu.make_async_copy(
                        sec.at[mid, b], chain_smem.at[r, ent["t"]],
                        chain_sem.at[0]))
                    op(pltpu.make_async_copy(
                        sec.at[mid, b], chain_vmem.at[r, ent["t"]],
                        chain_sem.at[1]))
                return _
            jax.lax.fori_loop(0, block_b, per_row, None)

        def act_copy(r, g):
            return pltpu.make_async_copy(
                act_ref.at[gat_smem[r, koff + g], 0], actv.at[r, g],
                act_sem)

        @pl.when(j == 0)
        def _generate():
            if n_chain:
                chain_copies(lambda cp: cp.start())
                chain_copies(lambda cp: cp.wait())

            def fill_row(r, _):
                row = qid_ref[r]
                if rescore:
                    def cp_cur(k, _):
                        gat_smem[r, k] = curs_ref[r, k]
                        return _
                    jax.lax.fori_loop(0, k_cur, cp_cur, None)
                for ent in slots:             # static unroll
                    kind, g = ent["kind"], ent["g"]
                    if kind == "uniform":
                        v = sdraw(row, 2 * g, n_rows)
                    elif kind == "one_hop":
                        a = sdraw(row, 2 * g, first_widths[ent["f"]])
                        v = first_s[ent["f"]][r, a]
                    elif kind == "two_hop":
                        v = chain_smem[r, ent["t"]]
                    else:                     # extra
                        v = extra_s[r, ent["e"]]
                    gat_smem[r, koff + g] = jnp.clip(v, 0, n_rows - 1)
                    if have_active:
                        act_copy(r, g).start()
                return _
            jax.lax.fori_loop(0, block_b, fill_row, None)

            # vector pass: the same draws on VPU lanes feed the merge's
            # dedup compares (raw ids, SENTINELs preserved)
            rows_v = qid_v_ref[...]                      # (block_b, 1)
            g0 = 0
            for src in sources:
                kind, c = src[0], src[-1]
                sl = jax.lax.broadcasted_iota(jnp.int32, (1, c), 1) + g0
                if kind == "uniform":
                    blk = knn_lib.counter_randint(salt, rows_v, 2 * sl,
                                                  n_rows)
                elif kind == "one_hop":
                    tab = first_v[src[1]][...]
                    a = knn_lib.counter_randint(salt, rows_v, 2 * sl,
                                                tab.shape[1])
                    kk = jax.lax.broadcasted_iota(
                        jnp.int32, (1, 1, tab.shape[1]), 2)
                    blk = jnp.sum(jnp.where(a[:, :, None] == kk,
                                            tab[:, None, :], 0), axis=2)
                elif kind == "two_hop":
                    t0 = next(e["t"] for e in slots
                              if e["g"] == g0)
                    blk = chain_vmem[:, t0:t0 + c]
                else:                                     # extra
                    e0 = next(e["e"] for e in slots if e["g"] == g0)
                    blk = extra_v[:, e0:e0 + c]
                cand_vmem[:, g0:g0 + c] = blk.astype(jnp.int32)
                g0 += c

        score_gather_block(qid_ref, gat_smem, x_ref, acc, q_scr, c_scr,
                           q_sem, c_sem, m_size=m_size, block_m=block_m,
                           sub_b=sub_b, persistent_q=persistent_q)

        @pl.when(j == pl.num_programs(1) - 1)
        def _merge():
            if have_active:
                def drain(r, _):
                    for ent in slots:
                        act_copy(r, ent["g"]).wait()
                    return _
                jax.lax.fori_loop(0, block_b, drain, None)
                ext_valid = actv[...] != 0
            else:
                # all-true, computed (a literal bool array would be a
                # captured kernel constant)
                cv = cand_vmem[...]
                ext_valid = cv == cv
            if rescore:
                cur_d = jnp.where(curw_ref[...] != 0, acc[:, :k_cur],
                                  jnp.inf)
                cand_d = acc[:, k_cur:]
            else:
                cur_d = curw_ref[...]
                cand_d = acc[...]
            new_idx, new_d, improved = merge_select(
                qid_v_ref[...], cur_idx_ref[...], cur_d, cand_vmem[...],
                cand_d, ext_valid)
            idx_out[...] = new_idx
            d_out[...] = new_d
            imp_out[...] = improved.astype(jnp.int32)[:, None]

    return kernel


@functools.partial(
    jax.jit, static_argnames=("sources", "rescore", "block_b", "block_m",
                              "sub_b", "persistent_q", "interpret"))
def knn_merge_cand_pallas(
    x: jnp.ndarray,
    qid: jnp.ndarray,
    cur_idx: jnp.ndarray,
    cur_w: jnp.ndarray,
    salt,
    first_tables=(),
    second_tables=(),
    extra=None,
    active=None,
    *,
    sources,
    rescore: bool,
    block_b: int = 128,
    block_m: int = 512,
    sub_b: int = None,
    persistent_q: bool = None,
    interpret: bool = False,
):
    """Candidate-fused refinement: sample, score, dedup and merge in ONE
    launch (§Perf H17).

    Args mirror :func:`knn_merge_pallas` except that the (B, C) candidate
    operand is replaced by its *generator*: ``salt`` (int32 counter-RNG
    salt), ``sources`` (static layout, see ``knn_lib.counter_candidates``),
    ``first_tables`` (tuple of (B, Kf) resident slabs), ``second_tables``
    (tuple of (N2, K2) HBM tables for the chained two-hop picks) and
    optional ``extra`` precomputed slots.  ``active`` is the global (N,)
    bool membership mask (None == all rows active): per-candidate flags
    are DMA'd in-kernel, matching ``active[clip(cand)]`` on the ref.
    """
    N, M = x.shape
    B, K = cur_idx.shape
    # zero-width sources are legal in the grammar but contribute no
    # slots; drop them here so the static slot plan and the vector-pass
    # offsets only ever see populated sources (slot/draw numbering is
    # unchanged -- empty sources never advanced it)
    sources = tuple(s for s in sources if s[-1] > 0)
    slots, n_chain, n_extra = _slot_plan(sources)
    C = len(slots)
    assert C > 0, "cand-fused merge needs at least one candidate source"
    have_extra = n_extra > 0
    if have_extra:
        assert extra is not None and extra.shape == (B, n_extra), \
            (n_extra, None if extra is None else extra.shape)
    have_active = active is not None
    G = C + (K if rescore else 0)

    qid = qid.astype(jnp.int32)
    cur_idx = cur_idx.astype(jnp.int32)
    salt = jnp.asarray(salt, jnp.int32).reshape(1, 1)
    first_tables = tuple(f.astype(jnp.int32) for f in first_tables)
    second_tables = tuple(s.astype(jnp.int32) for s in second_tables)
    cur_w = cur_w.astype(jnp.int32 if rescore else jnp.float32)
    if rescore:
        curs = jnp.clip(cur_idx, 0, N - 1)
    if have_extra:
        extra = extra.astype(jnp.int32)
    if have_active:
        act = active.astype(jnp.int32)[:, None]

    block_b, block_m, sub_b, persistent_q, n_mchunks, q_scr_shape = \
        plan_row_gather(B, M, G, x.dtype.itemsize, block_b=block_b,
                        block_m=block_m, sub_b=sub_b,
                        persistent_q=persistent_q, chain_slots=n_chain)
    Bp = _round_up(B, block_b)
    if Bp != B:
        pad = Bp - B
        qid = jnp.pad(qid, (0, pad))
        cur_idx = jnp.pad(cur_idx, ((0, pad), (0, 0)))
        cur_w = jnp.pad(cur_w, ((0, pad), (0, 0)))
        first_tables = tuple(jnp.pad(f, ((0, pad), (0, 0)))
                             for f in first_tables)
        if rescore:
            curs = jnp.pad(curs, ((0, pad), (0, 0)))
        if have_extra:
            extra = jnp.pad(extra, ((0, pad), (0, 0)))

    def blk(width, space=None):
        kw = {} if space is None else {"memory_space": space}
        return pl.BlockSpec((block_b, width), lambda i, j: (i, 0), **kw)

    operands = [qid, salt]
    in_specs = [
        pl.BlockSpec((block_b,), lambda i, j: (i,),
                     memory_space=pltpu.SMEM),
        pl.BlockSpec((1, 1), lambda i, j: (0, 0), memory_space=pltpu.SMEM),
    ]
    for f in first_tables:
        operands.append(f)
        in_specs.append(blk(f.shape[1], pltpu.SMEM))
    if have_extra:
        operands.append(extra)
        in_specs.append(blk(n_extra, pltpu.SMEM))
    if rescore:
        operands.append(curs)
        in_specs.append(blk(K, pltpu.SMEM))
    operands += [cur_idx, qid[:, None], cur_w]
    in_specs += [blk(K), blk(1), blk(K)]
    for f in first_tables:
        operands.append(f)
        in_specs.append(blk(f.shape[1]))
    if have_extra:
        operands.append(extra)
        in_specs.append(blk(n_extra))
    for s in second_tables:
        operands.append(s)
        in_specs.append(pl.BlockSpec(memory_space=pltpu.ANY))
    if have_active:
        operands.append(act)
        in_specs.append(pl.BlockSpec(memory_space=pltpu.ANY))
    operands.append(x)
    in_specs.append(pl.BlockSpec(memory_space=pltpu.ANY))

    scratch = [
        pltpu.VMEM((block_b, G), jnp.float32),
        pltpu.VMEM(q_scr_shape, x.dtype),
        pltpu.VMEM((2, sub_b, G, block_m), x.dtype),
        pltpu.SemaphoreType.DMA((n_mchunks,)),
        pltpu.SemaphoreType.DMA((2,)),
        pltpu.SMEM((block_b, G), jnp.int32),
        pltpu.VMEM((block_b, C), jnp.int32),
    ]
    if n_chain:
        scratch += [pltpu.SMEM((block_b, n_chain), jnp.int32),
                    pltpu.VMEM((block_b, n_chain), jnp.int32),
                    pltpu.SemaphoreType.DMA((2,))]
    if have_active:
        scratch += [pltpu.VMEM((block_b, C), jnp.int32),
                    pltpu.SemaphoreType.DMA(())]

    kernel = _make_cand_kernel(
        sources=sources, n_first=len(first_tables),
        first_widths=tuple(f.shape[1] for f in first_tables),
        second_shapes=tuple(s.shape for s in second_tables),
        have_extra=have_extra, have_active=have_active, rescore=rescore,
        k_cur=K, n_rows=N, m_size=M, block_m=block_m, sub_b=sub_b,
        persistent_q=persistent_q)

    grid = (Bp // block_b, n_mchunks)
    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=[blk(K), blk(K), blk(1)],
        out_shape=[
            jax.ShapeDtypeStruct((Bp, K), jnp.int32),
            jax.ShapeDtypeStruct((Bp, K), jnp.float32),
            jax.ShapeDtypeStruct((Bp, 1), jnp.int32),
        ],
        scratch_shapes=scratch,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(*operands)
    new_idx, new_d, imp = outs
    return new_idx[:B], new_d[:B], imp[:B, 0] != 0

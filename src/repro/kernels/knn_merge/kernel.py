"""Pallas TPU kernel: merge-fused neighbour-list refinement.

The per-iteration KNN refinement has three phases: score C candidate rows
against each query (``pairwise_sqdist_gather``), invalidate duplicates
(``knn_lib.dedup_candidates``), and merge the survivors into the resident
sorted (K,) neighbour list (``knn_lib.merge_knn``).  After PRs 1-3 fused
the scoring, the *selection* still ran as plain XLA: the dedup
materialises (n, C, K) and (n, C, C) broadcast-compare bool tensors in
HBM, the (n, C) candidate distances round-trip through HBM between the
kernel and the merge, and ``merge_knn`` pays a full ``lax.top_k`` sort
over (n, K+C) even though the resident side is already sorted.

This kernel extends the gather-fused scoring loop so each row block,
after accumulating candidate distances in VMEM, performs the dedup and
the top-K merge *in-register* and emits only the new (n, K) idx/d arrays
plus a per-row ``improved`` flag: no candidate-distance buffer, no dedup
broadcast tensor, and no sort anywhere in the step HLO.

The merge is a *stable-rank* selection (``merge_select``): every element
of the virtual [current, candidate] concatenation gets its output rank
from O((K+C)^2) vectorised compares (ties broken by concatenation index,
exactly ``lax.top_k``'s stable order -- and exactly what a sorted
insertion of the C candidates would produce), and rank-k elements are
gathered into slot k by one-hot masked sums.  This is the dense,
branch-free equivalent of NN-descent's per-candidate sorted-insertion
update (Dong et al.); on the 8x128 VPU the quadratic compare block
(<= (block_b, 42, 42) at config defaults) is register-resident noise next
to the row-gather DMAs the loop already pays.

Two modes share the kernel:
  * HD refinement: the stored sorted ``cur_d`` rides in as an operand and
    only the C candidate rows are gathered and scored.
  * LD refinement (``rescore=True``): the embedding moved since the list
    was built, so the kernel gathers and re-scores current *and*
    candidate rows in one sweep (the fused current+candidate split the
    XLA path used to do) and masks invalid current slots to +inf via
    ``cur_valid``.

Scoring IS the ``pairwise_sqdist_gather`` pipeline: ``score_gather_block``
and ``plan_row_gather`` are imported from that package (ONE copy of the
SMEM index slabs, 2-slot double-buffered sub-block row DMAs, persistent-q
slab and clamped+masked final M chunk), with the accumulator landing in a
(block_b, G) scratch instead of an output block.  Grid is
(B/block_b, M/block_m) with ``dimension_semantics=("parallel",
"arbitrary")``: row blocks are independent, the M axis sequentially
revisits the block's accumulator and runs the merge on its final chunk.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params
from repro.kernels.pairwise_sqdist.kernel import (_round_up, plan_row_gather,
                                                  score_gather_block)

_SENTINEL = jnp.iinfo(jnp.int32).max


def merge_select(qid_col, cur_idx, cur_d, cand, cand_d, ext_valid):
    """In-register dedup + stable-rank top-K merge of one row block.

    Bit-reproduces ``knn_lib.dedup_candidates`` followed by
    ``knn_lib.merge_knn`` (whose ``lax.top_k`` breaks distance ties by
    concatenation index) as flat compare/select arithmetic: no sort, no
    dynamic gather, no (B, C, K) HBM tensor.  Shared by the Pallas kernel
    body and the ``knn_merge_rank_ref`` XLA implementation.

    Args:
      qid_col: (B, 1) int32 query row ids.
      cur_idx: (B, K) int32 resident neighbour ids (SENTINEL = invalid).
      cur_d: (B, K) f32 resident squared distances (+inf = invalid).
      cand: (B, C) int32 candidate ids (unclipped; SENTINEL = invalid).
      cand_d: (B, C) f32 candidate squared distances.
      ext_valid: (B, C) bool extra validity (e.g. active-row membership).
    Returns:
      (new_idx (B, K) int32, new_d (B, K) f32, improved (B,) bool).
    """
    _, k = cur_idx.shape
    c = cand.shape[1]
    i32 = jnp.int32

    def count(mask):                    # bool any() via i32 sum: TPU-safe
        return jnp.sum(mask.astype(i32), axis=-1)

    # ---- dedup (knn_lib.dedup_candidates semantics) ----
    self_dup = cand == qid_col
    in_cur = count(cand[:, :, None] == cur_idx[:, None, :]) > 0
    ci = jax.lax.broadcasted_iota(i32, (1, c, c), 1)
    cj = jax.lax.broadcasted_iota(i32, (1, c, c), 2)
    within = count((cand[:, :, None] == cand[:, None, :]) & (cj < ci)) > 0
    valid = ext_valid & ~(self_dup | in_cur | within | (cand == _SENTINEL))
    cand_d = jnp.where(valid, cand_d, jnp.inf)
    improved = count(cand_d < cur_d[:, k - 1:k]) > 0

    # ---- stable ranks over the virtual [cur, cand] concatenation ----
    # rank(e) = #{e': d[e'] < d[e]  or  (d[e'] == d[e] and e' before e)};
    # "before" is concatenation order, so cur always precedes cand and
    # within each side the original index decides -- lax.top_k's tie rule.
    cur_e = cur_d[:, :, None]           # element being ranked
    cand_e = cand_d[:, :, None]
    kk = jax.lax.broadcasted_iota(i32, (1, k, k), 1)
    kp = jax.lax.broadcasted_iota(i32, (1, k, k), 2)
    cur_vs_cur = (cur_d[:, None, :] < cur_e) \
        | ((cur_d[:, None, :] == cur_e) & (kp < kk))
    cand_vs_cur = cand_d[:, None, :] < cur_e          # cand never ties-first
    rank_cur = count(cur_vs_cur) + count(cand_vs_cur)
    cur_vs_cand = cur_d[:, None, :] <= cand_e         # cur always ties-first
    cand_vs_cand = (cand_d[:, None, :] < cand_e) \
        | ((cand_d[:, None, :] == cand_e) & (cj < ci))
    rank_cand = count(cur_vs_cand) + count(cand_vs_cand)

    # ---- one-hot rank -> slot selection (ranks >= K fall off the list) ----
    slot = jax.lax.broadcasted_iota(i32, (1, 1, k), 2)
    hit_cur = rank_cur[:, :, None] == slot            # (B, K, K)
    hit_cand = rank_cand[:, :, None] == slot          # (B, C, K)
    new_d = jnp.sum(jnp.where(hit_cur, cur_d[:, :, None], 0.0), axis=1) \
        + jnp.sum(jnp.where(hit_cand, cand_d[:, :, None], 0.0), axis=1)
    new_idx = jnp.sum(jnp.where(hit_cur, cur_idx[:, :, None], 0), axis=1) \
        + jnp.sum(jnp.where(hit_cand, cand[:, :, None], 0), axis=1)
    return new_idx.astype(i32), new_d, improved


def _knn_merge_kernel(qid_ref, gat_ref, cur_idx_ref, cand_ref, qid_v_ref,
                      curw_ref, candval_ref, x_ref, idx_out, d_out, imp_out,
                      acc, q_scr, c_scr, q_sem, c_sem, *, m_size: int,
                      block_m: int, sub_b: int, persistent_q: bool,
                      k_cur: int, rescore: bool):
    """One (block_b, block_m) tile: gather+score rows, merge on last chunk.

    qid_ref: (block_b,) SMEM        query row ids (DMA addresses)
    gat_ref: (block_b, G) SMEM      clipped gather ids (G = C, or K+C when
                                    ``rescore``: [cur, cand] order)
    cur_idx_ref: (block_b, K) VMEM  unclipped resident ids (dedup compares)
    cand_ref: (block_b, C) VMEM     unclipped candidate ids
    qid_v_ref: (block_b, 1) VMEM    query ids (self-dedup compares)
    curw_ref: (block_b, K) VMEM     f32 cur_d (HD) / i32 cur_valid (rescore)
    candval_ref: (block_b, C) VMEM  i32 external candidate validity
    x_ref: (N, M) ANY               source matrix (stays in HBM)
    idx_out/d_out: (block_b, K)     merged neighbour list
    imp_out: (block_b, 1) i32       per-row improved flag
    acc: (block_b, G) VMEM          squared-distance accumulator scratch
    q_scr/c_scr/q_sem/c_sem         score_gather_block staging (G rows)
    """
    score_gather_block(qid_ref, gat_ref, x_ref, acc, q_scr, c_scr, q_sem,
                       c_sem, m_size=m_size, block_m=block_m, sub_b=sub_b,
                       persistent_q=persistent_q)
    j = pl.program_id(1)

    @pl.when(j == pl.num_programs(1) - 1)
    def _merge():
        if rescore:
            cur_d = jnp.where(curw_ref[...] != 0, acc[:, :k_cur], jnp.inf)
            cand_d = acc[:, k_cur:]
        else:
            cur_d = curw_ref[...]
            cand_d = acc[...]
        new_idx, new_d, improved = merge_select(
            qid_v_ref[...], cur_idx_ref[...], cur_d, cand_ref[...], cand_d,
            candval_ref[...] != 0)
        idx_out[...] = new_idx
        d_out[...] = new_d
        imp_out[...] = improved.astype(jnp.int32)[:, None]


@functools.partial(
    jax.jit, static_argnames=("rescore", "block_b", "block_m", "sub_b",
                              "persistent_q", "interpret"))
def knn_merge_pallas(
    x: jnp.ndarray,
    qid: jnp.ndarray,
    cur_idx: jnp.ndarray,
    cur_w: jnp.ndarray,
    cand: jnp.ndarray,
    cand_valid: jnp.ndarray,
    *,
    rescore: bool,
    block_b: int = 128,
    block_m: int = 512,
    sub_b: int = None,
    persistent_q: bool = None,
    interpret: bool = False,
):
    """Merge-fused refinement: score, dedup and top-K merge in one launch.

    Args:
      x: (N, M) source matrix, kept in HBM/ANY memory space.
      qid: (B,) int32 query row ids (assumed in-range).
      cur_idx: (B, K) int32 resident neighbour ids; SENTINEL = invalid.
      cur_w: (B, K) -- the stored sorted squared distances (f32) in HD
        mode, or the current-slot validity mask (bool) when ``rescore``.
      cand: (B, C) int32 candidate ids (out-of-range ids are gathered
        clipped, exactly like the ref, and deduped on their raw value).
      cand_valid: (B, C) bool external validity (active-row membership).
      rescore: gather + re-score the current neighbours too (LD mode: the
        embedding moved since ``cur_idx`` was merged).
    Returns:
      (new_idx (B, K) int32, new_d (B, K) f32, improved (B,) bool).
    """
    N, M = x.shape
    B, K = cur_idx.shape
    Bc, C = cand.shape
    assert Bc == B and qid.shape == (B,), (x.shape, qid.shape, cand.shape)
    assert cur_w.shape == (B, K), (cur_w.shape, cur_idx.shape)

    qid = qid.astype(jnp.int32)
    cur_idx = cur_idx.astype(jnp.int32)
    cand = cand.astype(jnp.int32)
    gat = jnp.clip(cand, 0, N - 1)
    if rescore:
        gat = jnp.concatenate([jnp.clip(cur_idx, 0, N - 1), gat], axis=1)
        cur_w = cur_w.astype(jnp.int32)       # validity mask travels as i32
    else:
        cur_w = cur_w.astype(jnp.float32)
    cand_valid = cand_valid.astype(jnp.int32)
    G = gat.shape[1]

    block_b, block_m, sub_b, persistent_q, n_mchunks, q_scr_shape = \
        plan_row_gather(B, M, G, x.dtype.itemsize, block_b=block_b,
                        block_m=block_m, sub_b=sub_b,
                        persistent_q=persistent_q)
    Bp = _round_up(B, block_b)
    if Bp != B:
        pad = Bp - B
        qid = jnp.pad(qid, (0, pad))
        cur_idx = jnp.pad(cur_idx, ((0, pad), (0, 0)))
        cand = jnp.pad(cand, ((0, pad), (0, 0)))
        gat = jnp.pad(gat, ((0, pad), (0, 0)))
        cur_w = jnp.pad(cur_w, ((0, pad), (0, 0)))
        cand_valid = jnp.pad(cand_valid, ((0, pad), (0, 0)))

    grid = (Bp // block_b, n_mchunks)
    outs = pl.pallas_call(
        functools.partial(_knn_merge_kernel, m_size=M, block_m=block_m,
                          sub_b=sub_b, persistent_q=persistent_q, k_cur=K,
                          rescore=rescore),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b,), lambda i, j: (i,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((block_b, G), lambda i, j: (i, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((block_b, K), lambda i, j: (i, 0)),
            pl.BlockSpec((block_b, C), lambda i, j: (i, 0)),
            pl.BlockSpec((block_b, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((block_b, K), lambda i, j: (i, 0)),
            pl.BlockSpec((block_b, C), lambda i, j: (i, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=[
            pl.BlockSpec((block_b, K), lambda i, j: (i, 0)),
            pl.BlockSpec((block_b, K), lambda i, j: (i, 0)),
            pl.BlockSpec((block_b, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bp, K), jnp.int32),
            jax.ShapeDtypeStruct((Bp, K), jnp.float32),
            jax.ShapeDtypeStruct((Bp, 1), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_b, G), jnp.float32),
            pltpu.VMEM(q_scr_shape, x.dtype),
            pltpu.VMEM((2, sub_b, G, block_m), x.dtype),
            pltpu.SemaphoreType.DMA((n_mchunks,)),
            pltpu.SemaphoreType.DMA((2,)),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(qid, gat, cur_idx, cand, qid[:, None], cur_w, cand_valid, x)
    new_idx, new_d, imp = outs
    return new_idx[:B], new_d[:B], imp[:B, 0] != 0

"""Pure-jnp oracles for the merge-fused neighbour refinement.

Two reference implementations, same interface as the kernel:

  * :func:`knn_merge_ref` -- the exact legacy selection pipeline
    (``knn_lib.dedup_candidates`` + gather-ref distances +
    ``knn_lib.merge_knn``).  This is the 'xla' backend: with it, flipping
    ``cfg.merge_fused`` is bit-neutral on the XLA path, the same contract
    the gather-fused rewiring established.
  * :func:`knn_merge_rank_ref` -- the kernel's stable-rank selection
    (``merge_select``) as a flat XLA program: identical outputs with no
    ``top_k``/sort and no (B, C, K) dedup broadcast, used as the
    algebraic cross-check of the merge algorithm and as the B side of the
    selection-epilogue A/B benchmark.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.knn_merge.kernel import merge_select
from repro.kernels.pairwise_sqdist.ref import pairwise_sqdist_gather_ref


def _knn_lib():
    # Deferred: repro.core.__init__ imports funcsne, which imports this
    # package -- a module-level import here would close that cycle in
    # whichever direction loses the import race.
    from repro.core import knn as knn_lib
    return knn_lib


def _score(x, qid, cur_idx, cur_d, cand, cur_valid):
    """(cur_d, cand_d) exactly as the legacy call sites computed them."""
    if cur_d is None:
        # LD rescore: one fused launch scores current + candidate rows
        # (the embedding moved since the list was merged)
        both = jnp.concatenate([cur_idx, cand], axis=1)
        both_d = pairwise_sqdist_gather_ref(x, qid, both)
        cur_d, cand_d = jnp.split(both_d, [cur_idx.shape[1]], axis=1)
        cur_d = jnp.where(cur_valid, cur_d, jnp.inf)
    else:
        cand_d = pairwise_sqdist_gather_ref(x, qid, cand)
    return cur_d, cand_d


def knn_merge_ref(x, qid, cur_idx, cur_d, cand, *, cand_active=None,
                  cur_valid=None):
    """Legacy-pipeline oracle; see ops.py for the argument contract."""
    knn_lib = _knn_lib()
    valid = knn_lib.dedup_candidates(qid, cur_idx, cand)
    if cand_active is not None:
        valid &= cand_active
    cur_d, cand_d = _score(x, qid, cur_idx, cur_d, cand, cur_valid)
    return knn_lib.merge_knn(cur_idx, cur_d, cand, cand_d, valid)


def knn_merge_rank_ref(x, qid, cur_idx, cur_d, cand, *, cand_active=None,
                       cur_valid=None):
    """Stable-rank-selection oracle: the kernel's algorithm, flat XLA."""
    cur_d, cand_d = _score(x, qid, cur_idx, cur_d, cand, cur_valid)
    if cand_active is None:
        cand_active = jnp.ones(cand.shape, bool)
    return merge_select(qid[:, None], cur_idx, cur_d, cand, cand_d,
                        cand_active)


def knn_merge_cand_ref(x, qid, cur_idx, cur_d, *, salt, sources,
                       first_tables=(), second_tables=(), extra=None,
                       active=None, cur_valid=None, rank=False):
    """Candidate-fused oracle (§Perf H17): the counter-RNG jnp sampler
    feeding the selection pipeline.

    Generates the candidate block with ``knn_lib.counter_candidates``
    (bit-identical draws to the kernel's in-register generation -- flat
    two-hop gathers, no (B, s, K2) broadcast, no threefry) and resolves
    per-candidate activity as ``active[clip(cand)]`` exactly like the
    kernel's element DMAs.  ``rank=True`` runs the stable-rank selection
    (the kernel's algorithm as flat XLA) instead of the legacy
    dedup+top_k pipeline; both give identical outputs.
    """
    knn_lib = _knn_lib()
    cand = knn_lib.counter_candidates(salt, qid, sources, first_tables,
                                      second_tables, n_total=x.shape[0],
                                      extra=extra)
    cand_active = None
    if active is not None:
        cand_active = active[jnp.clip(cand, 0, active.shape[0] - 1)]
    fn = knn_merge_rank_ref if rank else knn_merge_ref
    return fn(x, qid, cur_idx, cur_d, cand, cand_active=cand_active,
              cur_valid=cur_valid)

"""TPU Pallas kernels for the FUnc-SNE framework.

Each kernel package provides:
  kernel.py -- ``pl.pallas_call`` + explicit BlockSpec VMEM tiling (TPU target)
  ops.py    -- jit'd public wrapper with backend selection
               ('pallas' on TPU, 'interpret' for CPU validation, 'xla' pure-jnp)
  ref.py    -- pure-jnp oracle used by tests and as the XLA fallback

Kernels (the compute hot-spots the paper optimises on GPU, re-tiled for TPU):
  pairwise_sqdist  -- blocked ||q - c||^2 for KNN candidate scoring (HD hot spot)
  ne_forces        -- fused variable-tail attraction/repulsion force evaluation
  flash_attention  -- causal GQA flash attention (LM prefill hot spot)
"""

"""TPU Pallas kernels for the FUnc-SNE framework.

Each kernel package provides:
  kernel.py -- ``pl.pallas_call`` + explicit BlockSpec VMEM tiling (TPU target)
  ops.py    -- jit'd public wrapper with backend selection
               ('pallas' on TPU, 'interpret' for CPU validation, 'xla' pure-jnp)
  ref.py    -- pure-jnp oracle used by tests and as the XLA fallback

Kernels (the compute hot-spots the paper optimises on GPU, re-tiled for TPU):
  pairwise_sqdist  -- blocked ||q - c||^2 for KNN candidate scoring (HD hot spot)
  knn_merge        -- merge-fused refinement: candidate scoring + in-register
                      dedup + stable top-K merge in one launch (no selection
                      epilogue, no top_k sort, no (B, C, K) dedup broadcast)
  ne_forces        -- fused variable-tail attraction/repulsion force evaluation
  flash_attention  -- causal GQA flash attention (LM prefill hot spot)

The two NE kernels each come in two flavours: the pre-gather form takes
already-gathered (B, C, M) / (B, K, d) operands, and the gather-fused form
(``*_gather``) takes *indices* and DMAs only the needed rows in-kernel
(source matrix stays in HBM/ANY; index slabs staged into SMEM by the
pipeline).  ``ne_forces_gather`` additionally offers a scatter-fused
output mode (``scatter_fused=True``): per-edge forces and their symmetric
reactions are index-binned in-kernel into per-segment (N, d)
displacement-field partials (grid partials reduced by one XLA sum; XLA
fallback on ``jax.ops.segment_sum``), so the per-edge tensors never
round-trip through HBM.  The gather-fused forms are the per-iteration
default and scatter fusion the default force epilogue (funcsne §Perf
H12/H13/H14); the pre-gather and edge-emitting forms remain for A/B
testing and as building blocks elsewhere.
"""

"""Public jit'd wrapper for the pairwise squared-distance kernel.

Backend selection:
  'pallas'    -- compiled Pallas kernel (TPU runtime)
  'interpret' -- Pallas interpret mode (CPU validation of the kernel body)
  'xla'       -- pure-jnp oracle (default on CPU; also the dry-run lowering path)
  'auto'      -- 'pallas' when a TPU is present, else 'xla'
"""
from __future__ import annotations

import jax

from repro.kernels import fallback
from repro.kernels.pairwise_sqdist.kernel import (
    pairwise_sqdist_gather_pallas, pairwise_sqdist_pallas)
from repro.kernels.pairwise_sqdist.ref import (
    pairwise_sqdist_gather_ref, pairwise_sqdist_ref)


def _default_backend() -> str:
    try:
        platform = jax.devices()[0].platform
    except Exception:  # pragma: no cover - device init failure
        platform = "cpu"
    return "pallas" if platform == "tpu" else "xla"


def pairwise_sqdist(q, c, *, backend: str = "auto"):
    """Squared distances between queries (B, M) and candidates (B, C, M)."""
    if backend == "auto":
        backend = _default_backend()
    if backend in ("pallas", "interpret"):
        return fallback.guarded(
            "pairwise_sqdist",
            lambda: pairwise_sqdist_pallas(q, c,
                                           interpret=backend == "interpret"),
            lambda: pairwise_sqdist_ref(q, c))
    if backend == "xla":
        return pairwise_sqdist_ref(q, c)
    raise ValueError(f"unknown backend {backend!r}")


def pairwise_sqdist_gather(x, qid, cand, *, backend: str = "auto"):
    """Index-taking squared distances: ``||x[qid[b]] - x[cand[b, j]]||^2``.

    Unlike :func:`pairwise_sqdist` the (B, C, M) gathered operand is never
    materialised in HBM -- the Pallas kernel DMAs the needed rows per block.
    The 'xla' path is the pure-jnp fallback used on CPU and as the dry-run
    lowering; it gathers explicitly but keeps the same semantics.
    """
    if backend == "auto":
        backend = _default_backend()
    if backend in ("pallas", "interpret"):
        return fallback.guarded(
            "pairwise_sqdist",
            lambda: pairwise_sqdist_gather_pallas(
                x, qid, cand, interpret=backend == "interpret"),
            lambda: pairwise_sqdist_gather_ref(x, qid, cand))
    if backend == "xla":
        return pairwise_sqdist_gather_ref(x, qid, cand)
    raise ValueError(f"unknown backend {backend!r}")

"""Pure-jnp oracle for the blocked squared-distance kernel."""
from __future__ import annotations

import jax.numpy as jnp


def pairwise_sqdist_ref(q: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """Squared Euclidean distance between each query and its candidates.

    Args:
      q: (B, M) query points.
      c: (B, C, M) candidate points gathered per query.
    Returns:
      (B, C) float32 squared distances ``||q[b] - c[b, j]||^2``.
    """
    q32 = q.astype(jnp.float32)
    c32 = c.astype(jnp.float32)
    diff = q32[:, None, :] - c32
    return jnp.sum(diff * diff, axis=-1)

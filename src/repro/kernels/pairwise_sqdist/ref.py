"""Pure-jnp oracle for the blocked squared-distance kernel."""
from __future__ import annotations

import jax.numpy as jnp


def pairwise_sqdist_ref(q: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """Squared Euclidean distance between each query and its candidates.

    Args:
      q: (B, M) query points.
      c: (B, C, M) candidate points gathered per query.
    Returns:
      (B, C) float32 squared distances ``||q[b] - c[b, j]||^2``.
    """
    q32 = q.astype(jnp.float32)
    c32 = c.astype(jnp.float32)
    diff = q32[:, None, :] - c32
    return jnp.sum(diff * diff, axis=-1)


def pairwise_sqdist_gather_ref(x: jnp.ndarray, qid: jnp.ndarray,
                               cand: jnp.ndarray) -> jnp.ndarray:
    """Index-taking oracle: gathers (with clipping) then calls the ref.

    Args:
      x: (N, M) source matrix.
      qid: (B,) int32 query row ids.
      cand: (B, C) int32 candidate row ids.
    Returns:
      (B, C) float32 ``||x[qid[b]] - x[cand[b, j]]||^2``.  Indices are
      clipped to [0, N); invalid slots are the caller's concern.
    """
    n = x.shape[0]
    q = x[jnp.clip(qid, 0, n - 1)]
    c = x[jnp.clip(cand, 0, n - 1)]
    return pairwise_sqdist_ref(q, c)

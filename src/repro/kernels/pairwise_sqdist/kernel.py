"""Pallas TPU kernel: blocked squared Euclidean distances.

This is the per-iteration hot spot of FUnc-SNE's iterative KNN: for every
point we score C candidate neighbours against the point's HD vector,
``out[b, j] = ||q[b] - c[b, j]||^2``.

TPU adaptation of the paper's GPU code (which assigns one CUDA thread per
(point, candidate) pair and loops over M serially): we tile the feature
dimension M into VMEM-resident blocks and accumulate partial squared
distances across a second grid axis, so HBM traffic is one pass over q and c
and arithmetic runs on 8x128 VPU lanes.  Grid: (B/block_b, M/block_m) with the
M axis innermost ("arbitrary" semantics -> sequential revisit of the same
output block, enabling accumulation).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params


def _sqdist_kernel(q_ref, c_ref, out_ref):
    """One (block_b, block_m) tile: accumulate partial squared distances."""
    m_idx = pl.program_id(1)

    q = q_ref[...].astype(jnp.float32)          # (block_b, block_m)
    c = c_ref[...].astype(jnp.float32)          # (block_b, C, block_m)
    diff = q[:, None, :] - c                    # (block_b, C, block_m)
    partial = jnp.sum(diff * diff, axis=-1)     # (block_b, C)

    @pl.when(m_idx == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(m_idx > 0)
    def _acc():
        out_ref[...] += partial


@functools.partial(jax.jit, static_argnames=("block_b", "block_m", "interpret"))
def pairwise_sqdist_pallas(
    q: jnp.ndarray,
    c: jnp.ndarray,
    *,
    block_b: int = 256,
    block_m: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """(B, M), (B, C, M) -> (B, C) float32 squared distances.

    Pads B up to ``block_b`` and M up to ``block_m``; zero-padding of M is
    exact (contributes 0 to the sum), padded B rows are dropped.
    """
    B, M = q.shape
    Bc, C, Mc = c.shape
    assert Bc == B and Mc == M, (q.shape, c.shape)

    block_b = min(block_b, _round_up(B, 8))
    block_m = min(block_m, _round_up(M, 128))
    Bp = _round_up(B, block_b)
    Mp = _round_up(M, block_m)
    if (Bp, Mp) != (B, M):
        q = jnp.pad(q, ((0, Bp - B), (0, Mp - M)))
        c = jnp.pad(c, ((0, Bp - B), (0, 0), (0, Mp - M)))

    grid = (Bp // block_b, Mp // block_m)
    out = pl.pallas_call(
        _sqdist_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_m), lambda i, j: (i, j)),
            pl.BlockSpec((block_b, C, block_m), lambda i, j: (i, 0, j)),
        ],
        out_specs=pl.BlockSpec((block_b, C), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Bp, C), jnp.float32),
        interpret=interpret,
    )(q, c)
    return out[:B]


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


# --------------------------------------------------------------------------
# Gather-fused variant: the kernel takes *indices*, not gathered operands.
#
# The pre-gather kernel above forces XLA to materialise X[cand] as an
# (B, C, M) HBM buffer (C+1 copies of every touched row) which the kernel
# then streams from HBM a second time.  Here X stays in HBM/ANY memory and
# each (block_b, block_m) grid step DMAs only the block_b * (C+1) row chunks
# it needs straight into VMEM scratch: per-iteration HBM traffic drops from
# write+read of the gathered buffer to a single gather-read, and the (N,C,M)
# intermediate disappears from the memory high-water mark.
#
# The index slab is staged into SMEM by the pipeline (BlockSpec with
# memory_space=SMEM) so DMA source addresses are scalar reads; SMEM
# footprint is O(block_b * C), never O(B).
#
# Pipelining (two orthogonal levers):
#   * double-buffered b loop: the block's rows are processed in ``sub_b``
#     sub-blocks through 2-slot VMEM staging -- sub-block p+1's row DMAs
#     are issued *before* sub-block p is computed, so the serial
#     issue-all/drain-all/compute-all schedule (DMA latency fully exposed)
#     becomes DMA/compute overlap, and resident staging drops from
#     O(block_b * (C+1) * block_m) to O(2 * sub_b * (C+1) * block_m).
#   * persistent q: when M spans several ``block_m`` chunks, the q rows
#     of a block are DMA'd once (all chunks, issued at j == 0 on
#     per-chunk semaphores) into a (n_mchunks, block_b, block_m) resident
#     slab, saving one q-row DMA round per extra M-chunk; candidate rows
#     still stream per chunk (they are the C-fold bigger term).


def score_gather_block(qid_ref, gat_ref, x_ref, acc, q_scr, c_scr, q_sem,
                       c_sem, *, m_size: int, block_m: int, sub_b: int,
                       persistent_q: bool):
    """One (block_b, block_m) grid step of the row-gather scoring pipeline.

    DMAs the q row and the G gathered rows of each block row straight from
    ``x_ref`` (HBM/ANY) into VMEM staging and accumulates partial squared
    distances into ``acc`` across the M grid axis.  The single copy of the
    pipeline shared by ``pairwise_sqdist_gather`` and the merge-fused
    ``knn_merge`` kernel (which runs its selection epilogue on ``acc``
    after the final chunk).

    qid_ref: (block_b,) SMEM        query row ids
    gat_ref: (block_b, G) SMEM      gathered (clipped) row ids
    x_ref: (N, M) ANY               source matrix (stays in HBM)
    acc: (block_b, G) VMEM          squared-distance accumulator
                                    (output block or scratch)
    q_scr: (n_mchunks, block_b, block_m) if persistent_q
           else (2, sub_b, block_m) VMEM staging
    c_scr: (2, sub_b, G, block_m) VMEM double-buffer staging
    q_sem: (n_mchunks,) / c_sem: (2,) DMA semaphores
    """
    j = pl.program_id(1)
    block_b, G = acc.shape
    n_sub = block_b // sub_b
    # Ragged M: clamp each chunk's start so the DMA stays in bounds and
    # mask the columns the previous chunk already covered.
    def chunk_start(jc):
        return jnp.minimum(jc * block_m, m_size - block_m)

    m0 = chunk_start(j)

    if persistent_q:
        n_mchunks = q_scr.shape[0]

        def q_dma(jc, r):
            return pltpu.make_async_copy(
                x_ref.at[qid_ref[r], pl.ds(chunk_start(jc), block_m)],
                q_scr.at[jc, r], q_sem.at[jc])

        @pl.when(j == 0)
        def _issue_all_q():
            def per_chunk(jc, _):
                jax.lax.fori_loop(
                    0, block_b, lambda r, x: (q_dma(jc, r).start(), x)[1],
                    None)
                return _
            jax.lax.fori_loop(0, n_mchunks, per_chunk, None)

    def sub_copies(p, op):
        """Start/wait the 2-slot staged row DMAs of sub-block ``p``."""
        slot = p % 2

        def row(lr, _):
            r = p * sub_b + lr
            if not persistent_q:
                op(pltpu.make_async_copy(
                    x_ref.at[qid_ref[r], pl.ds(m0, block_m)],
                    q_scr.at[slot, lr], c_sem.at[slot]))
            jax.lax.fori_loop(
                0, G, lambda k, x: (op(pltpu.make_async_copy(
                    x_ref.at[gat_ref[r, k], pl.ds(m0, block_m)],
                    c_scr.at[slot, lr, k], c_sem.at[slot])), x)[1], None)
            return _

        jax.lax.fori_loop(0, sub_b, row, None)

    sub_copies(0, lambda cp: cp.start())
    if persistent_q:
        # drain this m-chunk's q rows (issued during j == 0) while the
        # first candidate sub-block is in flight
        jax.lax.fori_loop(0, block_b,
                          lambda r, x: (q_dma(j, r).wait(), x)[1], None)

    def body(p, _):
        slot = p % 2

        @pl.when(p + 1 < n_sub)
        def _prefetch():                     # overlap: copy p+1, compute p
            sub_copies(p + 1, lambda cp: cp.start())

        sub_copies(p, lambda cp: cp.wait())

        base = p * sub_b
        if persistent_q:
            q = q_scr[j, pl.ds(base, sub_b)].astype(jnp.float32)
        else:
            q = q_scr[slot].astype(jnp.float32)     # (sub_b, block_m)
        c = c_scr[slot].astype(jnp.float32)         # (sub_b, G, block_m)
        diff = q[:, None, :] - c
        col = jax.lax.broadcasted_iota(jnp.int32, diff.shape, 2)
        fresh = (m0 + col) >= j * block_m           # not already accumulated
        partial = jnp.sum(jnp.where(fresh, diff * diff, 0.0), axis=-1)

        @pl.when(j == 0)
        def _init():
            acc[pl.ds(base, sub_b)] = partial

        @pl.when(j > 0)
        def _acc():
            acc[pl.ds(base, sub_b)] += partial

        return _

    jax.lax.fori_loop(0, n_sub, body, None)


def _pick_sub_b(block_b: int) -> int:
    """Largest-throughput sub-block that divides ``block_b``: small blocks
    stay monolithic (nothing to overlap), bigger ones pipeline in 8-row
    (one f32 sublane tile) sub-blocks."""
    if block_b <= 16 or block_b % 8:
        return block_b
    return 8


def plan_row_gather(B, M, G, itemsize, *, block_b, block_m, sub_b,
                    persistent_q, chain_slots=0):
    """Tiling plan for the row-gather scoring pipeline (shared with the
    merge-fused ``knn_merge`` kernel): resolves the block/sub-block sizes
    against the VMEM staging budget and the persistent-q heuristic.

    ``chain_slots`` is the second-table channel (§Perf H17): the
    candidate-fused merge kernel stages that many chained
    ``second_idx[mid, b]`` int32 picks per block row (one SMEM + one VMEM
    element each, so the in-flight X-row DMAs can take their addresses
    from SMEM while the merge reads the same values as vectors); the
    per-row chain staging is charged against the same budget as the row
    staging so a wide chain shrinks ``block_b`` like a wide ``G`` does.

    Returns (block_b, block_m, sub_b, persistent_q, n_mchunks,
    q_scr_shape) with ``G`` gathered rows per block row.
    """
    block_m = min(block_m, M)
    block_b = min(block_b, _round_up(B, 8))
    if sub_b is None:
        sub_b = _pick_sub_b(block_b)
    assert block_b % sub_b == 0, (block_b, sub_b)
    # keep the 2-slot (G+1) row-chunk staging comfortably inside VMEM
    # (+ the chained second-table picks: 2 int32 copies per chain slot)
    while block_b > 8 and 2 * min(sub_b, block_b) * (G + 1) * block_m \
            * itemsize + 2 * block_b * chain_slots * 4 > 8 * 2 ** 20:
        block_b //= 2
        # a halved block_b may no longer be a multiple of sub_b: every row
        # of a block must land in some sub-block, so re-derive a divisor
        sub_b = math.gcd(sub_b, block_b)
    n_mchunks = _round_up(M, block_m) // block_m
    if persistent_q is None:
        persistent_q = n_mchunks > 1 and n_mchunks * block_b * block_m \
            * itemsize <= 4 * 2 ** 20
    q_scr_shape = (n_mchunks, block_b, block_m) if persistent_q \
        else (2, sub_b, block_m)
    return block_b, block_m, sub_b, persistent_q, n_mchunks, q_scr_shape


@functools.partial(
    jax.jit, static_argnames=("block_b", "block_m", "sub_b", "persistent_q",
                              "interpret"))
def pairwise_sqdist_gather_pallas(
    x: jnp.ndarray,
    qid: jnp.ndarray,
    cand: jnp.ndarray,
    *,
    block_b: int = 128,
    block_m: int = 512,
    sub_b: int = None,
    persistent_q: bool = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """(N, M), (B,), (B, C) -> (B, C) f32: ``||X[qid[b]] - X[cand[b,j]]||^2``.

    Indices are clipped to [0, N); callers mask invalid slots themselves
    (SENTINEL handling lives in the KNN merge).  B is padded to ``block_b``
    with row-0 gathers that are dropped on exit; M is tiled at ``block_m``
    with a clamped+masked final chunk, so X is never padded or copied.

    ``sub_b`` (must divide ``block_b``) sets the double-buffer sub-block;
    ``persistent_q`` keeps all M-chunks of the block's q rows VMEM-resident
    (auto: on when M spans >1 chunk and the slab stays under ~4MB).
    """
    N, M = x.shape
    B, = qid.shape
    Bc, C = cand.shape
    assert Bc == B, (qid.shape, cand.shape)

    qid = jnp.clip(qid.astype(jnp.int32), 0, N - 1)
    cand = jnp.clip(cand.astype(jnp.int32), 0, N - 1)

    block_b, block_m, sub_b, persistent_q, n_mchunks, q_scr_shape = \
        plan_row_gather(B, M, C, x.dtype.itemsize, block_b=block_b,
                        block_m=block_m, sub_b=sub_b,
                        persistent_q=persistent_q)
    Bp = _round_up(B, block_b)
    if Bp != B:
        qid = jnp.pad(qid, (0, Bp - B))
        cand = jnp.pad(cand, ((0, Bp - B), (0, 0)))

    grid = (Bp // block_b, n_mchunks)
    out = pl.pallas_call(
        functools.partial(score_gather_block, m_size=M, block_m=block_m,
                          sub_b=sub_b, persistent_q=persistent_q),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b,), lambda i, j: (i,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((block_b, C), lambda i, j: (i, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=pl.BlockSpec((block_b, C), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Bp, C), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM(q_scr_shape, x.dtype),
            pltpu.VMEM((2, sub_b, C, block_m), x.dtype),
            pltpu.SemaphoreType.DMA((n_mchunks,)),
            pltpu.SemaphoreType.DMA((2,)),
        ],
        # row blocks are independent (Mosaic may split them across
        # TensorCores); the M axis sequentially revisits the same output
        # block to accumulate partial distances, so it must stay serial
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(qid, cand, x)
    return out[:B]

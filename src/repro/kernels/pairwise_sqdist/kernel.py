"""Pallas TPU kernel: blocked squared Euclidean distances.

This is the per-iteration hot spot of FUnc-SNE's iterative KNN: for every
point we score C candidate neighbours against the point's HD vector,
``out[b, j] = ||q[b] - c[b, j]||^2``.

TPU adaptation of the paper's GPU code (which assigns one CUDA thread per
(point, candidate) pair and loops over M serially): we tile the feature
dimension M into VMEM-resident blocks and accumulate partial squared
distances across a second grid axis, so HBM traffic is one pass over q and c
and arithmetic runs on 8x128 VPU lanes.  Grid: (B/block_b, M/block_m) with the
M axis innermost ("arbitrary" semantics -> sequential revisit of the same
output block, enabling accumulation).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sqdist_kernel(q_ref, c_ref, out_ref):
    """One (block_b, block_m) tile: accumulate partial squared distances."""
    m_idx = pl.program_id(1)

    q = q_ref[...].astype(jnp.float32)          # (block_b, block_m)
    c = c_ref[...].astype(jnp.float32)          # (block_b, C, block_m)
    diff = q[:, None, :] - c                    # (block_b, C, block_m)
    partial = jnp.sum(diff * diff, axis=-1)     # (block_b, C)

    @pl.when(m_idx == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(m_idx > 0)
    def _acc():
        out_ref[...] += partial


@functools.partial(jax.jit, static_argnames=("block_b", "block_m", "interpret"))
def pairwise_sqdist_pallas(
    q: jnp.ndarray,
    c: jnp.ndarray,
    *,
    block_b: int = 256,
    block_m: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """(B, M), (B, C, M) -> (B, C) float32 squared distances.

    Pads B up to ``block_b`` and M up to ``block_m``; zero-padding of M is
    exact (contributes 0 to the sum), padded B rows are dropped.
    """
    B, M = q.shape
    Bc, C, Mc = c.shape
    assert Bc == B and Mc == M, (q.shape, c.shape)

    block_b = min(block_b, _round_up(B, 8))
    block_m = min(block_m, _round_up(M, 128))
    Bp = _round_up(B, block_b)
    Mp = _round_up(M, block_m)
    if (Bp, Mp) != (B, M):
        q = jnp.pad(q, ((0, Bp - B), (0, Mp - M)))
        c = jnp.pad(c, ((0, Bp - B), (0, 0), (0, Mp - M)))

    grid = (Bp // block_b, Mp // block_m)
    out = pl.pallas_call(
        _sqdist_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_m), lambda i, j: (i, j)),
            pl.BlockSpec((block_b, C, block_m), lambda i, j: (i, 0, j)),
        ],
        out_specs=pl.BlockSpec((block_b, C), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Bp, C), jnp.float32),
        interpret=interpret,
    )(q, c)
    return out[:B]


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult

from repro.kernels.pairwise_sqdist.ops import pairwise_sqdist  # noqa: F401

"""Pure-jnp oracle: causal GQA attention with logit softcap / local window."""
from __future__ import annotations

import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, scale: float | None = None,
                        softcap: float = 0.0, window: int = 0):
    """Materialised-softmax causal attention.

    Args:
      q: (B, Hq, S, D); k, v: (B, Hkv, S, D) with Hq % Hkv == 0 (GQA).
      scale: logit scale (default 1/sqrt(D)).
      softcap: if > 0, logits are soft-capped ``cap * tanh(s / cap)`` (Gemma2).
      window: if > 0, sliding-window attention over the last ``window``
        positions (inclusive of self).
    Returns:
      (B, Hq, S, D) in q.dtype.
    """
    B, Hq, S, D = q.shape
    Hkv = k.shape[1]
    group = Hq // Hkv
    if scale is None:
        scale = D ** -0.5
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    rows = jnp.arange(S)[:, None]
    cols = jnp.arange(S)[None, :]
    mask = cols <= rows
    if window > 0:
        mask = mask & (cols > rows - window)
    s = jnp.where(mask, s, -1e30)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)

"""Pallas TPU kernel: causal GQA flash attention (online softmax).

Tiling: grid (B, Hq, S/block_q, S/block_k) with the KV axis innermost
("arbitrary" semantics).  Q tiles of (block_q, D) stay resident while KV
tiles stream through VMEM; running max / denominator / accumulator live in
VMEM scratch that persists across the KV sweep (the canonical multi-visit
accumulation pattern).  QK^T and PV land on the MXU (block_q x block_k x D
with D in {64, 128} -> hardware-aligned).  Supports GQA head mapping via the
K/V index_map, Gemma2-style logit softcapping (tanh applied *before* the
online max so the cap composes exactly with streaming softmax), and
sliding-window masking.

Memory: per-step VMEM = q(block_q*D) + k,v(2*block_k*D) + scratch
(block_q*(2*128+D)) floats; defaults (block_q=block_k=512, D=128) fit
comfortably in the ~16 MiB v5e VMEM with double buffering.

Causal block skipping is done with masking (not grid pruning); the wasted
upper-triangle tiles are ~50% of the sweep.  The production LM path
(repro.models.attention) uses the same blocking via lax.scan for the XLA
dry-run; this kernel is the TPU runtime replacement.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30
_LANES = 128


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, softcap: float, window: int,
                  block_q: int, block_k: int, n_k: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)          # (block_q, D)
    k = k_ref[0, 0].astype(jnp.float32)          # (block_k, D)
    v = v_ref[0, 0].astype(jnp.float32)          # (block_k, D)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)

    rows = iq * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0)
    cols = ik * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
    mask = cols <= rows
    if window > 0:
        mask = mask & (cols > rows - window)
    s = jnp.where(mask, s, _NEG_INF)

    m_prev = m_scr[:, :1]                         # (block_q, 1)
    l_prev = l_scr[:, :1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    # Fully-masked rows: m_new == -inf -> exp(0) == 1 spuriously; zero them.
    p = jnp.where(m_new > _NEG_INF / 2, p, 0.0)
    corr = jnp.where(m_prev > _NEG_INF / 2, jnp.exp(m_prev - m_new), 0.0)
    l_new = corr * l_prev + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
    l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(ik == n_k - 1)
    def _finalize():
        l = l_scr[:, :1]
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "scale", "softcap", "window", "block_q", "block_k", "interpret"))
def flash_attention_pallas(q, k, v, *, scale: float | None = None,
                           softcap: float = 0.0, window: int = 0,
                           block_q: int = 512, block_k: int = 512,
                           interpret: bool = False):
    """(B,Hq,S,D) x (B,Hkv,S,D)^2 -> (B,Hq,S,D), causal GQA flash attention."""
    B, Hq, S, D = q.shape
    Hkv = k.shape[1]
    assert Hq % Hkv == 0, (Hq, Hkv)
    group = Hq // Hkv
    if scale is None:
        scale = D ** -0.5

    block_q = min(block_q, _round_up(S, 8))
    block_k = min(block_k, _round_up(S, 8))
    Sp = _round_up(S, max(block_q, block_k))
    if Sp != S:
        # Padded KV columns have col_id > every real row -> causally masked.
        q = jnp.pad(q, ((0, 0), (0, 0), (0, Sp - S), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, Sp - S), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, Sp - S), (0, 0)))

    n_q = Sp // block_q
    n_k = Sp // block_k
    grid = (B, Hq, n_q, n_k)
    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, softcap=softcap,
                          window=window, block_q=block_q, block_k=block_k,
                          n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sp, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, _LANES), jnp.float32),
            pltpu.VMEM((block_q, _LANES), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :S, :]


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult

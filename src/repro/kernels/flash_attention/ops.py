"""Public jit'd wrapper for causal GQA flash attention."""
from __future__ import annotations

import jax

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import flash_attention_ref


def _default_backend() -> str:
    try:
        platform = jax.devices()[0].platform
    except Exception:  # pragma: no cover
        platform = "cpu"
    return "pallas" if platform == "tpu" else "xla"


def flash_attention(q, k, v, *, scale=None, softcap: float = 0.0,
                    window: int = 0, backend: str = "auto"):
    """Causal GQA attention; see ref.py for exact semantics."""
    if backend == "auto":
        backend = _default_backend()
    if backend == "pallas":
        return flash_attention_pallas(q, k, v, scale=scale, softcap=softcap,
                                      window=window)
    if backend == "interpret":
        return flash_attention_pallas(q, k, v, scale=scale, softcap=softcap,
                                      window=window, interpret=True)
    if backend == "xla":
        return flash_attention_ref(q, k, v, scale=scale, softcap=softcap,
                                   window=window)
    raise ValueError(f"unknown backend {backend!r}")

"""Sticky per-kernel-family degradation to the XLA reference path.

A production embedding service would rather run a kernel family on its
(slower, always-correct) XLA reference than crash the whole session the
moment one Pallas launch fails to build -- a Mosaic lowering bug on a new
shape, a VMEM plan that doesn't fit, a driver hiccup.  Every kernel
``ops.py`` wrapper routes its Pallas/interpret dispatch through
:func:`guarded`:

  * disabled (the default) it is a pure passthrough -- exceptions
    propagate exactly as before, so kernel tests keep failing loudly;
  * enabled (``funcsne.fit`` turns it on while a ``ResiliencePolicy``
    with ``sticky_fallback=True`` is active), a raising Pallas launch
    demotes its *family* to the XLA ref for the remainder of the process
    and the call is answered by the reference instead.  The demotion is
    sticky: later traces consult the registry up front, so one failure
    never re-raises per chunk.

Demotions and degenerate-plan fallbacks are recorded as structured events
(:func:`events`) -- the telemetry channel the resilience layer drains
into its own log.  ``repro.runtime.faults.KernelLaunchFault`` injects a
failure right before the Pallas builder runs, so the whole path is
exercised deterministically in CI.
"""
from __future__ import annotations

import contextlib
import threading
import warnings
from typing import Callable, Dict, List

from repro.runtime import faults

# Lock discipline: EVERY access to the module registries below -- reads
# included -- happens under _LOCK (async checkpoint writers and the
# chunk dispatch thread consult this module concurrently; a reader
# iterating _EVENTS while a writer appends is a race even under the
# GIL's best behaviour).  The lock is never held across a kernel launch:
# guarded() snapshots what it needs, releases, then runs.
_LOCK = threading.Lock()
_ENABLED = False
_DEMOTED: Dict[str, str] = {}       # family -> reason
_EVENTS: List[dict] = []
_NOTED: set = set()                 # dedup key of already-logged notes


def is_enabled() -> bool:
    with _LOCK:
        return _ENABLED


@contextlib.contextmanager
def enabled(on: bool = True):
    """Enable (or force-disable) guarded launches within a scope."""
    global _ENABLED
    with _LOCK:
        prev, _ENABLED = _ENABLED, bool(on)
    try:
        yield
    finally:
        with _LOCK:
            _ENABLED = prev


def demote(family: str, reason) -> None:
    """Sticky-demote ``family`` to its XLA reference path."""
    with _LOCK:
        if family in _DEMOTED:
            return
        _DEMOTED[family] = str(reason)
        _EVENTS.append({"kind": "kernel_demoted", "family": family,
                        "reason": str(reason)})
    warnings.warn(f"[kernels.fallback] demoting {family!r} to its XLA "
                  f"reference for the rest of the run: {reason}",
                  RuntimeWarning, stacklevel=2)


def is_demoted(family: str) -> bool:
    with _LOCK:
        return family in _DEMOTED


def demotions() -> Dict[str, str]:
    with _LOCK:
        return dict(_DEMOTED)


def note(family: str, reason: str) -> None:
    """Log a non-sticky degradation event (e.g. a degenerate VMEM plan
    answered by the XLA ref for one shape) exactly once per reason."""
    key = (family, reason)
    with _LOCK:
        if key in _NOTED:
            return
        _NOTED.add(key)
        _EVENTS.append({"kind": "kernel_fallback", "family": family,
                        "reason": reason})


def events(since: int = 0) -> List[dict]:
    with _LOCK:
        return list(_EVENTS[since:])


def n_events() -> int:
    with _LOCK:
        return len(_EVENTS)


def reset() -> None:
    """Clear all sticky state (tests)."""
    global _ENABLED
    with _LOCK:
        _ENABLED = False
        _DEMOTED.clear()
        _EVENTS.clear()
        _NOTED.clear()


def guarded(family: str, run_pallas: Callable[[], object],
            run_xla: Callable[[], object]):
    """Run ``run_pallas`` under the sticky-fallback contract.

    Passthrough when disabled.  When enabled: demoted families are
    answered by ``run_xla`` up front; otherwise injected faults
    (``repro.runtime.faults``) and real launch/lowering exceptions demote
    the family and the XLA ref answers this call and every later one.
    """
    if not is_enabled():
        return run_pallas()
    if is_demoted(family):
        return run_xla()
    try:
        faults.check_kernel(family)
        return run_pallas()
    except Exception as e:
        demote(family, repr(e))
        return run_xla()

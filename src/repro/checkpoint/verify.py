"""Offline fsck for a checkpoint directory.

    python -m repro.checkpoint.verify <dir> [--step N]

Runs the same verification as ``Checkpointer.restore`` (CRC32 per shard
file, array manifest, row coverage, n_hosts consistency) over every
committed step -- or one ``--step`` -- printing one line per step and
exiting non-zero when any step is damaged.  No device memory is touched,
so this is safe to run against the checkpoint directory of a live run.
"""
from __future__ import annotations

import argparse
import sys

from repro.checkpoint.checkpointer import (CheckpointCorrupt,
                                           Checkpointer)


def verify_dir(directory, step=None, out=sys.stdout) -> int:
    """Verify every committed step (or just ``step``); returns the number
    of damaged steps.  Prints ``step N: OK ...`` / ``step N: CORRUPT ...``
    one line per step to ``out``."""
    ck = Checkpointer(directory, keep_last=0)    # never saves: no pruning
    steps = ck.all_steps()
    if step is not None:
        steps = [s for s in steps if s == step]
        if not steps:
            print(f"step {step}: NOT FOUND "
                  f"(available: {ck.all_steps() or '(none)'})", file=out)
            return 1
    if not steps:
        print(f"no committed checkpoints under {directory}", file=out)
        return 0
    bad = 0
    for s in steps:
        try:
            meta = ck.verify_step(s)
        except CheckpointCorrupt as e:
            bad += 1
            print(f"step {s}: CORRUPT -- {e.reason}", file=out)
            continue
        man = meta.get("manifest", {})
        print(f"step {s}: OK ({len(man.get('files', {}))} shard file(s), "
              f"n_hosts={man.get('n_hosts')})", file=out)
    return bad


def main(argv=None):
    ap = argparse.ArgumentParser(
        prog="python -m repro.checkpoint.verify",
        description="offline integrity check of a checkpoint directory")
    ap.add_argument("dir", help="checkpoint directory (holds step_* dirs)")
    ap.add_argument("--step", type=int, default=None,
                    help="verify only this step (default: all)")
    args = ap.parse_args(argv)
    bad = verify_dir(args.dir, step=args.step)
    if bad:
        print(f"{bad} damaged step(s)", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())

from repro.checkpoint.checkpointer import (CheckpointCorrupt,  # noqa: F401
                                           CheckpointError,
                                           CheckpointIncompatible,
                                           CheckpointNotFound,
                                           Checkpointer, cfg_compat,
                                           row_shard_filter)

from repro.checkpoint.checkpointer import (Checkpointer,  # noqa: F401
                                           row_shard_filter)

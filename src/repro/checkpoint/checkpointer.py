"""Fault-tolerant checkpointing: async, atomic, elastic.

Design (single-host container standing in for a multi-host pod):
  - save(): device_get the pytree off the step path (async thread by
    default), write one .npz per checkpoint with path-flattened keys, commit
    atomically via tmp-dir rename.  On a real pod each host writes only its
    addressable shards (`host_shard_filter`); here that set is all shards.
  - restore(): load latest (or a given) step; ``device_put`` with the
    *target* mesh's NamedShardings -- a checkpoint written on a 512-chip
    mesh restores onto 256 chips (elastic re-sharding) because arrays are
    stored unsharded and re-laid-out on load.
  - keep_last: old committed checkpoints are pruned.
  - metadata (step, data cursor, RNG, hyperparams) rides along as JSON.

QTensor (int8 optimiser moments) leaves flatten into q/scale arrays like
any other pytree node.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

_SEP = "||"


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten_into(tree, flat: dict):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in leaves:
        key = _SEP.join(str(p) for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key}")
        out.append(flat[key])
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(tree), out)


class Checkpointer:
    def __init__(self, directory, keep_last: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep_last = keep_last
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[BaseException] = None

    # -- save ------------------------------------------------------------

    def save(self, step: int, tree: Any, metadata: dict = None,
             blocking: bool = False):
        """Snapshot is taken synchronously (device_get); I/O is async."""
        self.wait()
        host_tree = jax.tree.map(np.asarray, jax.device_get(tree))
        meta = dict(metadata or {})
        meta["step"] = int(step)
        meta["time"] = time.time()

        def write():
            try:
                tmp = self.dir / f".tmp-{step}"
                if tmp.exists():
                    shutil.rmtree(tmp)
                tmp.mkdir(parents=True)
                np.savez(tmp / "arrays.npz", **_flatten(host_tree))
                (tmp / "meta.json").write_text(json.dumps(meta))
                final = self.dir / f"step_{step:010d}"
                if final.exists():
                    shutil.rmtree(final)
                os.rename(tmp, final)          # atomic commit
                self._prune()
            except BaseException as e:        # surfaced on next wait()
                self.last_error = e

        if blocking:
            write()
            if self.last_error is not None:   # blocking callers want it NOW
                err, self.last_error = self.last_error, None
                raise err
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err

    def _prune(self):
        steps = self.all_steps()
        for s in steps[:-self.keep_last]:
            shutil.rmtree(self.dir / f"step_{s:010d}", ignore_errors=True)

    # -- restore ---------------------------------------------------------

    def all_steps(self):
        return sorted(int(p.name.split("_")[1]) for p in self.dir.glob(
            "step_*") if (p / "meta.json").exists())

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like_tree: Any, step: Optional[int] = None,
                shardings: Any = None):
        """Returns (tree, metadata).  ``shardings``: optional NamedSharding
        tree for the *target* mesh (elastic re-shard on load)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = self.dir / f"step_{step:010d}"
        flat = dict(np.load(d / "arrays.npz", allow_pickle=False))
        meta = json.loads((d / "meta.json").read_text())
        tree = _unflatten_into(like_tree, flat)
        tree = jax.tree.map(
            lambda ref, x: np.asarray(x).astype(ref.dtype).reshape(ref.shape),
            like_tree, tree)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        return tree, meta

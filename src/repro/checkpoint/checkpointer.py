"""Fault-tolerant checkpointing: async, atomic, elastic, multi-host,
*verified*.

Design (single-host container standing in for a multi-host pod):
  - save(): device_get the pytree off the step path (async thread by
    default), write one .npz per checkpoint with path-flattened keys, commit
    atomically via tmp-dir rename.  On a pod each host writes ONLY its own
    shard file (``host_shard_filter`` + ``host_id``/``n_hosts``): parts are
    staged under the shared tmp dir and the host that completes the set
    commits, so checkpoint I/O scales with hosts instead of funnelling
    through one.  Elastic control planes additionally tag shards with a
    ``generation`` (``shard<h>-of-<H>-g<G>.npz``): the completing writer
    evicts stale-generation leftovers from the staging dir and the
    reader loads only the committing generation's manifest entries, so a
    half-dead generation's shards can never merge with a relaunch's.
  - integrity manifest: every save records, in ``meta.json``, a per-shard
    CRC32 of the file bytes plus an array manifest (key, dtype, shape,
    row range) -- computed from the in-memory bytes it is about to write,
    so the manifest is the ground truth a later reader can check the disk
    against.
  - verify_step(): re-reads every shard file and checks (a) the CRC32,
    (b) the exact array set with dtype/shape, (c) row coverage -- every
    host-sliced leaf covered exactly once, no gaps/overlaps across the
    ``shard*-of-*.npz`` set -- and (d) internal n_hosts consistency.
    Any violation raises a structured :class:`CheckpointCorrupt` naming
    the step, file and reason; a torn write, bit flip or deleted shard is
    detected *before* anything is materialised into device memory.
  - restore(): verify (on by default), then load, merging per-host shard
    files by row offset; ``device_put`` with the *target* mesh's
    NamedShardings -- a checkpoint written on a 512-chip mesh restores
    onto 256 chips (elastic re-sharding) because arrays are stored
    unsharded (or as host-row slices that merge to unsharded) and
    re-laid-out on load.  ``expect_compat=`` additionally checks the
    writer's config fingerprint (:func:`cfg_compat`: n, dims, K, flag
    matrix) against the restorer's and raises
    :class:`CheckpointIncompatible` on mismatch -- a cfg-mismatched
    resume fails structurally instead of silently loading garbage.
  - restore_verified(): the fallback chain -- walk committed steps
    newest -> oldest until one verifies, returning which damaged
    boundaries were skipped so the caller can log a
    ``checkpoint_fallback`` event per skip.  The step that verified is
    remembered and ``keep_last`` pruning never evicts it: graceful
    degradation must not saw off the branch it is standing on.
  - keep_last: old committed checkpoints are pruned (0 keeps nothing,
    except the last *verified* boundary, see above).
  - metadata (step, data cursor, RNG, hyperparams) rides along as JSON.
  - error surfacing: an async write failure raises on the next ``wait()``
    or ``save()``; ``close()`` (and ``__del__``) *warn* on an error nobody
    ever observed, so the final checkpoint of a run cannot vanish silently.

``python -m repro.checkpoint.verify <dir>`` runs the same verification
as an offline fsck over every committed step of a checkpoint directory.

QTensor (int8 optimiser moments) leaves flatten into q/scale arrays like
any other pytree node.
"""
from __future__ import annotations

import io
import json
import os
import shutil
import threading
import time
import warnings
import zlib
from pathlib import Path
from typing import Any, Callable, List, Optional

import jax
import numpy as np

_SEP = "||"
_ROWS = "@rows"     # key suffix marking a host-sliced leaf: key||@rows<start>
_MANIFEST_SUFFIX = ".manifest.json"     # staged per-shard sidecar (tmp only)


# --------------------------------------------------------------------------
# Structured errors


class CheckpointError(RuntimeError):
    """Base class for structured checkpoint failures."""


class CheckpointNotFound(CheckpointError, FileNotFoundError):
    """The requested step (or any step at all) is not committed.

    Attributes:
      step:      the step requested (None = latest).
      available: the committed steps actually present, oldest first.
    """

    def __init__(self, directory, step: Optional[int],
                 available: List[int]):
        what = "no checkpoints" if step is None \
            else f"no checkpoint for step {step}"
        super().__init__(
            f"{what} under {directory}; available steps: "
            f"{available if available else '(none)'}")
        self.step = step
        self.available = list(available)


class CheckpointCorrupt(CheckpointError):
    """A committed checkpoint failed integrity verification.

    Attributes:
      step:   the step that failed.
      path:   the step directory.
      reason: what exactly failed (missing shard, CRC mismatch, row
              coverage gap/overlap, dtype/shape drift, ...).
    """

    def __init__(self, path, step: int, reason: str):
        super().__init__(
            f"checkpoint step {step} under {path} failed verification: "
            f"{reason}")
        self.step = step
        self.path = str(path)
        self.reason = reason


class CheckpointIncompatible(CheckpointError):
    """The checkpoint verifies but was written under an incompatible
    config (different n / dims / K / fused-flag matrix): restoring it
    would poison the resumed run rather than continue it.

    Attributes:
      step:       the step checked.
      mismatches: ``{field: (checkpoint_value, expected_value)}``.
    """

    def __init__(self, path, step: int, mismatches: dict):
        diffs = ", ".join(f"{k}: checkpoint={a!r} != expected={b!r}"
                          for k, (a, b) in sorted(mismatches.items()))
        super().__init__(
            f"checkpoint step {step} under {path} is incompatible with "
            f"the resuming config: {diffs}")
        self.step = step
        self.path = str(path)
        self.mismatches = mismatches


def cfg_compat(cfg) -> dict:
    """Restore-compatibility fingerprint of a ``FuncSNEConfig``-like
    object: the fields a resumed run must agree on for the restored
    state to mean the same thing (array geometry) and for the random
    streams to continue bit-identically (the fused-flag matrix).
    Duck-typed so the checkpoint layer never imports ``repro.core``.
    """
    return {
        "n": int(cfg.n_points), "dim_hd": int(cfg.dim_hd),
        "dim_ld": int(cfg.dim_ld), "k_hd": int(cfg.k_hd),
        "k_ld": int(cfg.k_ld), "c_hd_rev": int(cfg.c_hd_rev),
        "flags": {
            "gather_fused": bool(cfg.gather_fused),
            "scatter_fused": bool(cfg.scatter_fused),
            "merge_fused": bool(cfg.merge_fused),
            "cand_fused": bool(cfg.cand_fused),
        },
    }


def _compat_mismatches(recorded: dict, expected: dict, prefix="") -> dict:
    out = {}
    for k, want in expected.items():
        have = recorded.get(k) if isinstance(recorded, dict) else None
        if isinstance(want, dict):
            out.update(_compat_mismatches(have or {}, want,
                                          prefix=f"{prefix}{k}."))
        elif have != want:
            out[f"{prefix}{k}"] = (have, want)
    return out


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten_into(tree, flat: dict):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in leaves:
        key = _SEP.join(str(p) for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key}")
        out.append(flat[key])
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(tree), out)


def row_shard_filter(host_id: int, n_hosts: int, n_rows: int) -> Callable:
    """Standard per-host filter: host ``h`` persists rows
    ``[h*n/H, (h+1)*n/H)`` of every leaf whose leading dim is ``n_rows``;
    host 0 additionally persists every other (replicated / scalar) leaf.
    Feed the result to :meth:`Checkpointer.save` as ``host_shard_filter``.
    """
    def filt(key: str, arr: np.ndarray):
        if arr.ndim >= 1 and arr.shape[0] == n_rows:
            lo = host_id * n_rows // n_hosts
            hi = (host_id + 1) * n_rows // n_hosts
            return lo, arr[lo:hi]
        return (None, arr) if host_id == 0 else None
    return filt


class Checkpointer:
    def __init__(self, directory, keep_last: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep_last = keep_last
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[BaseException] = None
        # last step that PASSED verification: pruning never evicts it,
        # so the fallback chain always has a floor to land on
        self._verified_step: Optional[int] = None

    # -- save ------------------------------------------------------------

    def save(self, step: int, tree: Any, metadata: dict = None,
             blocking: bool = False, host_shard_filter: Callable = None,
             host_id: int = 0, n_hosts: int = 1,
             generation: Optional[int] = None):
        """Snapshot is taken synchronously (device_get); I/O is async.

        ``host_shard_filter(key, array)`` selects what THIS host writes:
        ``None`` skips the leaf (another host owns it), ``(None, arr)``
        writes it whole, ``(start, rows)`` writes a row slice merged back
        by offset on restore (see :func:`row_shard_filter`).  With
        ``n_hosts > 1`` each host stages ``shard<h>-of-<H>.npz`` under
        the shared tmp dir and the host completing the set commits; a
        step directory is therefore only ever visible fully merged.

        ``generation`` (elastic runtimes: the pod-incarnation number the
        control plane bumps on every relaunch) tags the shard files --
        ``shard<h>-of-<H>-g<G>.npz`` -- and is recorded in the metadata.
        The completing writer only counts ITS generation's parts toward
        the set and EVICTS every stale-generation file still staged in
        the tmp dir before committing, so shards written by a generation
        that died mid-checkpoint can never be merged into a later
        generation's boundary (the reader additionally loads only the
        files named by the committing generation's manifest).  With a
        generation the staged-shard layout is used even for
        ``n_hosts == 1``, keeping tag semantics uniform across remesh
        widths.
        """
        self.wait()
        host_tree = jax.tree.map(np.asarray, jax.device_get(tree))
        meta = dict(metadata or {})
        meta["step"] = int(step)
        meta["time"] = time.time()
        meta["n_hosts"] = int(n_hosts)
        if generation is not None:
            meta["generation"] = int(generation)

        flat, arrays_meta = {}, {}
        for key, arr in _flatten(host_tree).items():
            if host_shard_filter is None:
                picked = (None, arr)
            else:
                picked = host_shard_filter(key, arr)
                if picked is None:
                    continue
            start, part = picked
            entry = {"dtype": str(part.dtype), "shape": list(part.shape)}
            if start is None:
                flat[key] = part
            else:
                entry["rows"] = [int(start), int(start) + int(part.shape[0])]
                entry["full_rows"] = int(arr.shape[0])
                key = f"{key}{_SEP}{_ROWS}{int(start)}"
                flat[key] = part
            arrays_meta[key] = entry

        def write():
            try:
                # serialise in memory first: the CRC32 in the manifest is
                # computed over the exact bytes that hit the disk
                buf = io.BytesIO()
                np.savez(buf, **flat)
                blob = buf.getvalue()
                file_meta = {"crc32": zlib.crc32(blob) & 0xFFFFFFFF,
                             "arrays": arrays_meta}
                tmp = self.dir / f".tmp-{step}"
                final = self.dir / f"step_{step:010d}"
                gen_tag = "" if generation is None \
                    else f"-g{int(generation):06d}"
                if n_hosts == 1 and generation is None:
                    # single writer: no commit race is possible, so the
                    # overwrite-an-existing-step semantics are safe here
                    if tmp.exists():
                        shutil.rmtree(tmp)
                    tmp.mkdir(parents=True)
                    (tmp / "arrays.npz").write_bytes(blob)
                    meta["manifest"] = {"n_hosts": 1,
                                        "files": {"arrays.npz": file_meta}}
                    (tmp / "meta.json").write_text(json.dumps(meta))
                    if final.exists():
                        shutil.rmtree(final)
                    os.rename(tmp, final)          # atomic commit
                else:
                    # multi-writer staging: parts land independently,
                    # the completing host commits.  Each host stages its
                    # manifest sidecar BEFORE the npz becomes visible, so
                    # a visible shard always has its manifest on disk.
                    tmp.mkdir(parents=True, exist_ok=True)
                    part = tmp / (f"shard{host_id:03d}-of-{n_hosts:03d}"
                                  f"{gen_tag}.npz")
                    (tmp / (part.name + _MANIFEST_SUFFIX)).write_text(
                        json.dumps(file_meta))
                    part_tmp = part.with_suffix(".npz.tmp")
                    part_tmp.write_bytes(blob)
                    os.replace(part_tmp, part)
                    parts = sorted(
                        tmp.glob(f"shard*-of-{n_hosts:03d}{gen_tag}.npz"))
                    if len(parts) < n_hosts:
                        return          # another host completes the set
                    # Exactly ONE completing writer may commit: real
                    # SPMD processes hit the boundary near-
                    # simultaneously, so BOTH can glob a full set.  The
                    # commit is claimed with an O_EXCL marker beside the
                    # staging dir; the race's loser backs off here
                    # instead of renaming (or deleting!) the winner's
                    # just-committed step dir.  The claim is generation-
                    # tagged so a claim left by a writer that died mid-
                    # commit can never block a relaunched generation
                    # from committing the same step.
                    claim = self.dir / f".tmp-{step}.claim{gen_tag}"
                    try:
                        os.close(os.open(str(claim),
                                         os.O_CREAT | os.O_EXCL
                                         | os.O_WRONLY))
                    except FileExistsError:
                        return  # the other completing writer commits
                    try:
                        files = {}
                        for p in parts:
                            side = tmp / (p.name + _MANIFEST_SUFFIX)
                            files[p.name] = json.loads(side.read_text())
                            side.unlink()
                        if generation is not None:
                            # completing writer owns the commit: any
                            # file still staged that is NOT part of this
                            # generation's set is a stale shard (or torn
                            # tmp/sidecar) from a generation that died
                            # mid-checkpoint -- evict it so it can
                            # neither merge into this boundary nor
                            # linger on disk
                            keep = {p.name for p in parts}
                            evicted = []
                            for f in sorted(tmp.iterdir()):
                                if f.name not in keep:
                                    f.unlink()
                                    evicted.append(f.name)
                            if evicted:
                                meta["evicted_stale"] = evicted
                        meta["manifest"] = {"n_hosts": n_hosts,
                                            "files": files}
                        (tmp / "meta.json").write_text(json.dumps(meta))
                        # never pre-delete `final` here: with the claim
                        # released post-commit a straggling writer can
                        # still reach this point, and an rmtree would
                        # destroy the committed boundary elastic resume
                        # depends on.  rename IS the atomic commit; its
                        # failure with the boundary present just means
                        # the other writer won.
                        os.rename(tmp, final)
                    except OSError:
                        if (final / "meta.json").exists():
                            # lost the race: the boundary is committed
                            claim.unlink(missing_ok=True)
                            return
                        raise
                    for c in self.dir.glob(f".tmp-{step}.claim*"):
                        try:
                            c.unlink()
                        except OSError:     # pragma: no cover
                            pass
                self._prune()
            except BaseException as e:        # surfaced on next wait()
                self.last_error = e

        if blocking:
            write()
            if self.last_error is not None:   # blocking callers want it NOW
                err, self.last_error = self.last_error, None
                raise err
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err

    def close(self):
        """Join any in-flight write; WARN (never raise) on an error that
        no ``wait()`` ever observed.  Safe on error-handling paths where
        raising would mask the in-flight exception."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            warnings.warn(
                f"[checkpoint] async write under {self.dir} failed and the "
                f"error was never observed by wait(): {err!r} -- the last "
                f"checkpoint of this run may be missing", RuntimeWarning,
                stacklevel=2)

    def __del__(self):
        # a Checkpointer dropped with a pending failure must not take the
        # evidence with it; never join/raise during interpreter teardown
        err = getattr(self, "last_error", None)
        if err is not None:
            self.last_error = None      # deliver once
            try:
                warnings.warn(
                    f"[checkpoint] Checkpointer({self.dir}) garbage-"
                    f"collected with an unobserved write error: {err!r}",
                    RuntimeWarning, stacklevel=2)
            except Exception:       # pragma: no cover - teardown races
                pass

    def _prune(self):
        steps = self.all_steps()
        # keep_last=0 keeps NOTHING: guard the [:-0] empty slice that
        # would silently keep everything
        drop = steps if self.keep_last <= 0 else steps[:-self.keep_last]
        for s in drop:
            if s == self._verified_step:
                # never evict the boundary the fallback chain last landed
                # on: newer checkpoints exist but have NOT been verified,
                # so this is the only committed step known to be good
                continue
            shutil.rmtree(self.dir / f"step_{s:010d}", ignore_errors=True)

    # -- restore ---------------------------------------------------------

    def all_steps(self):
        return sorted(int(p.name.split("_")[1]) for p in self.dir.glob(
            "step_*") if (p / "meta.json").exists())

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def _load_merged(self, d: Path, meta: Optional[dict] = None) -> dict:
        """Load one committed step dir, merging per-host shard files:
        plain keys load as-is, ``key||@rows<start>`` slices concat by
        offset.  The single-host ``arrays.npz`` layout is the n_hosts=1
        special case of the same reader.

        When ``meta`` carries a manifest, ONLY the files it names are
        read: the manifest was written by the generation that committed
        the boundary, so a stale-generation shard that somehow survived
        into the directory is filtered out rather than merged (the
        verifying reader additionally flags it as a stray)."""
        man = (meta or {}).get("manifest")
        if isinstance(man, dict) and man.get("files"):
            files = [d / name for name in sorted(man["files"])]
        else:
            files = sorted(d.glob("shard*-of-*.npz"))
            if not files:
                files = [d / "arrays.npz"]
        flat, sliced = {}, {}
        for f in files:
            with np.load(f, allow_pickle=False) as z:
                for key in z.files:
                    if _SEP + _ROWS in key:
                        base, _, start = key.rpartition(_SEP + _ROWS)
                        sliced.setdefault(base, []).append(
                            (int(start), z[key]))
                    else:
                        flat[key] = z[key]
        for base, parts in sliced.items():
            parts.sort(key=lambda p: p[0])
            flat[base] = np.concatenate([a for _, a in parts], axis=0) \
                if len(parts) > 1 else parts[0][1]
        return flat

    # -- verify ----------------------------------------------------------

    def verify_step(self, step: int) -> dict:
        """Full integrity check of one committed step WITHOUT
        materialising anything: CRC32 of every shard file, exact array
        set with dtype/shape, row coverage (each host-sliced leaf covered
        exactly once, no gaps/overlaps) and internal n_hosts consistency.
        Returns the checkpoint metadata on success; raises
        :class:`CheckpointCorrupt` naming the failure otherwise."""
        d = self.dir / f"step_{step:010d}"
        if not (d / "meta.json").exists():
            raise CheckpointNotFound(self.dir, step, self.all_steps())
        try:
            meta = json.loads((d / "meta.json").read_text())
        except (OSError, ValueError) as e:
            raise CheckpointCorrupt(d, step, f"meta.json unreadable: {e}")
        man = meta.get("manifest")
        if not isinstance(man, dict) or "files" not in man:
            raise CheckpointCorrupt(
                d, step, "meta.json carries no integrity manifest "
                "(checkpoint predates verification?)")
        want_files = man["files"]
        have = sorted(p.name for p in d.glob("*.npz"))
        missing = sorted(set(want_files) - set(have))
        stray = sorted(set(have) - set(want_files))
        if missing:
            raise CheckpointCorrupt(
                d, step, f"missing shard file(s): {missing}")
        if stray:
            raise CheckpointCorrupt(
                d, step, f"file(s) not in manifest: {stray}")
        if int(man.get("n_hosts", len(want_files))) != len(want_files):
            raise CheckpointCorrupt(
                d, step, f"manifest n_hosts={man.get('n_hosts')} but "
                f"{len(want_files)} shard file(s) recorded")

        coverage = {}   # base key -> [(start, stop, full_rows, fname)]
        plain_seen = {}  # base key -> fname (unsliced leaves)
        for fname, fman in sorted(want_files.items()):
            try:
                blob = (d / fname).read_bytes()
            except OSError as e:
                raise CheckpointCorrupt(d, step, f"{fname}: unreadable: {e}")
            crc = zlib.crc32(blob) & 0xFFFFFFFF
            if crc != int(fman["crc32"]):
                raise CheckpointCorrupt(
                    d, step, f"{fname}: CRC32 mismatch "
                    f"(file {crc:#010x} != manifest "
                    f"{int(fman['crc32']) & 0xFFFFFFFF:#010x})")
            try:
                with np.load(io.BytesIO(blob), allow_pickle=False) as z:
                    info = {k: (str(z[k].dtype), list(z[k].shape))
                            for k in z.files}
            except Exception as e:
                raise CheckpointCorrupt(
                    d, step, f"{fname}: unloadable npz despite matching "
                    f"CRC: {e}")
            want_arrays = fman.get("arrays", {})
            if set(want_arrays) != set(info):
                gone = sorted(set(want_arrays) - set(info))
                extra = sorted(set(info) - set(want_arrays))
                raise CheckpointCorrupt(
                    d, step, f"{fname}: array set drifted from manifest "
                    f"(missing {gone}, unexpected {extra})")
            for key, am in want_arrays.items():
                dt, shp = info[key]
                if dt != am["dtype"] or shp != list(am["shape"]):
                    raise CheckpointCorrupt(
                        d, step, f"{fname}: {key}: {dt}{shp} != manifest "
                        f"{am['dtype']}{list(am['shape'])}")
                if "rows" in am:
                    lo, hi = int(am["rows"][0]), int(am["rows"][1])
                    if hi - lo != shp[0]:
                        raise CheckpointCorrupt(
                            d, step, f"{fname}: {key}: row range "
                            f"[{lo}, {hi}) disagrees with leading dim "
                            f"{shp[0]}")
                    base = key.rpartition(_SEP + _ROWS)[0]
                    coverage.setdefault(base, []).append(
                        (lo, hi, int(am["full_rows"]), fname))
                else:
                    if key in plain_seen:
                        raise CheckpointCorrupt(
                            d, step, f"leaf {key} written whole by both "
                            f"{plain_seen[key]} and {fname}")
                    plain_seen[key] = fname
        for base, parts in coverage.items():
            if base in plain_seen:
                raise CheckpointCorrupt(
                    d, step, f"leaf {base} written both whole "
                    f"({plain_seen[base]}) and row-sliced")
            full = {p[2] for p in parts}
            if len(full) != 1:
                raise CheckpointCorrupt(
                    d, step, f"leaf {base}: shards disagree on full row "
                    f"count: {sorted(full)}")
            n_rows = full.pop()
            pos = 0
            for lo, hi, _, fname in sorted(parts):
                if lo > pos:
                    raise CheckpointCorrupt(
                        d, step, f"leaf {base}: rows [{pos}, {lo}) "
                        f"uncovered")
                if lo < pos:
                    raise CheckpointCorrupt(
                        d, step, f"leaf {base}: rows [{lo}, {pos}) "
                        f"covered twice ({fname})")
                pos = hi
            if pos != n_rows:
                raise CheckpointCorrupt(
                    d, step, f"leaf {base}: rows [{pos}, {n_rows}) "
                    f"uncovered")
        return meta

    def _check_compat(self, d, step, meta, expect_compat):
        if expect_compat is None:
            return
        mism = _compat_mismatches(meta.get("compat") or {}, expect_compat)
        if mism:
            raise CheckpointIncompatible(d, step, mism)

    def restore(self, like_tree: Any, step: Optional[int] = None,
                shardings: Any = None, verify: bool = True,
                expect_compat: Optional[dict] = None):
        """Returns (tree, metadata).  ``shardings``: optional NamedSharding
        tree for the *target* mesh (elastic re-shard on load -- the mesh
        may be smaller than the one that wrote the checkpoint).

        ``verify=True`` (default) runs :meth:`verify_step` first, raising
        :class:`CheckpointCorrupt` before anything touches device memory.
        ``expect_compat`` (a :func:`cfg_compat` dict) raises
        :class:`CheckpointIncompatible` when the checkpoint was written
        under a different config fingerprint.  A missing step (or an
        empty directory) raises :class:`CheckpointNotFound` naming the
        available steps."""
        steps = self.all_steps()
        if step is None:
            if not steps:
                raise CheckpointNotFound(self.dir, None, [])
            step = steps[-1]
        elif step not in steps:
            raise CheckpointNotFound(self.dir, step, steps)
        d = self.dir / f"step_{step:010d}"
        if verify:
            meta = self.verify_step(step)
            self._verified_step = step
        else:
            meta = json.loads((d / "meta.json").read_text())
        self._check_compat(d, step, meta, expect_compat)
        flat = self._load_merged(d, meta)
        tree = _unflatten_into(like_tree, flat)
        tree = jax.tree.map(
            lambda ref, x: np.asarray(x).astype(ref.dtype).reshape(ref.shape),
            like_tree, tree)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        return tree, meta

    def restore_verified(self, like_tree: Any, step: Optional[int] = None,
                         shardings: Any = None,
                         expect_compat: Optional[dict] = None):
        """Fallback-chain restore: walk committed steps newest -> oldest
        (at most ``step``, when given) until one passes verification.

        Returns ``(tree, metadata, fallbacks)`` where ``fallbacks`` lists
        ``{"step", "reason"}`` for every damaged boundary that was
        skipped -- callers log one ``checkpoint_fallback`` event per
        entry.  Raises :class:`CheckpointNotFound` when nothing is
        committed, :class:`CheckpointCorrupt` when every committed step
        is damaged, and :class:`CheckpointIncompatible` immediately on a
        config mismatch (every boundary of a run shares its config, so
        falling back would only mask the user error)."""
        steps = self.all_steps()
        if step is not None:
            steps = [s for s in steps if s <= step]
        if not steps:
            raise CheckpointNotFound(self.dir, step, self.all_steps())
        fallbacks = []
        for s in reversed(steps):
            try:
                tree, meta = self.restore(like_tree, step=s,
                                          shardings=shardings,
                                          expect_compat=expect_compat)
            except CheckpointCorrupt as e:
                fallbacks.append({"step": s, "reason": e.reason})
                continue
            return tree, meta, fallbacks
        raise CheckpointCorrupt(
            self.dir, steps[-1],
            "every committed step failed verification: " + "; ".join(
                f"step {f['step']}: {f['reason']}" for f in fallbacks))

"""Fault-tolerant checkpointing: async, atomic, elastic, multi-host.

Design (single-host container standing in for a multi-host pod):
  - save(): device_get the pytree off the step path (async thread by
    default), write one .npz per checkpoint with path-flattened keys, commit
    atomically via tmp-dir rename.  On a pod each host writes ONLY its own
    shard file (``host_shard_filter`` + ``host_id``/``n_hosts``): parts are
    staged under the shared tmp dir and the host that completes the set
    commits, so checkpoint I/O scales with hosts instead of funnelling
    through one.
  - restore(): load latest (or a given) step, merging per-host shard files
    by row offset; ``device_put`` with the *target* mesh's NamedShardings
    -- a checkpoint written on a 512-chip mesh restores onto 256 chips
    (elastic re-sharding) because arrays are stored unsharded (or as
    host-row slices that merge to unsharded) and re-laid-out on load.
  - keep_last: old committed checkpoints are pruned (0 keeps nothing).
  - metadata (step, data cursor, RNG, hyperparams) rides along as JSON.
  - error surfacing: an async write failure raises on the next ``wait()``
    or ``save()``; ``close()`` (and ``__del__``) *warn* on an error nobody
    ever observed, so the final checkpoint of a run cannot vanish silently.

QTensor (int8 optimiser moments) leaves flatten into q/scale arrays like
any other pytree node.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
import warnings
from pathlib import Path
from typing import Any, Callable, Optional

import jax
import numpy as np

_SEP = "||"
_ROWS = "@rows"     # key suffix marking a host-sliced leaf: key||@rows<start>


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten_into(tree, flat: dict):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in leaves:
        key = _SEP.join(str(p) for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key}")
        out.append(flat[key])
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(tree), out)


def row_shard_filter(host_id: int, n_hosts: int, n_rows: int) -> Callable:
    """Standard per-host filter: host ``h`` persists rows
    ``[h*n/H, (h+1)*n/H)`` of every leaf whose leading dim is ``n_rows``;
    host 0 additionally persists every other (replicated / scalar) leaf.
    Feed the result to :meth:`Checkpointer.save` as ``host_shard_filter``.
    """
    def filt(key: str, arr: np.ndarray):
        if arr.ndim >= 1 and arr.shape[0] == n_rows:
            lo = host_id * n_rows // n_hosts
            hi = (host_id + 1) * n_rows // n_hosts
            return lo, arr[lo:hi]
        return (None, arr) if host_id == 0 else None
    return filt


class Checkpointer:
    def __init__(self, directory, keep_last: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep_last = keep_last
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[BaseException] = None

    # -- save ------------------------------------------------------------

    def save(self, step: int, tree: Any, metadata: dict = None,
             blocking: bool = False, host_shard_filter: Callable = None,
             host_id: int = 0, n_hosts: int = 1):
        """Snapshot is taken synchronously (device_get); I/O is async.

        ``host_shard_filter(key, array)`` selects what THIS host writes:
        ``None`` skips the leaf (another host owns it), ``(None, arr)``
        writes it whole, ``(start, rows)`` writes a row slice merged back
        by offset on restore (see :func:`row_shard_filter`).  With
        ``n_hosts > 1`` each host stages ``shard<h>-of-<H>.npz`` under
        the shared tmp dir and the host completing the set commits; a
        step directory is therefore only ever visible fully merged.
        """
        self.wait()
        host_tree = jax.tree.map(np.asarray, jax.device_get(tree))
        meta = dict(metadata or {})
        meta["step"] = int(step)
        meta["time"] = time.time()
        meta["n_hosts"] = int(n_hosts)

        flat = {}
        for key, arr in _flatten(host_tree).items():
            if host_shard_filter is None:
                flat[key] = arr
                continue
            picked = host_shard_filter(key, arr)
            if picked is None:
                continue
            start, part = picked
            if start is None:
                flat[key] = part
            else:
                flat[f"{key}{_SEP}{_ROWS}{int(start)}"] = part

        def write():
            try:
                tmp = self.dir / f".tmp-{step}"
                if n_hosts == 1:
                    if tmp.exists():
                        shutil.rmtree(tmp)
                    tmp.mkdir(parents=True)
                    np.savez(tmp / "arrays.npz", **flat)
                else:
                    # multi-writer staging: parts land independently,
                    # the completing host commits
                    tmp.mkdir(parents=True, exist_ok=True)
                    part = tmp / f"shard{host_id:03d}-of-{n_hosts:03d}.npz"
                    part_tmp = part.with_suffix(".npz.tmp")
                    # write through a handle: np.savez(path) appends
                    # ".npz" to names missing it, breaking the rename
                    with open(part_tmp, "wb") as fh:
                        np.savez(fh, **flat)
                    os.replace(part_tmp, part)
                    if len(list(tmp.glob(f"shard*-of-{n_hosts:03d}.npz"))) \
                            < n_hosts:
                        return          # another host completes the set
                (tmp / "meta.json").write_text(json.dumps(meta))
                final = self.dir / f"step_{step:010d}"
                if final.exists():
                    shutil.rmtree(final)
                os.rename(tmp, final)          # atomic commit
                self._prune()
            except BaseException as e:        # surfaced on next wait()
                self.last_error = e

        if blocking:
            write()
            if self.last_error is not None:   # blocking callers want it NOW
                err, self.last_error = self.last_error, None
                raise err
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err

    def close(self):
        """Join any in-flight write; WARN (never raise) on an error that
        no ``wait()`` ever observed.  Safe on error-handling paths where
        raising would mask the in-flight exception."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            warnings.warn(
                f"[checkpoint] async write under {self.dir} failed and the "
                f"error was never observed by wait(): {err!r} -- the last "
                f"checkpoint of this run may be missing", RuntimeWarning,
                stacklevel=2)

    def __del__(self):
        # a Checkpointer dropped with a pending failure must not take the
        # evidence with it; never join/raise during interpreter teardown
        err = getattr(self, "last_error", None)
        if err is not None:
            self.last_error = None      # deliver once
            try:
                warnings.warn(
                    f"[checkpoint] Checkpointer({self.dir}) garbage-"
                    f"collected with an unobserved write error: {err!r}",
                    RuntimeWarning, stacklevel=2)
            except Exception:       # pragma: no cover - teardown races
                pass

    def _prune(self):
        steps = self.all_steps()
        # keep_last=0 keeps NOTHING: guard the [:-0] empty slice that
        # would silently keep everything
        drop = steps if self.keep_last <= 0 else steps[:-self.keep_last]
        for s in drop:
            shutil.rmtree(self.dir / f"step_{s:010d}", ignore_errors=True)

    # -- restore ---------------------------------------------------------

    def all_steps(self):
        return sorted(int(p.name.split("_")[1]) for p in self.dir.glob(
            "step_*") if (p / "meta.json").exists())

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def _load_merged(self, d: Path) -> dict:
        """Load one committed step dir, merging per-host shard files:
        plain keys load as-is, ``key||@rows<start>`` slices concat by
        offset.  The single-host ``arrays.npz`` layout is the n_hosts=1
        special case of the same reader."""
        files = sorted(d.glob("shard*-of-*.npz"))
        if not files:
            files = [d / "arrays.npz"]
        flat, sliced = {}, {}
        for f in files:
            with np.load(f, allow_pickle=False) as z:
                for key in z.files:
                    if _SEP + _ROWS in key:
                        base, _, start = key.rpartition(_SEP + _ROWS)
                        sliced.setdefault(base, []).append(
                            (int(start), z[key]))
                    else:
                        flat[key] = z[key]
        for base, parts in sliced.items():
            parts.sort(key=lambda p: p[0])
            flat[base] = np.concatenate([a for _, a in parts], axis=0) \
                if len(parts) > 1 else parts[0][1]
        return flat

    def restore(self, like_tree: Any, step: Optional[int] = None,
                shardings: Any = None):
        """Returns (tree, metadata).  ``shardings``: optional NamedSharding
        tree for the *target* mesh (elastic re-shard on load -- the mesh
        may be smaller than the one that wrote the checkpoint)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = self.dir / f"step_{step:010d}"
        flat = self._load_merged(d)
        meta = json.loads((d / "meta.json").read_text())
        tree = _unflatten_into(like_tree, flat)
        tree = jax.tree.map(
            lambda ref, x: np.asarray(x).astype(ref.dtype).reshape(ref.shape),
            like_tree, tree)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        return tree, meta
